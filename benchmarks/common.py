"""Shared benchmark plumbing: timing + ``name,us_per_call,derived`` CSV,
and the one merge-and-validate writer every serving benchmark uses for
``BENCH_serve.json`` (DESIGN.md SS15)."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []

# ``BENCH_serve.json`` schema: every serving benchmark owns exactly one
# top-level section and must provide at least these keys in it. Keeping
# the whole file section-keyed is what makes the merge safe — a run of
# one benchmark can never clobber another's results.
BENCH_SECTIONS: Dict[str, Tuple[str, ...]] = {
    "serve_bench": ("workload", "baseline_no_sharing", "prefix_sharing",
                    "derived"),
    "hbs_sweep": ("analytic_13b", "measured_reduced"),
    "chiplet_sweep": ("analytic_1b", "measured_reduced"),
    "spec_sweep": ("workload", "ngram", "spec_x_hbs"),
    "shard_sweep": ("workload", "overlap", "mesh", "capacity"),
}


def merge_bench_json(path: str, section: str, payload: dict) -> dict:
    """Merge one benchmark's ``payload`` into ``path`` under its section
    key, preserving every other benchmark's section, and validate the
    merged document against ``BENCH_SECTIONS`` before writing (atomic
    tmp + rename). Returns the merged document.

    Raises ``ValueError`` on an unknown section, a payload missing its
    required keys, a corrupt/non-object existing file, or an existing
    file with non-section top-level keys (the pre-SS15 layout, where
    ``serve_bench`` wrote its results at top level — regenerate it)."""
    if section not in BENCH_SECTIONS:
        raise ValueError(f"unknown BENCH_serve section {section!r}; "
                         f"known: {sorted(BENCH_SECTIONS)}")
    merged: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path} is not valid JSON ({e}); delete it "
                             f"and re-run the benchmarks") from e
        if not isinstance(merged, dict):
            raise ValueError(f"{path} must hold a JSON object, found "
                             f"{type(merged).__name__}")
        legacy = sorted(k for k in merged if k not in BENCH_SECTIONS)
        if legacy:
            raise ValueError(
                f"{path} has non-section top-level keys {legacy} — the "
                f"pre-sectioned layout (or a foreign file). Delete it and "
                f"re-run the benchmarks to regenerate the sectioned form.")
    merged[section] = payload
    for sec, required in BENCH_SECTIONS.items():
        if sec not in merged:
            continue
        if not isinstance(merged[sec], dict):
            raise ValueError(f"section {sec!r} must be an object")
        missing = [k for k in required if k not in merged[sec]]
        if missing:
            raise ValueError(f"section {sec!r} is missing required keys "
                             f"{missing}")
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
    return merged


def goodput_summary(report: dict) -> dict:
    """Compact per-cell form of ``TraceRecorder.slo_report`` for sweep
    grids: goodput + how many violators each phase is blamed for."""
    blame: Dict[str, int] = {}
    for v in report["violators"]:
        blame[v["blame"]] = blame.get(v["blame"], 0) + 1
    return {"goodput_frac": report["goodput_frac"],
            "n_met_slo": report["n_met_slo"],
            "n_requests": report["n_requests"],
            "violator_blame": blame}


def bench(name: str, fn: Callable[[], object], *, repeat: int = 1) -> object:
    """Time ``fn`` and record a CSV row; returns fn's result."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn()
    us = (time.perf_counter() - t0) * 1e6 / repeat
    derived = out if isinstance(out, str) else getattr(out, "derived", "")
    ROWS.append((name, us, str(derived)))
    return out


def emit(row_name: str, us: float, derived: str) -> None:
    ROWS.append((row_name, us, derived))


def flush() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in ROWS:
        print(f"{name},{us:.1f},{derived}")
    ROWS.clear()
