"""Shared benchmark plumbing: timing + ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def bench(name: str, fn: Callable[[], object], *, repeat: int = 1) -> object:
    """Time ``fn`` and record a CSV row; returns fn's result."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn()
    us = (time.perf_counter() - t0) * 1e6 / repeat
    derived = out if isinstance(out, str) else getattr(out, "derived", "")
    ROWS.append((name, us, str(derived)))
    return out


def emit(row_name: str, us: float, derived: str) -> None:
    ROWS.append((row_name, us, derived))


def flush() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in ROWS:
        print(f"{name},{us:.1f},{derived}")
    ROWS.clear()
