"""Paper Fig. 4 + takeaway IV: SRAM chiplet for Llama-3.2-1B (128/384).

Sweeps chiplet bandwidth for several DDR latencies; compares QKV-in-chiplet
vs MLP/projection-weights-in-chiplet, BOTH capacity-limited (128 MB, honest)
and idealised (unbounded, the paper's implicit assumption) — the capacity
split is a beyond-paper contribution.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (chiplet_mlp_weights, chiplet_qkv, ddr_only, lpddr6,
                        npu_hierarchy, run_inference, sram_chiplet)

CH_BWS = (173.0, 512.0, 1000.0)
DDR_LATS_NS = (100.0, 500.0, 1000.0)


def run(emit) -> str:
    cfg = get_config("llama3.2-1b")
    attn_shares = []
    pm_shares = []
    ideal_better = 0
    for lat in DDR_LATS_NS:
        base = run_inference(cfg, npu_hierarchy(lpddr6(173.0, latency_ns=lat)),
                             ddr_only(), 128, 384, dtype_bytes=2)
        a_lo, a_hi = base.decode_group_share("attn")
        # paper's Sec-II kernel list has no LM-head GEMM -> exclude "embed"
        mid = base.decode_samples[len(base.decode_samples) // 2][1]
        gemm_t = {g: 0.0 for g in ("attn", "proj", "mlp", "qkv_gen", "embed")}
        for kt in mid.kernel_times:
            if kt.kernel.kind == "gemm":
                gemm_t[kt.kernel.group] = gemm_t.get(kt.kernel.group, 0.0) + kt.time
        core = sum(v for g, v in gemm_t.items() if g != "embed")
        attn_shares.append(gemm_t["attn"] / core)
        pm_shares.append((gemm_t["proj"] + gemm_t["mlp"]) / core)
        rows = [f"base:{base.tps:.1f}"]
        for cbw in CH_BWS:
            for cap, tag in ((128.0, "128MB"), (4096.0, "ideal")):
                h = npu_hierarchy(lpddr6(173.0, latency_ns=lat),
                                  chiplet=sram_chiplet(cbw, capacity_mb=cap))
                r_q = run_inference(cfg, h, chiplet_qkv(), 128, 384,
                                    dtype_bytes=2)
                r_w = run_inference(cfg, h, chiplet_mlp_weights(), 128, 384,
                                    dtype_bytes=2)
                rows.append(f"{cbw:g}GB/s.{tag}:qkv={r_q.tps:.1f}"
                            f"/w={r_w.tps:.1f}")
                if tag == "ideal" and r_w.tps > r_q.tps:
                    ideal_better += 1
        emit(f"fig4.ddr_lat{lat:g}ns", 0.0, " ".join(rows))
    return (f"attn_share={min(attn_shares)*100:.0f}-{max(attn_shares)*100:.0f}%"
            f"(paper 4-9) proj+mlp={min(pm_shares)*100:.0f}-"
            f"{max(pm_shares)*100:.0f}%(paper 82-86) "
            f"takeawayIV_ideal={ideal_better}/{len(DDR_LATS_NS)*len(CH_BWS)}")
