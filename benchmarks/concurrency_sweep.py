"""TPS vs concurrency: runtime engine (static vs continuous) x analytical.

The paper's capacity-pressure experiment, answerable for any hierarchy
preset: every concurrent request adds a full KV cache, so aggregate KV
grows linearly while the fast tiers don't. Two halves:

1. ANALYTICAL — ``repro.core.concurrency`` sweeps a 1B-class config on the
   paper's NPU+HBS hierarchy and the bonded-SRAM-chiplet hierarchy,
   reporting aggregate/per-request TPS and the KV tier split as the
   ``capacity_aware`` policy starts spilling.
2. RUNTIME — the reduced same-family config served by the real engine with
   the static bucketed scheduler vs the continuous paged scheduler over a
   ragged request stream (the continuous engine keeps slots busy as short
   requests retire; the static engine waits for each wave).

Run: PYTHONPATH=src python benchmarks/concurrency_sweep.py [--skip-runtime]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.core import (chiplet_qkv, concurrency_sweep, hbs, kv_dedup_factor,
                        lpddr6, max_concurrency_without_spill, npu_hierarchy,
                        qkv_in_ddr, sram_chiplet)
from repro.models import RuntimeOptions, init_params

ARCH = "llama3.2-1b"           # the paper's 1B-class subject
PREFILL, DECODE = 2048, 256
CONCURRENCY = (1, 2, 4, 8, 16, 32, 64)


def hierarchies():
    return (
        ("npu+hbs", npu_hierarchy(lpddr6(520.0), hbs(64.0, latency_us=20.0)),
         qkv_in_ddr()),
        ("npu+chiplet", npu_hierarchy(lpddr6(173.0),
                                      chiplet=sram_chiplet(512.0)),
         chiplet_qkv()),
    )


def analytical() -> None:
    cfg = get_config(ARCH)
    print(f"== analytical: {ARCH}  prefill={PREFILL} decode={DECODE} ==")
    for name, hier, place in hierarchies():
        limit = max_concurrency_without_spill(cfg, hier, place,
                                              prefill_len=PREFILL,
                                              decode_len=DECODE)
        print(f"\n-- {name} (placement={place.name}; "
              f"no-spill concurrency limit={limit})")
        print(f"{'n':>4} {'agg_tps':>10} {'tps/req':>9} {'kv_GB':>7} "
              f"{'spill':>6} {'bottleneck':>10}  kv tiers")
        for p in concurrency_sweep(cfg, hier, place,
                                   concurrency=CONCURRENCY,
                                   prefill_len=PREFILL, decode_len=DECODE):
            tiers = " ".join(f"{lv}:{fr:.2f}" for lv, fr in p.kv_locations)
            print(f"{p.n_concurrent:>4} {p.aggregate_tps:>10.1f} "
                  f"{p.per_request_tps:>9.2f} {p.kv_bytes/1e9:>7.2f} "
                  f"{p.kv_spill_frac:>6.2f} {p.bottleneck:>10}  {tiers}")


def runtime() -> None:
    import jax
    from repro.serving import ServeEngine

    cfg = reduced(get_config(ARCH), d_model=128, n_layers=4, vocab=512)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)
    new_tokens, max_len = 16, 96

    print(f"\n== runtime: reduced {ARCH} ({cfg.d_model}d x {cfg.n_layers}L) "
          f"ragged prompts, {new_tokens} new tokens ==")
    print(f"{'n':>4} {'static_tps':>11} {'continuous_tps':>15} "
          f"{'steps_s/c':>10} {'preempt':>8} {'ttft_p50/p95_ms':>16} "
          f"{'itl_p50/p95_ms':>15}")
    for n in (2, 4, 8):
        lens = rng.integers(8, 64, size=n)
        reqs = [rng.integers(1, cfg.vocab, size=int(ln)).tolist()
                for ln in lens]
        res = {}
        for sched in ("static", "continuous"):
            eng = ServeEngine(cfg, params, opts, max_len=max_len,
                              scheduler=sched, page_size=16, max_batch=8)
            # warm the jit caches so TPS compares steady-state decode
            eng.serve([r[:] for r in reqs], new_tokens)
            eng.stats.__init__()
            eng.serve([r[:] for r in reqs], new_tokens)
            res[sched] = eng.stats
        s, c = res["static"], res["continuous"]
        print(f"{n:>4} {s.tps:>11.1f} {c.tps:>15.1f} "
              f"{s.decode_steps:>4}/{c.decode_steps:<4} "
              f"{c.preemptions:>8} "
              f"{c.ttft_p50*1e3:>7.1f}/{c.ttft_p95*1e3:<8.1f} "
              f"{c.itl_p50*1e3:>6.1f}/{c.itl_p95*1e3:<8.1f}")


def shared_prefix_analytical() -> None:
    """Sharing-aware no-spill concurrency per hierarchy preset."""
    cfg = get_config(ARCH)
    print(f"\n== shared-prefix dedup: {ARCH} prefill={PREFILL} "
          f"decode={DECODE} (prefix = 75% of prompt) ==")
    pfx = int(PREFILL * 0.75)
    print(f"{'hier':>12} {'share_group':>12} {'dedup@8':>8} "
          f"{'no-spill limit':>15}")
    for name, hier, place in hierarchies():
        for g in (1, 4, 8):
            lim = max_concurrency_without_spill(
                cfg, hier, place, prefill_len=PREFILL, decode_len=DECODE,
                shared_prefix_len=pfx, share_group=g)
            f = kv_dedup_factor(8, PREFILL, DECODE,
                                shared_prefix_len=pfx, share_group=g)
            print(f"{name:>12} {g:>12} {f:>8.2f} {lim:>15}")


def shared_prefix_runtime() -> None:
    """Measured dedup on a shared-document QA workload vs predicted."""
    import jax
    from repro.serving import ServeEngine

    rcfg = reduced(get_config(ARCH), d_model=128, n_layers=4, vocab=512)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(rcfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(1)
    doc = rng.integers(1, rcfg.vocab, size=48).tolist()
    reqs = [doc + rng.integers(1, rcfg.vocab, size=8).tolist()
            for _ in range(6)]
    print(f"\n-- runtime: 6 requests x (48-token doc + 8-token question), "
          f"16 new tokens")
    print(f"{'prefix_cache':>13} {'prefill_toks':>13} {'peak_pages':>11} "
          f"{'deduped':>8} {'ttft_p95_ms':>12}")
    meas = {}
    for pc in (False, True):
        eng = ServeEngine(rcfg, params, opts, max_len=96,
                          scheduler="continuous", page_size=16, max_batch=8,
                          prefix_cache=pc)
        eng.serve([r[:] for r in reqs], 16)
        eng.stats.__init__()
        eng.serve([r[:] for r in reqs], 16)
        st = eng.stats
        meas[pc] = st
        print(f"{str(pc):>13} {st.prefill_tokens_computed:>13} "
              f"{st.peak_pages_used:>11} {st.pages_deduped:>8} "
              f"{st.ttft_p95*1e3:>12.1f}")
    predicted = kv_dedup_factor(6, 56, 16, shared_prefix_len=48,
                                share_group=6)
    measured = (meas[True].peak_pages_used
                / max(meas[False].peak_pages_used, 1))
    print(f"   predicted KV dedup factor {predicted:.2f} vs measured "
          f"peak-page ratio {measured:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-runtime", action="store_true",
                    help="analytical table only (no jit compiles)")
    args = ap.parse_args()
    analytical()
    shared_prefix_analytical()
    if not args.skip_runtime:
        runtime()
        shared_prefix_runtime()


if __name__ == "__main__":
    main()
