"""Paper Fig. 1: TPS vs HBS bandwidth x latency, DDR at 173 / 520 GB/s.

LLaVa-1.5-13B FP16, prefill/decode 200/200, 35 TFLOP/s NPU.
Derived: saturation TPS per panel + the HBS:DDR bandwidth ratio at which the
bottleneck shifts to DDR (paper takeaway I: ~1.4x).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import all_hbs, hbs, lpddr6, npu_hierarchy, run_inference

HBS_BWS = (16, 32, 64, 128, 173, 256, 384, 512)
LATENCIES_US = (2.0, 10.0, 50.0, 100.0)


def tps_at(ddr_bw: float, hbs_bw: float, lat_us: float) -> object:
    cfg = get_config("llava15-13b")
    hier = npu_hierarchy(lpddr6(ddr_bw), hbs(hbs_bw, latency_us=lat_us))
    return run_inference(cfg, hier, all_hbs(), 200, 200, dtype_bytes=2)


def sweep(ddr_bw: float):
    grid = {}
    for lat in LATENCIES_US:
        for bw in HBS_BWS:
            rep = tps_at(ddr_bw, bw, lat)
            grid[(lat, bw)] = (rep.tps, rep.bottleneck)
    return grid


def shift_ratio(grid, ddr_bw: float) -> float:
    """Lowest HBS:DDR bw ratio where the mid-latency curve goes DDR-bound."""
    for bw in HBS_BWS:
        tps, bott = grid[(10.0, bw)]
        if bott == "ddr":
            return bw / ddr_bw
    return float("inf")


def run(emit) -> str:
    derived = []
    for panel, ddr_bw in (("a", 173.0), ("b", 520.0)):
        grid = sweep(ddr_bw)
        for lat in LATENCIES_US:
            pts = " ".join(f"{bw}:{grid[(lat, bw)][0]:.2f}" for bw in HBS_BWS)
            emit(f"fig1{panel}.lat{lat:g}us", 0.0, f"tps[{pts}]")
        sat = max(grid[(2.0, bw)][0] for bw in HBS_BWS)
        ratio = shift_ratio(grid, ddr_bw)
        meets = sat >= 10.0
        derived.append(f"panel{panel}: sat_tps={sat:.2f} shift@{ratio:.2f}xDDR "
                       f"10tps={'yes' if meets else 'no'}")
    return "; ".join(derived)
