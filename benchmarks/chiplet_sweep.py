"""Chiplet global-buffer sweep: chiplet size x migration policy, both
halves (DESIGN.md SS17).

The paper's bonded-SRAM-chiplet lever puts a small, very fast buffer in
front of the constrained platform's DDR; this benchmark asks what that
buys a 1B-class on-device model whose long-context KV spills to HBS:

* **analytic_1b** — the hierarchical roofline at FULL llama3.2-1b scale
  (`core.concurrency.chiplet_interactivity_sweep`): the HBS
  bandwidth x latency interactivity grid with the chiplet's steady-state
  hit fraction absorbing its share of the KV streaming, per swept chiplet
  capacity. The readout is the minimum-HBS-bandwidth envelope per ITL
  target — which must shift DOWN (never up) as the chiplet grows — plus
  the int8-KV x chiplet compounded envelope
  (`compounded_offload_envelope`).
* **measured_reduced** — the real serve engine on a reduced dense twin
  over a chiplet-pages x policy grid (layer-overlap vs whole-block
  barrier, dedicated vs shared write-back link): recorded stall, the
  within-run counterfactual barrier stall (``stall + stall_saved`` — what
  the SAME run would have charged without layer slicing, so the
  comparison is exact rather than cross-run-noisy), EMA promotion hit
  rate, promotion/demotion traffic per channel, and token identity
  against the no-offload reference.

Acceptance gates (in ``derived``): every offload/overlap/chiplet config
is token-identical to the no-offload baseline; layer-overlap stall is
never above the barrier stall and strictly below it somewhere; a growing
chiplet hit fraction lowers the analytic min-bandwidth envelope.

Run: PYTHONPATH=src python benchmarks/chiplet_sweep.py --json
(merges its section into BENCH_serve.json next to the other sweeps').
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

try:
    from benchmarks.common import merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from common import merge_bench_json

from repro.configs import get_config
from repro.configs.reduce import reduced

# generous-bandwidth point: transfers complete in sub-µs virtual time, so
# recorded stall must round to zero and outputs stay token-identical
GENEROUS_GBPS = 1e6


def _envelope(grid, targets, **kw) -> dict:
    from repro.core import min_hbs_bandwidth_for_itl
    return {f"itl<={int(t * 1e3)}ms":
            {f"{lat_us:g}us": (bw if bw != float("inf") else None)
             for lat_us, bw in
             min_hbs_bandwidth_for_itl(grid, t, **kw).items()}
            for t in targets}


def _le(a, b) -> bool:
    """None means 'no swept bandwidth met the target' (= +inf)."""
    return (a or float("inf")) <= (b or float("inf"))


def analytic_section(args) -> dict:
    from repro.core import (TC, chiplet_interactivity_sweep,
                            chiplet_kv_hit_frac, compounded_offload_envelope,
                            ddr_only, hbs, lpddr6, npu_hierarchy,
                            resident_bytes)

    cfg = get_config("llama3.2-1b")
    # DDR sized so the weights stay hot but only ~25% of the long-context
    # KV fits — the remainder streams from HBS, the regime where the
    # chiplet's hit fraction matters (same pinned-split setup as
    # hbs_sweep; see that module for why capacity_aware alone inverts it)
    ctx = args.context
    fp = resident_bytes(cfg, ctx + 256, 1, 2)
    kv_bytes = fp[TC.KV]
    non_kv = sum(v for c, v in fp.items() if c != TC.KV)
    kv_fast = 0.25
    ddr_gb = (non_kv + kv_fast * kv_bytes) / 1e9
    hier = npu_hierarchy(lpddr6(520.0, capacity_gb=ddr_gb),
                         hbs(8.0, latency_us=20.0))
    kv_split = (("ddr", kv_fast), ("hbs", 1.0 - kv_fast))
    bw = [float(x) for x in args.bw_gbps.split(",")]
    lat = [float(x) for x in args.latency_us.split(",")]
    sizes = [float(x) for x in args.chiplet_mb.split(",")]
    grid = chiplet_interactivity_sweep(cfg, hier, ddr_only(),
                                       chiplet_mb=sizes, bw_gbps=bw,
                                       latency_us=lat, prefill_len=ctx,
                                       decode_len=256, dtype_bytes=2,
                                       kv_split=kv_split)
    cells = [{
        "chiplet_mb": g.chiplet_mb,
        "hit_frac": round(g.hit_frac, 4),
        "bw_gbps": g.bw_gbps,
        "latency_us": g.latency_us,
        "tps": round(g.tps, 3),
        "itl_ms": round(g.itl_s * 1e3, 3),
        "kv_spill_frac": round(g.kv_spill_frac, 3),
    } for g in grid]

    # per-chiplet-size min-bandwidth envelope: each ChipletGridPoint
    # already folds its hit fraction into itl_s, so the plain readout
    # applied per size slice IS the chiplet-adjusted envelope
    targets = (0.05, 0.25, 1.0)
    by_size = {}
    for mb in sizes:
        sub = [g for g in grid if g.chiplet_mb == mb]
        by_size[f"{mb:g}MB"] = {
            "hit_frac": round(sub[0].hit_frac, 4),
            "min_bw_gbps_for_target": _envelope(sub, targets),
        }
    # gate: a growing hit fraction never RAISES any envelope entry and
    # strictly lowers at least one, relative to the chiplet-less slice
    base_env = by_size[f"{min(sizes):g}MB"]["min_bw_gbps_for_target"]
    never_worse, strictly_lower = True, False
    for mb in sizes:
        env = by_size[f"{mb:g}MB"]["min_bw_gbps_for_target"]
        h = by_size[f"{mb:g}MB"]["hit_frac"]
        for t in env:
            for c in env[t]:
                if h > 0 and not _le(env[t][c], base_env[t][c]):
                    never_worse = False
                if h > 0 and (env[t][c] or 0.0) < (base_env[t][c]
                                                   or float("inf")):
                    strictly_lower = True

    # the compounded readout: int8 KV halves the streamed bytes AND
    # doubles what fits in the chiplet — both enter the envelope
    mb_max = max(sizes)
    h8 = chiplet_kv_hit_frac(cfg, ctx + 256, chiplet_mb=mb_max,
                             dtype_bytes=1)
    compounded = {f"itl<={int(t * 1e3)}ms":
                  {f"{lat_us:g}us": (bw_min if bw_min != float("inf")
                                     else None)
                   for lat_us, bw_min in compounded_offload_envelope(
                       [g.base for g in grid if g.chiplet_mb == mb_max],
                       t, dtype_bytes=2, kv_dtype_bytes=1,
                       chiplet_hit_frac=h8).items()}
                  for t in targets}
    return {"arch": cfg.name, "context": ctx,
            "kv_mb": round(kv_bytes / 1e6, 1),
            "ddr_gb": round(ddr_gb, 3), "kv_fast_frac": kv_fast,
            "grid": cells, "by_chiplet_size": by_size,
            "int8_compounded": {
                "chiplet_mb": mb_max, "hit_frac": round(h8, 4),
                "min_bw_gbps_for_target": compounded},
            "derived": {
                "hit_frac_lowers_envelope_everywhere": never_worse,
                "hit_frac_strictly_lowers_somewhere": strictly_lower,
            }}


def measured_section(args) -> dict:
    import jax
    from repro.core import hbs, lpddr6, npu_hierarchy, sram_chiplet
    from repro.models import RuntimeOptions, init_params
    from repro.serving import ServeEngine
    from repro.serving.kv_manager import page_bytes

    # reduced dense twin of the 1B config, shrunk for the CPU engine but
    # deep enough (4 layers) that layer slicing has layers to hide behind
    cfg = dataclasses.replace(
        reduced(get_config("llama3.2-1b"), d_model=128, n_layers=4,
                vocab=512),
        family="dense", prefix_len=0, source_len=0,
        name="llama3.2-1b-reduced-dense")
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    page_size = 16
    pb = page_bytes(cfg, page_size, 4)

    rng = np.random.default_rng(0)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (args.prompt_len, args.prompt_len,
                      args.prompt_len // 2, args.prompt_len // 2)]
    max_len = args.prompt_len + args.new_tokens
    common = dict(max_len=max_len, scheduler="continuous",
                  page_size=page_size, max_batch=4, prefix_cache=True)

    # no-offload baseline: the token-identity reference
    base = ServeEngine(cfg, params, opts, **common)
    base.serve([r[:] for r in reqs], args.new_tokens)       # warm jit
    base.stats.__init__()
    want = base.serve([r[:] for r in reqs], args.new_tokens)

    total_pages = sum(-(-(len(r) + args.new_tokens) // page_size)
                      for r in reqs)
    fast_pages = max(total_pages // 3, 2)
    chip_sizes = [int(x) for x in args.chiplet_pages.split(",")]
    policies = [("overlap", "dedicated"), ("barrier", "dedicated"),
                ("overlap", "shared")]

    def hier_for(chip_pages: int):
        chiplet = (sram_chiplet(512.0, capacity_mb=chip_pages * pb / 1e6)
                   if chip_pages else None)
        return npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                             hbs(8.0, latency_us=20.0, capacity_gb=1.0),
                             chiplet=chiplet)

    def run(chip_pages: int, policy: str, link: str, bw: float,
            lat_us: float = 20.0) -> dict:
        eng = ServeEngine(cfg, params, opts, **common,
                          hierarchy=hier_for(chip_pages), hbs_gbps=bw,
                          hbs_latency_us=lat_us,
                          layer_overlap=(policy == "overlap"),
                          writeback_link=link)
        eng.serve([r[:] for r in reqs], args.new_tokens)    # warm jit
        eng.stats.__init__()
        outs = eng.serve([r[:] for r in reqs], args.new_tokens)
        s = eng.stats
        return {
            "chiplet_pages": chip_pages, "policy": policy,
            "writeback_link": link, "bw_gbps": bw,
            "tps": round(s.tps, 2),
            "stall_ms": round(s.stall_s * 1e3, 3),
            "stall_saved_ms": round(s.stall_saved_s * 1e3, 3),
            # what the SAME run's calls would have charged with the
            # whole-block barrier — the exact counterfactual
            "barrier_stall_ms": round(
                (s.stall_s + s.stall_saved_s) * 1e3, 3),
            "itl_p95_ms": round(s.itl_p95 * 1e3, 3),
            "chiplet_hit_rate": round(s.chiplet_hit_rate, 4),
            "chiplet_promotions": s.chiplet_promotions,
            "chiplet_demotions": s.chiplet_demotions,
            "clean_demotions": s.clean_demotions,
            "spill_mb": round(s.spill_bytes / 1e6, 3),
            "fetch_mb": round(s.fetch_bytes / 1e6, 3),
            "channel_mb": {k: round(v / 1e6, 4)
                           for k, v in sorted(s.channel_bytes.items())},
            "token_identical": outs == want,
            "trace_reconciled": eng.trace_report["ok"],
        }

    cells = [run(cp, pol, link, args.hbs_gbps)
             for cp in chip_sizes for pol, link in policies]
    generous = run(max(chip_sizes), "overlap", "dedicated", GENEROUS_GBPS,
                   lat_us=0.0)
    cells.append(generous)

    # gates: pair each overlap cell with its barrier twin (same chiplet
    # size, dedicated link, stingy bandwidth); the measured cross-run
    # comparison gets a small wall-clock-noise tolerance, while the
    # within-run counterfactual (stall <= barrier_stall) is exact
    pairs = []
    for cp in chip_sizes:
        o = next(c for c in cells
                 if c["chiplet_pages"] == cp and c["policy"] == "overlap"
                 and c["writeback_link"] == "dedicated"
                 and c["bw_gbps"] == args.hbs_gbps)
        b = next(c for c in cells
                 if c["chiplet_pages"] == cp and c["policy"] == "barrier"
                 and c["writeback_link"] == "dedicated")
        pairs.append({"chiplet_pages": cp,
                      "overlap_stall_ms": o["stall_ms"],
                      "barrier_run_stall_ms": b["stall_ms"],
                      "counterfactual_barrier_ms": o["barrier_stall_ms"],
                      "saved_ms": o["stall_saved_ms"]})
    tol = lambda b_ms: max(2.0, 0.05 * b_ms)
    overlap_le = all(
        p["overlap_stall_ms"] <= p["counterfactual_barrier_ms"] + 1e-9
        and p["overlap_stall_ms"]
        <= p["barrier_run_stall_ms"] + tol(p["barrier_run_stall_ms"])
        for p in pairs)
    hit = {c["chiplet_pages"]: c["chiplet_hit_rate"] for c in cells
           if c["policy"] == "overlap" and c["writeback_link"] == "dedicated"
           and c["bw_gbps"] == args.hbs_gbps}
    return {
        "arch": cfg.name, "n_requests": len(reqs),
        "prompt_len": args.prompt_len, "new_tokens": args.new_tokens,
        "fast_pages": fast_pages, "page_kb": round(pb / 1e3, 2),
        "n_layer_slices": cfg.n_layers, "hbs_gbps": args.hbs_gbps,
        "grid": cells, "overlap_vs_barrier": pairs,
        "derived": {
            "all_token_identical": all(c["token_identical"]
                                       for c in cells),
            "all_traces_reconciled": all(c["trace_reconciled"]
                                         for c in cells),
            "overlap_le_barrier_everywhere": overlap_le,
            "overlap_strictly_lower_somewhere": any(
                p["saved_ms"] > 0.1 for p in pairs),
            "hit_rate_by_chiplet_pages": hit,
            "hit_rate_grows_with_chiplet": (
                hit[max(chip_sizes)] >= hit[min(chip_sizes)]),
            "generous_stall_ms": generous["stall_ms"],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None,
                    help="merge results into this JSON file under the "
                         "'chiplet_sweep' key")
    ap.add_argument("--context", type=int, default=4096,
                    help="analytic long-context prefill length")
    ap.add_argument("--bw-gbps", default="2,8,32,128",
                    help="analytic HBS bandwidth grid (GB/s)")
    ap.add_argument("--latency-us", default="5,20,80",
                    help="analytic HBS latency grid (µs)")
    ap.add_argument("--chiplet-mb", default="0,32,128,512",
                    help="analytic chiplet capacity grid (MB; 0 = none; "
                         "the 1B model's KV at the default context is "
                         "~570 MB, so the grid spans hit fractions from "
                         "~0.06 to ~0.9)")
    ap.add_argument("--chiplet-pages", default="0,2,6",
                    help="measured-engine chiplet sizes in KV pages")
    ap.add_argument("--hbs-gbps", type=float, default=0.005,
                    help="measured-engine stingy HBS bandwidth (GB/s; a "
                         "generous point is appended automatically)")
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    results = {"analytic_1b": analytic_section(args),
               "measured_reduced": measured_section(args)}
    print(json.dumps(results, indent=2))
    if args.json:
        merge_bench_json(args.json, "chiplet_sweep", results)
        print(f"[chiplet_sweep] merged into {args.json}")


if __name__ == "__main__":
    main()
