"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``us_per_call`` times the analytical
evaluation itself (the paper's artifact is the model, so its evaluation cost
is the honest per-call number); ``derived`` carries the reproduced claim.

Run: PYTHONPATH=src python -m benchmarks.run [--only fig1,table1]
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common

MODULES = ("table1", "fig1", "fig2", "fig3", "fig4",
           "beyond_tpu_tiers", "roofline_tpu")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module names")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            derived = mod.run(common.emit)
        except Exception as e:  # keep the harness alive; report the failure
            derived = f"ERROR:{type(e).__name__}:{e}"
        us = (time.perf_counter() - t0) * 1e6
        common.emit(f"{name}.total", us, derived)
    common.flush()


if __name__ == "__main__":
    main()
