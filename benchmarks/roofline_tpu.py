"""TPU-pod roofline table (deliverable g) from dry-run artifacts.

Reads ``artifacts/dryrun/*.json`` produced by ``repro.launch.dryrun`` and
reports the three roofline terms per (arch x shape x mesh). Skips quietly if
no artifacts exist yet (run the dry-run first).
"""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(emit) -> str:
    if not ART.exists():
        return "no dry-run artifacts (run repro.launch.dryrun first)"
    n = 0
    worst = ("", 0.0)
    for p in sorted(ART.glob("*.json")):
        rec = json.loads(p.read_text())
        if "roofline" not in rec or rec.get("tag"):
            continue  # tagged records are SSPerf hillclimb variants
        r = rec["roofline"]
        emit(f"roofline.{p.stem}", 0.0,
             f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
             f"collective={r['collective_s']:.2e}s bott={r['bottleneck']} "
             f"useful={r['model_flops_ratio']:.2f}")
        n += 1
        frac = r.get("roofline_fraction", 0.0)
        if worst[0] == "" or frac < worst[1]:
            worst = (p.stem, frac)
    return f"{n} cells; worst_roofline_fraction={worst[0]}:{worst[1]:.2f}"
