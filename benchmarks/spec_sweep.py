"""Speculative decoding sweep: acceptance and TPS speedup on the fused
paged path (DESIGN.md SS14).

Three sections, all on the real serve engine:

* **ngram** — the shared-document prompt-lookup workload the paper's
  constrained-platform story targets: every request shares a document
  prefix and greedy decode loops through predictable continuations, so
  the model-free n-gram draft proposes multi-token runs that the single
  verify pass accepts. Sweeps the draft length K, recording acceptance
  rate, TPS, speedup over spec-off, and the temp-0 token-identity gate.
* **model_draft** — the same workload drafted by a half-width model over
  its own paged KV pool (sync / catch-up / propose-rollback protocol).
* **spec_x_hbs** — the compounding claim: with the fast KV tier capped
  and cold pages in simulated HBS, every saved verify pass is a saved
  fetch-wait barrier, so speculative decoding buys back stall exactly
  where bandwidth is scarce.

Run: PYTHONPATH=src python benchmarks/spec_sweep.py --json
(merges its section into BENCH_serve.json next to serve_bench's).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced

try:
    from benchmarks.common import goodput_summary, merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from common import goodput_summary, merge_bench_json

GENEROUS_GBPS = 1e6


def _mk(cfg, params, opts, common, **kw):
    from repro.serving import ServeEngine
    return ServeEngine(cfg, params, opts, **common, **kw)


def _run(eng, reqs, new_tokens):
    """Warm the jit caches, then measure a clean pass."""
    eng.serve([r[:] for r in reqs], new_tokens)
    eng.stats.__init__()
    outs = eng.serve([r[:] for r in reqs], new_tokens)
    return outs, eng.stats


def _workload(cfg, args):
    """Shared-document QA shape: one document every request shares, plus a
    short unique question suffix. Greedy decode of the reduced model then
    revisits spans of its own context — prompt-lookup territory."""
    rng = np.random.default_rng(0)
    doc = rng.integers(1, cfg.vocab, size=args.doc_len).tolist()
    return [doc + rng.integers(1, cfg.vocab, size=args.tail_len).tolist()
            for _ in range(args.n_requests)]


def ngram_section(cfg, params, opts, common, reqs, args) -> dict:
    base = _mk(cfg, params, opts, common)
    want, s0 = _run(base, reqs, args.new_tokens)
    tps0 = s0.tps
    rows = []
    for k in (int(x) for x in args.k_sweep.split(",")):
        eng = _mk(cfg, params, opts, common, spec_mode="ngram", spec_k=k)
        outs, s = _run(eng, reqs, args.new_tokens)
        rows.append({
            "k": k,
            "tps": round(s.tps, 2),
            "speedup": round(s.tps / tps0, 3),
            "acceptance_rate": round(s.acceptance_rate, 3),
            "draft_proposed": s.draft_proposed,
            "draft_accepted": s.draft_accepted,
            "spec_blocks": s.spec_blocks,
            "decode_steps": s.decode_steps,
            "host_syncs": s.host_syncs,
            "token_identical": outs == want,
            # trace-derived (SS15): draft overhead vs decode time, and
            # goodput vs the SLO targets
            "breakdown_ms": eng.trace.aggregate_breakdown_ms(),
            "goodput": goodput_summary(eng.trace.slo_report(
                args.slo_ttft_ms * 1e-3, args.slo_itl_ms * 1e-3)),
        })
    best = max(rows, key=lambda r: r["speedup"])
    return {
        "baseline_tps": round(tps0, 2),
        "baseline_decode_steps": s0.decode_steps,
        "sweep": rows,
        "derived": {
            "all_token_identical": all(r["token_identical"] for r in rows),
            "best_speedup": best["speedup"],
            "best_k": best["k"],
            "speedup_ge_1_2x": best["speedup"] >= 1.2,
        },
    }


def model_draft_section(cfg, params, opts, common, reqs, args) -> dict:
    base = _mk(cfg, params, opts, common)
    want, s0 = _run(base, reqs, args.new_tokens)
    dcfg = dataclasses.replace(
        reduced(get_config(args.arch), d_model=args.d_model // 2,
                n_layers=1, vocab=cfg.vocab),
        name=cfg.name + "-draft")
    eng = _mk(cfg, params, opts, common, spec_mode="model",
              spec_k=args.spec_k, draft_cfg=dcfg)
    outs, s = _run(eng, reqs, args.new_tokens)
    return {
        "draft_arch": dcfg.name, "k": args.spec_k,
        "tps": round(s.tps, 2),
        "speedup": round(s.tps / s0.tps, 3),
        "acceptance_rate": round(s.acceptance_rate, 3),
        "draft_proposed": s.draft_proposed,
        "draft_accepted": s.draft_accepted,
        "token_identical": outs == want,
        # what this section gates is the sync/catch-up/propose-rollback
        # PROTOCOL (identity + acceptance); wall-clock speedup needs a
        # draft ≪ target cost ratio that a reduced CPU twin cannot
        # provide — its per-pass dispatch floor is the target's
        "note": "protocol + identity gate; reduced-scale draft is not "
                "cheaper than the reduced target, so tps is not the "
                "deployment signal here (ngram section is)",
    }


def spec_x_hbs_section(cfg, params, opts, common, reqs, args) -> dict:
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    ps = common["page_size"]
    pb = page_bytes(cfg, ps, 4)
    total_pages = sum(-(-(len(r) + args.new_tokens) // ps) for r in reqs)
    fast_pages = max(total_pages // 3, 2)
    cells = []
    for bw in [float(x) for x in args.hbs_bw_gbps.split(",")] + \
              [GENEROUS_GBPS]:
        hier = npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                             hbs(bw, latency_us=20.0, capacity_gb=1.0))
        row = {"bw_gbps": bw}
        for mode in ("off", "ngram"):
            eng = _mk(cfg, params, opts, common, hierarchy=hier,
                      hbs_gbps=bw, hbs_latency_us=20.0, spec_mode=mode,
                      spec_k=args.spec_k)
            outs, s = _run(eng, reqs, args.new_tokens)
            row[mode] = {
                "tps": round(s.tps, 2),
                "stall_ms": round(s.stall_s * 1e3, 3),
                "itl_p95_ms": round(s.itl_p95 * 1e3, 3),
                "fetch_mb": round(s.fetch_bytes / 1e6, 3),
                "acceptance_rate": round(s.acceptance_rate, 3),
                "breakdown_ms": eng.trace.aggregate_breakdown_ms(),
                "goodput": goodput_summary(eng.trace.slo_report(
                    args.slo_ttft_ms * 1e-3, args.slo_itl_ms * 1e-3)),
            }
        row["spec_speedup"] = round(
            row["ngram"]["tps"] / max(row["off"]["tps"], 1e-9), 3)
        cells.append(row)
    return {"fast_pages": fast_pages, "grid": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None,
                    help="merge results into this JSON file under the "
                         "'spec_sweep' key")
    # the reduced target must carry enough compute per stack traversal for
    # the verify pass's one-traversal-per-K-tokens advantage to beat the
    # fused baseline's dispatch-bound 8-token blocks; a dispatch-bound toy
    # (d_model 64) under-reports spec decoding the same way it
    # under-reports any bandwidth-side win
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--doc-len", type=int, default=96)
    ap.add_argument("--tail-len", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--k-sweep", default="4,8,12")
    ap.add_argument("--spec-k", type=int, default=8,
                    help="draft length for the model-draft and spec x HBS "
                         "sections; match the baseline's fused block size "
                         "(--decode-lookahead 8) so the HBS grid compares "
                         "equal streaming cadences — KV fetch traffic per "
                         "pass is the same, spec just lands more tokens "
                         "per stream")
    ap.add_argument("--hbs-bw-gbps", default="0.002,0.02")
    ap.add_argument("--skip-model-draft", action="store_true")
    ap.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="TTFT target for the goodput reports")
    ap.add_argument("--slo-itl-ms", type=float, default=100.0,
                    help="per-request p95 ITL target for the goodput "
                         "reports")
    args = ap.parse_args()

    import jax
    from repro.models import RuntimeOptions, init_params

    cfg = reduced(get_config(args.arch), d_model=args.d_model,
                  n_layers=args.n_layers, vocab=args.vocab)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    reqs = _workload(cfg, args)
    max_len = args.doc_len + args.tail_len + args.new_tokens
    common = dict(max_len=max_len, scheduler="continuous", page_size=16,
                  max_batch=4, prefix_cache=True)

    results = {
        "workload": {"arch": cfg.name, "doc_len": args.doc_len,
                     "tail_len": args.tail_len,
                     "n_requests": args.n_requests,
                     "new_tokens": args.new_tokens},
        "ngram": ngram_section(cfg, params, opts, common, reqs, args),
        "spec_x_hbs": spec_x_hbs_section(cfg, params, opts, common, reqs,
                                         args),
    }
    if not args.skip_model_draft:
        results["model_draft"] = model_draft_section(cfg, params, opts,
                                                     common, reqs, args)
    print(json.dumps(results, indent=2))
    if args.json:
        merge_bench_json(args.json, "spec_sweep", results)
        print(f"[spec_sweep] merged into {args.json}")


if __name__ == "__main__":
    main()
