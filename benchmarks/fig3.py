"""Paper Fig. 3: context scaling — prefill/decode up to 8192/24576.

HBS fixed at 512 GB/s / 10 us. Derived: monotonic TPS degradation with
context + consistent relative gains (paper) + the ~27 GB KV @ 33k claim.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (all_hbs, hbs, lpddr6, npu_hierarchy, qkv_in_ddr,
                        run_inference)

CONTEXTS = ((200, 200), (4096, 12288), (8192, 24576))
CONFIGS = (
    ("I", 173.0, all_hbs()),
    ("II", 520.0, all_hbs()),
    ("III", 520.0, qkv_in_ddr()),
)


def run(emit) -> str:
    cfg = get_config("llava15-13b")
    kv33k = cfg.kv_bytes_per_token(2) * (8192 + 24576) / 1e9
    table = {}
    for label, ddr_bw, place in CONFIGS:
        for pf, dec in CONTEXTS:
            hier = npu_hierarchy(lpddr6(ddr_bw), hbs(512.0, latency_us=10.0),)
            rep = run_inference(cfg, hier, place, pf, dec, dtype_bytes=2,
                                n_samples=7)
            table[(label, pf)] = rep.tps
        pts = " ".join(f"{pf}+{dec}:{table[(label, pf)]:.2f}"
                       for pf, dec in CONTEXTS)
        emit(f"fig3.cfg{label}", 0.0, f"tps[{pts}]")
    mono = all(table[(lbl, 200)] >= table[(lbl, 4096)] >= table[(lbl, 8192)]
               for lbl, _, _ in CONFIGS)
    gains = [table[("III", pf)] / table[("I", pf)] for pf, _ in CONTEXTS]
    spread = max(gains) / min(gains)
    return (f"kv@33k={kv33k:.1f}GB(paper~27) monotonic={mono} "
            f"III/I_gain={gains[0]:.2f}/{gains[1]:.2f}/{gains[2]:.2f} "
            f"consistency={spread:.2f}x")
