"""Paper Table I: the four headline configurations for LLaVa-1.5-13B 200/200.

Derived: TPS + bottleneck per row and the relative gains vs row 1
(paper: ~4 / ~5.5 (1.4x) / ~8.9 (2.2x) / ~12.5 (3.1x)).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (all_hbs, hbs, lpddr6, npu_hierarchy, qkv_in_ddr,
                        run_inference)

PAPER = (4.0, 5.5, 8.9, 12.5)

ROWS = (
    ("lpddr6+hbs<=173.all-hbs", 173.0, 173.0, all_hbs()),
    ("lpddr6+hbs<=520.all-hbs", 173.0, 520.0, all_hbs()),
    ("3xddr+hbs<=520.all-hbs", 520.0, 512.0, all_hbs()),
    ("3xddr+hbs512.qkv-in-ddr", 520.0, 512.0, qkv_in_ddr()),
)


def compute_rows():
    cfg = get_config("llava15-13b")
    out = []
    for name, ddr_bw, hbs_bw, place in ROWS:
        hier = npu_hierarchy(lpddr6(ddr_bw), hbs(hbs_bw, latency_us=10.0))
        rep = run_inference(cfg, hier, place, 200, 200, dtype_bytes=2)
        out.append((name, rep.tps, rep.bottleneck))
    return out


def run(emit) -> str:
    rows = compute_rows()
    base = rows[0][1]
    gains = []
    for (name, tps, bott), paper_tps in zip(rows, PAPER):
        gain = tps / base
        gains.append(gain)
        emit(f"table1.{name}", 0.0,
             f"tps={tps:.2f} paper~{paper_tps} gain={gain:.2f}x bott={bott}")
    return (f"tps={rows[0][1]:.1f}/{rows[1][1]:.1f}/{rows[2][1]:.1f}/"
            f"{rows[3][1]:.1f} gains={gains[1]:.2f}/{gains[2]:.2f}/"
            f"{gains[3]:.2f} (paper 1.4/2.2/3.1)")
