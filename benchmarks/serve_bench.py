"""Serving smoke benchmark: continuous engine on a shared-document QA
workload, prefix cache on vs off (DESIGN.md SS11) plus a fused-decode
lookahead sweep (DESIGN.md SS12).

Emits the perf trajectory the CI tracks from PR 3 on: TPS, TTFT/ITL
percentiles, prefill tokens actually computed, jitted-prefill compile
count (fixed chunk shapes => 1), page dedup, and — from PR 4 — host sync
counts across decode-lookahead K in {1, 4, 8, 16}: the fused multi-step
decode should cut host round-trips by ~K at identical outputs.

Run: PYTHONPATH=src python benchmarks/serve_bench.py --json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, init_params


def run_workload(eng, reqs, new_tokens: int) -> tuple:
    """Returns (outputs of the timed pass, metrics dict) — greedy decode
    is deterministic, so callers reuse the outputs instead of
    re-serving."""
    eng.serve([r[:] for r in reqs], new_tokens)   # warm the jit caches
    eng.stats.__init__()
    outs = eng.serve([r[:] for r in reqs], new_tokens)
    s = eng.stats
    return outs, {
        "tps": round(s.tps, 2),
        "ttft_p50_ms": round(s.ttft_p50 * 1e3, 3),
        "ttft_p95_ms": round(s.ttft_p95 * 1e3, 3),
        "itl_p50_ms": round(s.itl_p50 * 1e3, 3),
        "itl_p95_ms": round(s.itl_p95 * 1e3, 3),
        "prefill_tokens_computed": s.prefill_tokens_computed,
        "cached_prefix_tokens": s.cached_prefix_tokens,
        "pages_deduped": s.pages_deduped,
        "cow_copies": s.cow_copies,
        "peak_pages_used": s.peak_pages_used,
        "prefill_recompiles": s.prefill_compiles,
        "decode_compiles": s.decode_compiles,
        "preemptions": s.preemptions,
        "decode_steps": s.decode_steps,
        "host_syncs": s.host_syncs,
    }


def main() -> None:
    import jax
    from repro.serving import ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, help="write results to this JSON file")
    ap.add_argument("--doc-len", type=int, default=48)
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lookahead", default="1,4,8,16",
                    help="comma-separated decode-lookahead K values to "
                         "sweep (fused multi-step decode)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=128, n_layers=4, vocab=512)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)
    doc = rng.integers(1, cfg.vocab, size=args.doc_len).tolist()
    reqs = [doc + rng.integers(1, cfg.vocab, size=8).tolist()
            for _ in range(args.n_requests)]
    max_len = args.doc_len + 8 + args.new_tokens + 16

    results = {"workload": {
        "arch": args.arch, "doc_len": args.doc_len,
        "n_requests": args.n_requests, "question_len": 8,
        "new_tokens": args.new_tokens}}
    outs = {}
    for key, pc in (("baseline_no_sharing", False), ("prefix_sharing", True)):
        eng = ServeEngine(cfg, params, opts, max_len=max_len,
                          scheduler="continuous", page_size=16, max_batch=8,
                          prefix_cache=pc)
        outs[pc], results[key] = run_workload(eng, reqs, args.new_tokens)

    base, shared = results["baseline_no_sharing"], results["prefix_sharing"]
    results["derived"] = {
        "outputs_token_identical": outs[False] == outs[True],
        "prefill_tokens_saved_frac": round(
            1 - shared["prefill_tokens_computed"]
            / max(base["prefill_tokens_computed"], 1), 3),
        "peak_pages_ratio": round(
            shared["peak_pages_used"] / max(base["peak_pages_used"], 1), 3),
    }

    # ---- fused-decode lookahead sweep (DESIGN.md SS12) ---- #
    # decode-bound variant of the workload: distinct prompts (no shared-
    # prefix deferral staggering the joins) and a prefill budget covering
    # every pending chunk, so all requests decode in lock-step and the
    # sweep isolates the per-token host round-trip the fused path removes.
    ks = [int(k) for k in args.lookahead.split(",") if k]
    d_reqs = [rng.integers(1, cfg.vocab, size=args.doc_len + 8).tolist()
              for _ in range(args.n_requests)]
    budget = args.n_requests * (args.doc_len + 8 + 32)
    sweep, k_outs = {}, {}
    for k in ks:
        eng = ServeEngine(cfg, params, opts, max_len=max_len,
                          scheduler="continuous", page_size=16, max_batch=8,
                          prefix_cache=True, decode_lookahead=k,
                          prefill_budget=budget)
        k_outs[k], sweep[str(k)] = run_workload(eng, d_reqs,
                                                args.new_tokens)
    results["lookahead_sweep"] = sweep
    if 1 in ks and 8 in ks:
        k1, k8 = sweep["1"], sweep["8"]
        results["derived"]["lookahead"] = {
            "outputs_token_identical_across_k": all(
                k_outs[k] == k_outs[ks[0]] for k in ks),
            "host_syncs_k1": k1["host_syncs"],
            "host_syncs_k8": k8["host_syncs"],
            "host_sync_reduction_k8_over_k1": round(
                1 - k8["host_syncs"] / max(k1["host_syncs"], 1), 3),
            "tps_speedup_k8_over_k1": round(k8["tps"] / max(k1["tps"],
                                                            1e-9), 3),
        }

    print(json.dumps(results, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {args.json}")


if __name__ == "__main__":
    main()
