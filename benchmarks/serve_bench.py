"""Serving smoke benchmark: continuous engine on a shared-document QA
workload, prefix cache on vs off (DESIGN.md SS11) plus a fused-decode
lookahead sweep (DESIGN.md SS12).

Emits the perf trajectory the CI tracks from PR 3 on: TPS, TTFT/ITL
percentiles, prefill tokens actually computed, jitted-prefill compile
count (fixed chunk shapes => 1), page dedup, and — from PR 4 — host sync
counts across decode-lookahead K in {1, 4, 8, 16}: the fused multi-step
decode should cut host round-trips by ~K at identical outputs.

Run: PYTHONPATH=src python benchmarks/serve_bench.py --json BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, init_params
from repro.serving.metrics import pct_ms

try:
    from benchmarks.common import merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from common import merge_bench_json


def run_workload(eng, reqs, new_tokens: int, *,
                 slo_ttft_s=None, slo_itl_s=None) -> tuple:
    """Returns (outputs of the timed pass, metrics dict) — greedy decode
    is deterministic, so callers reuse the outputs instead of
    re-serving. The metrics fold in the run's trace exports (SS15):
    aggregate phase breakdown, SLO goodput, per-request stall
    attribution, and the spec acceptance stats."""
    eng.serve([r[:] for r in reqs], new_tokens)   # warm the jit caches
    eng.stats.__init__()
    outs = eng.serve([r[:] for r in reqs], new_tokens)
    s = eng.stats
    tr = eng.trace
    return outs, {
        "tps": round(s.tps, 2),
        "ttft_p50_ms": pct_ms(s.ttft, 50),
        "ttft_p95_ms": pct_ms(s.ttft, 95),
        "itl_p50_ms": pct_ms(s.itl, 50),
        "itl_p95_ms": pct_ms(s.itl, 95),
        "prefill_tokens_computed": s.prefill_tokens_computed,
        "cached_prefix_tokens": s.cached_prefix_tokens,
        "pages_deduped": s.pages_deduped,
        "cow_copies": s.cow_copies,
        "peak_pages_used": s.peak_pages_used,
        "prefill_recompiles": s.prefill_compiles,
        "decode_compiles": s.decode_compiles,
        "preemptions": s.preemptions,
        "decode_steps": s.decode_steps,
        "host_syncs": s.host_syncs,
        # per-request attribution (SS15): residency stall by request id
        # and the draft acceptance counters, straight from ServeStats
        "stall_ms": round(s.stall_s * 1e3, 3),
        "stall_by_rid_ms": {str(rid): round(v * 1e3, 3)
                            for rid, v in sorted(s.stall_by_rid.items())},
        "spec": {
            "acceptance_rate": round(s.acceptance_rate, 3),
            "draft_proposed": s.draft_proposed,
            "draft_accepted": s.draft_accepted,
            "spec_blocks": s.spec_blocks,
        },
        # trace-derived sections (audited against the stats by reconcile)
        "breakdown_ms": tr.aggregate_breakdown_ms(),
        "goodput": tr.slo_report(slo_ttft_s, slo_itl_s),
        "trace_reconciled": bool(eng.trace_report
                                 and eng.trace_report["ok"]),
    }


def main() -> None:
    import jax
    from repro.serving import ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None,
                    help="merge results into this JSON file under the "
                         "'serve_bench' key")
    ap.add_argument("--doc-len", type=int, default=48)
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lookahead", default="1,4,8,16",
                    help="comma-separated decode-lookahead K values to "
                         "sweep (fused multi-step decode)")
    ap.add_argument("--trace-out", default=None,
                    help="write the prefix-sharing run's Chrome trace-"
                         "event JSON here (perfetto-loadable; the CI "
                         "artifact)")
    ap.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="TTFT target for the goodput report (reduced "
                         "CPU model: generous by default)")
    ap.add_argument("--slo-itl-ms", type=float, default=100.0,
                    help="per-request p95 ITL target for the goodput "
                         "report")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=128, n_layers=4, vocab=512)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)
    doc = rng.integers(1, cfg.vocab, size=args.doc_len).tolist()
    reqs = [doc + rng.integers(1, cfg.vocab, size=8).tolist()
            for _ in range(args.n_requests)]
    max_len = args.doc_len + 8 + args.new_tokens + 16

    slo = dict(slo_ttft_s=args.slo_ttft_ms * 1e-3,
               slo_itl_s=args.slo_itl_ms * 1e-3)
    results = {"workload": {
        "arch": args.arch, "doc_len": args.doc_len,
        "n_requests": args.n_requests, "question_len": 8,
        "new_tokens": args.new_tokens,
        "slo_ttft_ms": args.slo_ttft_ms, "slo_itl_ms": args.slo_itl_ms}}
    outs = {}
    for key, pc in (("baseline_no_sharing", False), ("prefix_sharing", True)):
        eng = ServeEngine(cfg, params, opts, max_len=max_len,
                          scheduler="continuous", page_size=16, max_batch=8,
                          prefix_cache=pc)
        outs[pc], results[key] = run_workload(eng, reqs, args.new_tokens,
                                              **slo)
        if pc and args.trace_out:
            eng.trace.save(args.trace_out)
            print(f"[serve_bench] wrote trace {args.trace_out}")

    base, shared = results["baseline_no_sharing"], results["prefix_sharing"]
    results["derived"] = {
        "outputs_token_identical": outs[False] == outs[True],
        "prefill_tokens_saved_frac": round(
            1 - shared["prefill_tokens_computed"]
            / max(base["prefill_tokens_computed"], 1), 3),
        "peak_pages_ratio": round(
            shared["peak_pages_used"] / max(base["peak_pages_used"], 1), 3),
    }

    # ---- fused-decode lookahead sweep (DESIGN.md SS12) ---- #
    # decode-bound variant of the workload: distinct prompts (no shared-
    # prefix deferral staggering the joins) and a prefill budget covering
    # every pending chunk, so all requests decode in lock-step and the
    # sweep isolates the per-token host round-trip the fused path removes.
    ks = [int(k) for k in args.lookahead.split(",") if k]
    d_reqs = [rng.integers(1, cfg.vocab, size=args.doc_len + 8).tolist()
              for _ in range(args.n_requests)]
    budget = args.n_requests * (args.doc_len + 8 + 32)
    sweep, k_outs = {}, {}
    for k in ks:
        eng = ServeEngine(cfg, params, opts, max_len=max_len,
                          scheduler="continuous", page_size=16, max_batch=8,
                          prefix_cache=True, decode_lookahead=k,
                          prefill_budget=budget)
        k_outs[k], sweep[str(k)] = run_workload(eng, d_reqs,
                                                args.new_tokens, **slo)
    results["lookahead_sweep"] = sweep
    if 1 in ks and 8 in ks:
        k1, k8 = sweep["1"], sweep["8"]
        results["derived"]["lookahead"] = {
            "outputs_token_identical_across_k": all(
                k_outs[k] == k_outs[ks[0]] for k in ks),
            "host_syncs_k1": k1["host_syncs"],
            "host_syncs_k8": k8["host_syncs"],
            "host_sync_reduction_k8_over_k1": round(
                1 - k8["host_syncs"] / max(k1["host_syncs"], 1), 3),
            "tps_speedup_k8_over_k1": round(k8["tps"] / max(k1["tps"],
                                                            1e-9), 3),
        }

    print(json.dumps(results, indent=2))
    if args.json:
        merge_bench_json(args.json, "serve_bench", results)
        print(f"[serve_bench] merged into {args.json}")


if __name__ == "__main__":
    main()
