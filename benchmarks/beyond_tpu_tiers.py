"""BEYOND-PAPER: the paper's STCO methodology retargeted at a TPU serving
node — "HBS" becomes host-DRAM offload over PCIe, the "chiplet" becomes
keeping the decode working set effectively faster via int8 KV.

Question answered (paper Sec. III style): for each pool architecture at
32k context, which KV-cache tier assignment sustains 10 TPS/request at
batch 1 on ONE v5e chip, and where does host-offloaded KV break down?

Tiers modeled with the SAME hierarchical-roofline engine as the paper's
NPU study: vmem(128MB) - HBM(819GB/s, 16GB) - host DRAM over PCIe Gen4
(~24 GB/s effective, ~5 us) as the capacity tier.
"""
from __future__ import annotations

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.core import (MemoryHierarchy, MemoryLevel, make_placement,
                        run_inference)
from repro.core.memspec import GB, MB, US, ComputeSpec


def tpu_serving_hierarchy(host_bw_gbps: float = 24.0,
                          host_lat_us: float = 5.0) -> MemoryHierarchy:
    chain = (
        MemoryLevel("vmem", capacity=128 * MB, bandwidth=40e12, latency=0.0),
        MemoryLevel("l2", capacity=128 * MB, bandwidth=20e12, latency=0.0),
        # "ddr" slot = HBM on this node; "hbs" slot = host DRAM over PCIe
        MemoryLevel("ddr", capacity=16 * GB, bandwidth=819e9, latency=0.4e-6),
        MemoryLevel("hbs", capacity=512 * GB, bandwidth=host_bw_gbps * GB,
                    latency=host_lat_us * US),
    )
    return MemoryHierarchy(compute=ComputeSpec("tpu-v5e", flops=197e12),
                           chain=chain)


PLACEMENTS = (
    ("all-hbm", make_placement("all-hbm", "ddr")),
    ("kv-host-offload", make_placement("kv-host", "ddr", kv="hbs")),
    ("weights-host-kv-hbm", make_placement("w-host", "ddr",
                                           w_attn="hbs", w_mlp="hbs",
                                           w_moe="hbs", w_emb="hbs")),
)


def run(emit) -> str:
    met = 0
    total = 0
    for arch in ASSIGNED_ARCHS + PAPER_ARCHS:
        cfg = get_config(arch)
        hier = tpu_serving_hierarchy()
        results = []
        for label, place in PLACEMENTS:
            # feasibility: HBM-resident classes must fit 16 GB
            weights = cfg.n_params() * 2
            kv = cfg.kv_bytes_per_token(2) * 33000
            hbm_need = 0.0
            if label == "all-hbm":
                hbm_need = weights + kv
            elif label == "kv-host-offload":
                hbm_need = weights
            else:
                hbm_need = kv
            if hbm_need > 16 * GB:
                results.append(f"{label}:DOES-NOT-FIT")
                continue
            rep = run_inference(cfg, hier, place, 512, 512, n_samples=5)
            results.append(f"{label}:{rep.tps:.1f}tps/{rep.bottleneck}")
            total += 1
            if rep.tps >= 10.0:
                met += 1
        emit(f"beyond.tpu_tiers.{arch}", 0.0, " ".join(results))
    return (f"{met}/{total} feasible (arch,placement) pairs meet 10 TPS; "
            "host-offloaded KV is PCIe-bound exactly like the paper's "
            "HBS-bound regime (takeaway I analogue)")
