"""Paper Fig. 2: (a) TPS vs HBS bw at 10us for configs I/II/III;
(b) per-GEMM time breakdown for HBS latency 10us vs 50us at 512 GB/s.

Derived: attention share of total GEMM time (paper: 31-69 % for 10-50 us).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (all_hbs, hbs, lpddr6, npu_hierarchy, qkv_in_ddr,
                        run_inference)

HBS_BWS = (16, 64, 128, 173, 256, 384, 512)

CONFIGS = (
    ("I", 173.0, all_hbs()),
    ("II", 520.0, all_hbs()),
    ("III", 520.0, qkv_in_ddr()),
)


def run(emit) -> str:
    cfg = get_config("llava15-13b")
    for label, ddr_bw, place in CONFIGS:
        pts = []
        for bw in HBS_BWS:
            hier = npu_hierarchy(lpddr6(ddr_bw), hbs(bw, latency_us=10.0))
            rep = run_inference(cfg, hier, place, 200, 200, dtype_bytes=2)
            pts.append(f"{bw}:{rep.tps:.2f}")
        emit(f"fig2a.cfg{label}", 0.0, "tps[" + " ".join(pts) + "]")

    # (b) per-layer GEMM breakdown at 512 GB/s for two latencies
    shares = []
    for lat in (10.0, 50.0):
        hier = npu_hierarchy(lpddr6(520.0), hbs(512.0, latency_us=lat))
        rep = run_inference(cfg, hier, all_hbs(), 200, 200, dtype_bytes=2)
        mid = rep.decode_samples[len(rep.decode_samples) // 2][1]
        per_layer = {g: t / cfg.n_layers * 1e3 for g, t in mid.by_group.items()
                     if g != "elem"}
        emit(f"fig2b.lat{lat:g}us", 0.0,
             "ms/layer[" + " ".join(f"{g}:{v:.3f}" for g, v in
                                    sorted(per_layer.items())) + "]")
        lo, hi = rep.decode_group_share("attn")
        shares.append(hi)
    return (f"attn_share@10us={shares[0]*100:.0f}% @50us={shares[1]*100:.0f}% "
            f"(paper 31-69%)")
