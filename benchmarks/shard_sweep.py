"""Multi-device sharded serving sweep (DESIGN.md SS16).

Three questions, answered on the CPU rig (host devices via
``--xla_force_host_platform_device_count``) with the reduced dense twin:

* **overlap** — does the two-stream engine (prefill worker + decode
  worker on the virtual clock) beat the serialized loop on a mixed
  prefill+decode workload? Gate: overlapped TPS > serialized TPS, token
  identity across both.
* **mesh** — token identity of the head-sharded engine across mesh sizes
  {1, 2, 4}, plus per-mesh makespan/TPS, and the per-device analytic
  bridge: ``concurrent_inference(kv_shards=N)`` at full 13B scale shows
  the per-chip KV footprint shrinking with N (the paper's memory
  constraint is per chip).
* **capacity** — a per-device tier budget admits what one device cannot:
  a working set the single-device pool rejects outright serves
  token-identically on the 4-way mesh, and a concurrent workload that
  forces preemptions at N=1 runs preemption-free at N=4.

Run: PYTHONPATH=src python benchmarks/shard_sweep.py --json
(merges its section into BENCH_serve.json next to the other serving
benchmarks). The device-count flag must land before jax initializes, so
this module prepends it to XLA_FLAGS at import.
"""
from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

import numpy as np

try:
    from benchmarks.common import merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from common import merge_bench_json


def _model(args):
    import jax
    from repro.configs import get_config
    from repro.configs.reduce import reduced
    from repro.models import RuntimeOptions, init_params

    cfg = dataclasses.replace(
        reduced(get_config("llama3.2-1b"), d_model=args.d_model,
                n_layers=2, vocab=128),
        n_kv_heads=4)                    # divisible by meshes {1, 2, 4}
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def _workload(cfg, args):
    """Mixed prefill+decode stream: ragged prompts, more requests than
    slots, so admissions keep prefilling while earlier requests decode —
    the regime where the two streams actually overlap."""
    rng = np.random.default_rng(0)
    lens = [args.prompt_len if i % 2 == 0 else max(args.prompt_len // 3, 4)
            for i in range(args.n_requests)]
    return [rng.integers(1, cfg.vocab, size=n).tolist() for n in lens]


def _run(cfg, params, opts, reqs, args, **kw):
    from repro.serving import ServeEngine

    common = dict(max_len=args.prompt_len + args.new_tokens,
                  scheduler="continuous", page_size=args.page_size,
                  max_batch=args.max_batch)
    common.update(kw)
    eng = ServeEngine(cfg, params, opts, **common)
    eng.serve([r[:] for r in reqs], args.new_tokens)       # warm jit
    eng.stats.__init__()
    outs = eng.serve([r[:] for r in reqs], args.new_tokens)
    return eng, outs


def _cell(eng):
    s = eng.stats
    return {"tps": round(s.tps, 2),
            "serve_ms": round(s.serve_s * 1e3, 3),
            "prefill_ms": round(s.prefill_s * 1e3, 3),
            "decode_ms": round(s.decode_s * 1e3, 3),
            "preemptions": s.preemptions,
            "peak_fast_pages": s.peak_fast_pages}


def overlap_section(cfg, params, opts, reqs, args, want) -> dict:
    over, o_outs = _run(cfg, params, opts, reqs, args, overlap=True)
    ser, s_outs = _run(cfg, params, opts, reqs, args, overlap=False)
    o, s = _cell(over), _cell(ser)
    return {
        "overlapped": o, "serialized": s,
        "token_identical": o_outs == want and s_outs == want,
        "speedup": round(s["serve_ms"] / max(o["serve_ms"], 1e-9), 3),
        "overlap_beats_serialized": o["tps"] > s["tps"],
    }


def mesh_section(cfg, params, opts, reqs, args, want) -> dict:
    import jax

    n_dev = len(jax.devices())
    cells = {}
    for shards in (1, 2, 4):
        if shards > n_dev or cfg.n_kv_heads % shards:
            continue
        eng, outs = _run(cfg, params, opts, reqs, args, shards=shards)
        cells[f"mesh{shards}"] = dict(_cell(eng),
                                      token_identical=outs == want)

    # per-device analytic bridge at FULL 13B scale: each chip holds 1/N
    # of the paged KV, so the per-chip footprint (the paper's constraint)
    # shrinks with the mesh while weights/activations replicate
    from repro.configs import get_config
    from repro.core import (TC, concurrent_inference, ddr_only, hbs,
                            lpddr6, npu_hierarchy, resident_bytes)
    big = get_config("llava15-13b")
    hier = npu_hierarchy(lpddr6(520.0, capacity_gb=32.0),
                         hbs(8.0, latency_us=20.0, capacity_gb=64.0))
    analytic = {}
    for n in (1, 2, 4):
        pt = concurrent_inference(big, hier, ddr_only(), n_concurrent=4,
                                  prefill_len=4096, decode_len=256,
                                  dtype_bytes=2, kv_shards=n)
        fp = resident_bytes(big, 4096 + 256, 4, 2)
        analytic[f"kv_shards{n}"] = {
            "kv_gb_per_chip": round(fp[TC.KV] / n / 1e9, 3),
            "aggregate_tps": round(pt.aggregate_tps, 3),
        }
    kv1 = analytic["kv_shards1"]["kv_gb_per_chip"]
    kv4 = analytic["kv_shards4"]["kv_gb_per_chip"]
    return {"n_devices": n_dev, "cells": cells,
            "all_token_identical": all(c["token_identical"]
                                       for c in cells.values()),
            "analytic_13b_per_chip": analytic,
            "per_chip_kv_shrinks": kv4 < kv1}


def capacity_section(cfg, params, opts, args, want_fn) -> dict:
    import jax
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    n_dev = len(jax.devices())
    ps = args.page_size
    pb = page_bytes(cfg, ps, 4)
    rng = np.random.default_rng(7)

    # (a) reject vs serve: one long request whose KV exceeds the WHOLE
    # single-device hierarchy but fits the 4-way per-device slices
    long_req = rng.integers(1, cfg.vocab,
                            size=3 * ps).tolist()          # 4 pages + new
    tight = npu_hierarchy(lpddr6(capacity_gb=1.5 * pb / 1e9),
                          hbs(1e3, latency_us=0.0,
                              capacity_gb=2.5 * pb / 1e9))
    single_rejects = False
    try:
        _run(cfg, params, opts, [long_req], args, hierarchy=tight)
    except ValueError as e:
        single_rejects = "across all" in str(e)
    out = {"single_device_rejects": single_rejects}
    if n_dev >= 4:
        want = want_fn([long_req])
        eng4, outs4 = _run(cfg, params, opts, [long_req], args,
                           hierarchy=tight, shards=4)
        out["mesh4_serves_token_identical"] = outs4 == want
        out["mesh4_peak_fast_pages"] = eng4.stats.peak_fast_pages

    # (b) concurrency under pressure: each request fits alone, but the
    # JOINT working set exceeds the N=1 pool — the scheduler can only run
    # the mix by preempting. The 4-way per-device budget holds 4x the
    # pages, so the same mix runs fully resident, preemption-free.
    conc = [rng.integers(1, cfg.vocab, size=2 * ps).tolist()
            for _ in range(4)]
    need = sum(-(-(len(r) + args.new_tokens) // ps) for r in conc)
    tight2 = npu_hierarchy(
        lpddr6(capacity_gb=(need // 4 + 0.5) * pb / 1e9),
        hbs(1e3, latency_us=0.0,
            capacity_gb=(need // 2 - need // 4 + 0.5) * pb / 1e9))
    want = want_fn(conc)
    eng1, outs1 = _run(cfg, params, opts, conc, args, hierarchy=tight2,
                       max_batch=4)
    out["n1"] = dict(_cell(eng1), token_identical=outs1 == want)
    if n_dev >= 4:
        eng4, outs4 = _run(cfg, params, opts, conc, args, hierarchy=tight2,
                           max_batch=4, shards=4)
        out["n4"] = dict(_cell(eng4), token_identical=outs4 == want)
        out["mesh_relieves_pressure"] = (
            eng1.stats.preemptions > 0
            and eng4.stats.preemptions == 0)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None,
                    help="merge results into this JSON file under the "
                         "'shard_sweep' key")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=3)
    args = ap.parse_args()

    cfg, opts, params = _model(args)
    reqs = _workload(cfg, args)

    # token-identity reference: the plain single-device overlapped engine
    def want_fn(rs):
        _, outs = _run(cfg, params, opts, rs, args)
        return outs

    want = want_fn(reqs)
    results = {
        "workload": {
            "arch": cfg.name, "n_requests": len(reqs),
            "prompt_lens": sorted({len(r) for r in reqs}),
            "new_tokens": args.new_tokens,
            "max_batch": args.max_batch, "page_size": args.page_size,
        },
        "overlap": overlap_section(cfg, params, opts, reqs, args, want),
        "mesh": mesh_section(cfg, params, opts, reqs, args, want),
        "capacity": capacity_section(cfg, params, opts, args, want_fn),
    }
    print(json.dumps(results, indent=2))
    if args.json:
        merge_bench_json(args.json, "shard_sweep", results)
        print(f"[shard_sweep] merged into {args.json}")
    gates = (results["overlap"]["overlap_beats_serialized"],
             results["overlap"]["token_identical"],
             results["mesh"]["all_token_identical"],
             results["capacity"]["single_device_rejects"])
    print(f"[shard_sweep] gates: overlap_beats_serialized={gates[0]} "
          f"token_identical={gates[1]} mesh_identical={gates[2]} "
          f"per_device_budget={gates[3]}")


if __name__ == "__main__":
    main()
