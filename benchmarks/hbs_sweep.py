"""HBS interactivity sweep: the paper's requirement table, both halves
(DESIGN.md SS13).

The paper's headline large-model scenario is a 13B-class model whose
long-context KV spills past the fast tiers into High Bandwidth Storage;
the question it answers is what bandwidth/latency envelope HBS must hit
for decode to stay interactive. This benchmark reproduces that table
twice over a bandwidth x latency grid:

* **analytic_13b** — the hierarchical roofline model at FULL llava1.5-13B
  scale and long context (`core.concurrency.hbs_interactivity_sweep`):
  predicted TPS, per-token ITL and KV spill fraction per (GB/s, µs) cell,
  plus the minimum-bandwidth requirement readout per ITL target — and the
  spec-compounded variant of that readout (DESIGN.md SS14): with
  speculative decoding landing E(alpha, k) tokens per streaming pass, the
  same target is met at lower HBS bandwidth.
* **measured_reduced** — the real serve engine on a reduced dense twin of
  the same config, with per-page tier residency and the
  ``SimulatedTierDevice`` charging migrations over the same grid: TPS,
  ITL p50/p95, recorded decode stall, spill/fetch traffic and prefetch
  hit rate. At generous bandwidth the offload path must be
  token-identical to the no-offload path with zero recorded stall — the
  acceptance gate — and the runtime-observed ``kv_split_at_peak`` is
  pinned back into ``concurrent_inference`` (predicted_from_runtime_split)
  to close the predicted-vs-measured loop.

Run: PYTHONPATH=src python benchmarks/hbs_sweep.py --json
(merges its section into BENCH_serve.json next to serve_bench's).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced

try:
    from benchmarks.common import goodput_summary, merge_bench_json
except ImportError:                      # run as a script from benchmarks/
    from common import goodput_summary, merge_bench_json

# generous-bandwidth grid point: transfers complete in sub-µs virtual
# time, so recorded stall must round to zero and prefetch always wins
GENEROUS_GBPS = 1e6


def analytic_section(args) -> dict:
    from repro.core import (TC, ddr_only, hbs, hbs_interactivity_sweep,
                            lpddr6, min_hbs_bandwidth_for_itl,
                            npu_hierarchy, resident_bytes)

    cfg = get_config("llava15-13b")
    # DDR sized so the FP16 weights fit but the long-context KV does not.
    # capacity_aware alone would keep the (largest-class) KV on DDR and
    # stream the WEIGHTS from HBS instead; the paper's regime is the
    # opposite — weights stay hot on DDR, the KV overflow spills — so pin
    # the KV split explicitly: fast share = whatever DDR has left after
    # the non-KV residents, remainder on HBS.
    ddr_gb = 32.0
    hier = npu_hierarchy(lpddr6(520.0, capacity_gb=ddr_gb),
                         hbs(8.0, latency_us=20.0))
    fp = resident_bytes(cfg, args.context + 256, 1, 2)
    kv_bytes = fp[TC.KV]
    non_kv = sum(v for c, v in fp.items() if c != TC.KV)
    kv_fast = min(max(ddr_gb * 1e9 - non_kv, 0.0) / kv_bytes, 1.0)
    kv_split = ((("ddr", kv_fast),) if kv_fast >= 1.0 else
                (("ddr", kv_fast), ("hbs", 1.0 - kv_fast)) if kv_fast > 0
                else (("hbs", 1.0),))
    bw = [float(x) for x in args.bw_gbps.split(",")]
    lat = [float(x) for x in args.latency_us.split(",")]
    grid = hbs_interactivity_sweep(cfg, hier, ddr_only(),
                                   bw_gbps=bw, latency_us=lat,
                                   prefill_len=args.context,
                                   decode_len=256, dtype_bytes=2,
                                   kv_split=kv_split)
    cells = [{
        "bw_gbps": g.bw_gbps,
        "latency_us": g.latency_us,
        "tps": round(g.tps, 3),
        "itl_ms": round(g.itl_s * 1e3, 3),
        "kv_spill_frac": round(g.kv_spill_frac, 3),
        "bottleneck": g.point.bottleneck,
    } for g in grid]
    req = {f"itl<={int(t * 1e3)}ms":
           {f"{lat_us:g}us": (bw_min if bw_min != float("inf") else None)
            for lat_us, bw_min in
            min_hbs_bandwidth_for_itl(grid, t).items()}
           for t in (0.05, 0.25, 1.0)}
    # spec-compounded envelope (DESIGN.md SS14): every verify pass streams
    # the spilled KV once but lands E(alpha, k) tokens, so the SAME ITL
    # target is met at LOWER HBS bandwidth — the two techniques compound
    from repro.core import expected_tokens_per_pass
    e_tok = expected_tokens_per_pass(args.spec_alpha, args.spec_k)
    req_spec = {f"itl<={int(t * 1e3)}ms":
                {f"{lat_us:g}us": (bw_min if bw_min != float("inf")
                                   else None)
                 for lat_us, bw_min in
                 min_hbs_bandwidth_for_itl(
                     grid, t, tokens_per_pass=e_tok).items()}
                for t in (0.05, 0.25, 1.0)}
    shifts_down = any(
        (req[k][c] or float("inf")) > (req_spec[k][c] or float("inf"))
        for k in req for c in req[k]
        if req[k][c] is not None or req_spec[k][c] is not None)
    return {"arch": cfg.name, "context": args.context,
            "kv_gb": round(kv_bytes / 1e9, 2),
            "kv_fast_frac": round(kv_fast, 4),
            "grid": cells, "min_bw_gbps_for_target": req,
            "spec_compounded": {
                "alpha": args.spec_alpha, "k": args.spec_k,
                "tokens_per_pass": round(e_tok, 3),
                "min_bw_gbps_for_target": req_spec,
                "envelope_shifts_down": shifts_down,
            }}


def measured_section(args) -> dict:
    import jax
    from repro.core import concurrent_inference, ddr_only, hbs, lpddr6, \
        npu_hierarchy
    from repro.models import RuntimeOptions, init_params
    from repro.serving import ServeEngine
    from repro.serving.kv_manager import page_bytes

    # reduced dense twin of the 13B config: same family of shapes the
    # paper models, shrunk so the CPU engine can sweep the grid
    cfg = dataclasses.replace(
        reduced(get_config("llava15-13b"), d_model=128, n_layers=4,
                vocab=512),
        family="dense", prefix_len=0, source_len=0,
        name="llava15-13b-reduced-dense")
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    page_size = 16
    pb = page_bytes(cfg, page_size, 4)

    rng = np.random.default_rng(0)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (args.prompt_len, args.prompt_len,
                      args.prompt_len // 2, args.prompt_len // 2)]
    max_len = args.prompt_len + args.new_tokens
    common = dict(max_len=max_len, scheduler="continuous",
                  page_size=page_size, max_batch=4, prefix_cache=True)

    # no-offload baseline: the token-identity reference
    base = ServeEngine(cfg, params, opts, **common)
    base.serve([r[:] for r in reqs], args.new_tokens)      # warm jit
    base.stats.__init__()
    want = base.serve([r[:] for r in reqs], args.new_tokens)

    # fast tier holds ~1/3 of the aggregate KV; the rest lives in HBS
    total_pages = sum(-(-(len(r) + args.new_tokens) // page_size)
                      for r in reqs)
    fast_pages = max(total_pages // 3, 2)
    hier = npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                         hbs(8.0, latency_us=20.0, capacity_gb=1.0))

    bw_grid = [float(x) for x in args.measured_bw_gbps.split(",")]
    bw_grid.append(GENEROUS_GBPS)
    lat_grid = [float(x) for x in args.measured_latency_us.split(",")]
    cells = []
    for bw in bw_grid:
        for lat in ([0.0] if bw == GENEROUS_GBPS else lat_grid):
            eng = ServeEngine(cfg, params, opts, **common, hierarchy=hier,
                              hbs_gbps=bw, hbs_latency_us=lat)
            eng.serve([r[:] for r in reqs], args.new_tokens)  # warm jit
            eng.stats.__init__()
            outs = eng.serve([r[:] for r in reqs], args.new_tokens)
            s = eng.stats
            slo = eng.trace.slo_report(args.slo_ttft_ms * 1e-3,
                                       args.slo_itl_ms * 1e-3)
            cells.append({
                "bw_gbps": bw, "latency_us": lat,
                "tps": round(s.tps, 2),
                "itl_p50_ms": round(s.itl_p50 * 1e3, 3),
                "itl_p95_ms": round(s.itl_p95 * 1e3, 3),
                "stall_ms": round(s.stall_s * 1e3, 3),
                "stall_by_rid_ms": {
                    str(rid): round(v * 1e3, 3)
                    for rid, v in sorted(s.stall_by_rid.items())},
                "spill_mb": round(s.spill_bytes / 1e6, 3),
                "fetch_mb": round(s.fetch_bytes / 1e6, 3),
                "prefetch_hit_rate": round(s.prefetch_hit_rate, 3),
                "peak_fast_pages": s.peak_fast_pages,
                "preemptions": s.preemptions,
                "token_identical": outs == want,
                "kv_split_at_peak": [[t, round(f, 4)]
                                     for t, f in s.kv_split_at_peak],
                # trace-derived (SS15): where each cell's time went, and
                # goodput vs the SLO targets with per-phase blame
                "breakdown_ms": eng.trace.aggregate_breakdown_ms(),
                "goodput": goodput_summary(slo),
            })

    generous = [c for c in cells if c["bw_gbps"] == GENEROUS_GBPS][0]
    stingiest = min(cells, key=lambda c: (c["bw_gbps"], -c["latency_us"]))
    # close the loop: pin the runtime-observed split into the analytical
    # model (the reduced hierarchy prices it; TPS>0 proves the bridge)
    bridge = None
    if generous["kv_split_at_peak"]:
        split = tuple((t, f) for t, f in generous["kv_split_at_peak"])
        pt = concurrent_inference(cfg, hier, ddr_only(),
                                  n_concurrent=len(reqs),
                                  prefill_len=args.prompt_len,
                                  decode_len=args.new_tokens,
                                  dtype_bytes=4, kv_split=split)
        bridge = {"kv_split": generous["kv_split_at_peak"],
                  "predicted_tps": round(pt.aggregate_tps, 3)}
    return {
        "arch": cfg.name, "n_requests": len(reqs),
        "prompt_len": args.prompt_len, "new_tokens": args.new_tokens,
        "fast_pages": fast_pages, "page_kb": round(pb / 1e3, 2),
        "slo_ttft_ms": args.slo_ttft_ms, "slo_itl_ms": args.slo_itl_ms,
        "grid": cells,
        "derived": {
            "goodput_generous": generous["goodput"]["goodput_frac"],
            "goodput_stingiest": stingiest["goodput"]["goodput_frac"],
            "generous_token_identical": generous["token_identical"],
            "generous_stall_ms": generous["stall_ms"],
            "all_token_identical": all(c["token_identical"] for c in cells),
            "stall_grows_as_bw_shrinks":
                stingiest["stall_ms"] > generous["stall_ms"],
            "predicted_from_runtime_split": bridge,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None,
                    help="merge results into this JSON file under the "
                         "'hbs_sweep' key")
    ap.add_argument("--context", type=int, default=16384,
                    help="analytic long-context prefill length")
    ap.add_argument("--bw-gbps", default="2,8,32,128,520",
                    help="analytic HBS bandwidth grid (GB/s)")
    ap.add_argument("--latency-us", default="5,20,80",
                    help="analytic HBS latency grid (µs)")
    ap.add_argument("--measured-bw-gbps", default="0.002,0.02,0.2",
                    help="measured-engine HBS bandwidth grid (GB/s; a "
                         "generous point is appended automatically)")
    ap.add_argument("--measured-latency-us", default="20,2000",
                    help="measured-engine HBS latency grid (µs)")
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--spec-alpha", type=float, default=0.7,
                    help="assumed per-position draft acceptance for the "
                         "spec-compounded analytic envelope (spec_sweep.py "
                         "measures the real rate)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft length for the spec-compounded envelope")
    ap.add_argument("--slo-ttft-ms", type=float, default=2000.0,
                    help="TTFT target for the per-cell goodput report")
    ap.add_argument("--slo-itl-ms", type=float, default=100.0,
                    help="per-request p95 ITL target for the per-cell "
                         "goodput report")
    args = ap.parse_args()

    results = {"analytic_13b": analytic_section(args),
               "measured_reduced": measured_section(args)}
    print(json.dumps(results, indent=2))
    if args.json:
        merge_bench_json(args.json, "hbs_sweep", results)
        print(f"[hbs_sweep] merged into {args.json}")


if __name__ == "__main__":
    main()
