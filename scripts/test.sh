#!/usr/bin/env bash
# Tier-1 test invocation (CPU). Usage: scripts/test.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# CPU XLA setup (see SNIPPETS.md): single host device; JAX stays off any
# accelerator so Pallas kernels run through interpret mode.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="--xla_force_host_platform_device_count=1 ${XLA_FLAGS:-}"

exec python -m pytest -x -q -m "not slow" "$@"
