#!/usr/bin/env bash
# Tier-1 test invocation (CPU). Usage: scripts/test.sh [extra pytest args]
#
# Lanes: the default (fast) lane skips tests marked `slow` — the heavy
# engine/serve end-to-end equivalence runs — for a quick signal;
# TEST_LANE=full runs everything, matching the ROADMAP tier-1 verify
# (`python -m pytest -x -q`). CI runs both lanes in parallel.
set -euo pipefail
cd "$(dirname "$0")/.."

# CPU XLA setup (see SNIPPETS.md): single host device; JAX stays off any
# accelerator so Pallas kernels run through interpret mode.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="--xla_force_host_platform_device_count=1 ${XLA_FLAGS:-}"

# static gates first: repro-lint (always — stdlib only) and ruff
# (when installed; requirements-dev has it, the bare image may not)
python scripts/analyze.py
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests scripts benchmarks
fi

if [ "${TEST_LANE:-fast}" = "full" ]; then
    exec python -m pytest -x -q "$@"
fi
exec python -m pytest -x -q -m "not slow" "$@"
