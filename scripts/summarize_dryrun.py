"""Summarize artifacts/dryrun/*.json into the SSDry-run / SSRoofline tables."""
import json
import pathlib
import sys

ART = pathlib.Path("artifacts/dryrun")

rows = []
for p in sorted(ART.glob("*.json")):
    r = json.loads(p.read_text())
    if r.get("tag"):
        continue
    rows.append(r)

def fmt(x, d=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) < 1e-3 or abs(x) >= 1e4:
        return f"{x:.1e}"
    return f"{x:.{d}f}"

print("| arch | shape | mesh | status | peak GiB/dev | compute_s | memory_s "
      "| collective_s | bottleneck | useful-FLOPs | roofline-frac |")
print("|---|---|---|---|---|---|---|---|---|---|---|")
for r in rows:
    mesh = r["mesh"].replace("pod", "")
    if "skipped" in r:
        print(f"| {r['arch']} | {r['shape']} | {mesh} | SKIP | - | - | - | - "
              f"| - | - | - |")
        continue
    if "error" in r:
        print(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | - | - | - | -"
              f" | - | - | - |")
        continue
    ro = r["roofline"]
    peak = r["memory"]["peak_bytes"] / 2**30
    print(f"| {r['arch']} | {r['shape']} | {mesh} | ok | {peak:.2f} "
          f"| {fmt(ro['compute_s'])} | {fmt(ro['memory_s'])} "
          f"| {fmt(ro['collective_s'])} | {ro['bottleneck']} "
          f"| {fmt(ro['model_flops_ratio'])} "
          f"| {fmt(ro['roofline_fraction'])} |")

# quick picks for the hillclimb
single = [r for r in rows if r["mesh"] == "pod16x16" and "roofline" in r]
by_frac = sorted(single, key=lambda r: r["roofline"]["roofline_fraction"])
coll = sorted((r for r in single if r["roofline"]["bottleneck"] == "collective"),
              key=lambda r: -r["roofline"]["collective_s"])
print("\nWorst roofline fraction (single-pod):", file=sys.stderr)
for r in by_frac[:6]:
    print(f"  {r['arch']} x {r['shape']}: frac="
          f"{r['roofline']['roofline_fraction']:.4f} "
          f"bott={r['roofline']['bottleneck']}", file=sys.stderr)
print("Most collective-bound:", file=sys.stderr)
for r in coll[:6]:
    print(f"  {r['arch']} x {r['shape']}: coll_s="
          f"{r['roofline']['collective_s']:.3f}", file=sys.stderr)
