#!/usr/bin/env python
"""repro-lint driver: run the static-analysis pass over src/repro.

Usage:
    PYTHONPATH=src python scripts/analyze.py [--json OUT] \
        [--baseline analysis_baseline.json] [--src src] [--write-baseline]

Exit codes: 0 clean (or every finding baselined), 1 new findings or a
malformed baseline, 2 usage/internal error. ``--json`` writes the full
machine-readable report (findings, baseline status, per-rule counts) —
CI uploads it as an artifact next to BENCH_serve.json.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import load_project, run_checkers          # noqa: E402
from repro.analysis.core import (apply_baseline, load_baseline,  # noqa: E402
                                 write_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--src", default=str(REPO / "src"),
                    help="source root holding the repro package")
    ap.add_argument("--baseline",
                    default=str(REPO / "analysis_baseline.json"),
                    help="committed baseline of grandfathered findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings "
                         "(entries still need hand-written "
                         "justifications)")
    args = ap.parse_args(argv)

    project = load_project(Path(args.src))
    findings = run_checkers(project)

    if args.write_baseline:
        write_baseline(Path(args.baseline), findings,
                       justification="TODO: justify or fix")
        print(f"wrote {len(findings)} finding(s) to {args.baseline}; "
              f"every entry needs a real justification before it lands")
        return 0

    try:
        baseline = load_baseline(Path(args.baseline))
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    new, stale = apply_baseline(findings, baseline)

    if args.json_out:
        doc = {
            "findings": [f.to_dict() for f in findings],
            "new": [f.to_dict() for f in new],
            "stale_baseline": stale,
            "by_rule": dict(Counter(f.rule for f in findings)),
            "modules_analyzed": len(project.modules),
            "baseline_entries": len(baseline),
        }
        Path(args.json_out).write_text(json.dumps(doc, indent=2) + "\n")

    for f in new:
        print(f.render())
    for e in stale:
        print(f"warning: stale baseline entry {e.get('fingerprint')} "
              f"({e.get('rule')} {e.get('path')}): no longer fires — "
              f"remove it", file=sys.stderr)
    n_grandfathered = len(findings) - len(new)
    print(f"repro-lint: {len(project.modules)} modules, "
          f"{len(findings)} finding(s) "
          f"({len(new)} new, {n_grandfathered} baselined, "
          f"{len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'})")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
