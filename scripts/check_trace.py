#!/usr/bin/env python
"""CI smoke check for a serve trace artifact (DESIGN.md SS15).

Loads a Chrome trace-event JSON produced by ``--trace-out`` (launch CLI
or ``benchmarks/serve_bench.py``) and verifies it parses as valid Chrome
trace-event format — the same structural validation the golden-trace
test applies — plus the breakdown metadata's conservation invariant
(per-request phase sums equal end-to-end latency).

Usage: PYTHONPATH=src python scripts/check_trace.py trace.json
"""
from __future__ import annotations

import json
import sys

from repro.serving.trace import PHASES, validate_chrome_trace


def main(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    counts = validate_chrome_trace(doc)
    breakdowns = doc.get("metadata", {}).get("breakdowns", {})
    worst = 0.0
    for rid, bd in breakdowns.items():
        parts = sum(bd[f"{p}_s"] for p in PHASES)
        err = abs(parts - bd["e2e_s"])
        worst = max(worst, err)
        if err > 1e-6:
            print(f"[check_trace] FAIL: request {rid} phase sum {parts} "
                  f"!= e2e {bd['e2e_s']}")
            return 1
    print(f"[check_trace] OK: {path} — {counts['X']} spans, "
          f"{counts['i']} instants, {counts['M']} metadata events, "
          f"{len(breakdowns)} request breakdowns conserve time "
          f"(worst drift {worst:.2e}s)")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1]))
