#!/usr/bin/env python
"""CI smoke check for a serve trace artifact (DESIGN.md SS15).

Loads a Chrome trace-event JSON produced by ``--trace-out`` (launch CLI
or ``benchmarks/serve_bench.py``) and verifies it parses as valid Chrome
trace-event format — the same structural validation the golden-trace
test applies — plus the breakdown metadata's conservation invariant
(per-request phase sums equal end-to-end latency).

``--strict-vocab`` additionally cross-checks every DMA channel label the
runtime emitted (the ``link`` arg on fetch/spill/promote/demote spans,
and any ``metadata.channel_bytes`` keys) against the fixed ``src->dst``
vocabulary in :mod:`repro.serving.channels` — the same constant the
static ``channel-vocab`` rule enforces on source literals, so the trace
and the tree cannot drift apart.

Usage: PYTHONPATH=src python scripts/check_trace.py [--strict-vocab] trace.json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.serving.channels import CHANNEL_LABELS
from repro.serving.trace import PHASES, validate_chrome_trace


def _trace_labels(doc: dict) -> set:
    labels = set()
    for ev in doc.get("traceEvents", []):
        link = ev.get("args", {}).get("link")
        if isinstance(link, str):
            labels.add(link)
    labels |= set(doc.get("metadata", {}).get("channel_bytes", {}))
    return labels


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="Chrome trace-event JSON to validate")
    ap.add_argument("--strict-vocab", action="store_true",
                    help="fail on channel labels outside "
                         "repro.serving.channels.CHANNEL_LABELS")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        doc = json.load(f)
    counts = validate_chrome_trace(doc)
    breakdowns = doc.get("metadata", {}).get("breakdowns", {})
    worst = 0.0
    for rid, bd in breakdowns.items():
        parts = sum(bd[f"{p}_s"] for p in PHASES)
        err = abs(parts - bd["e2e_s"])
        worst = max(worst, err)
        if err > 1e-6:
            print(f"[check_trace] FAIL: request {rid} phase sum {parts} "
                  f"!= e2e {bd['e2e_s']}")
            return 1

    labels = _trace_labels(doc)
    if args.strict_vocab:
        rogue = sorted(labels - set(CHANNEL_LABELS))
        if rogue:
            print(f"[check_trace] FAIL: channel label(s) {rogue} not in "
                  f"the fixed vocabulary {list(CHANNEL_LABELS)} "
                  f"(repro/serving/channels.py)")
            return 1

    vocab_note = (f", {len(labels)} channel label(s) in vocabulary"
                  if args.strict_vocab else "")
    print(f"[check_trace] OK: {args.path} — {counts['X']} spans, "
          f"{counts['i']} instants, {counts['M']} metadata events, "
          f"{len(breakdowns)} request breakdowns conserve time "
          f"(worst drift {worst:.2e}s){vocab_note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
