"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float = 1.0):
    """q: (B,S,H,dh); k/v: (B,L,Hkv,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, S, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bshgd,blhd->bhgsl", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(L)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgsl,blhd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_valid, *, scale: float,
                         k_scale=None, v_scale=None):
    """q: (B,H,dh); k/v_cache: (B,L,Hkv,dh) [int8 when scales given];
    kv_valid: (B,) valid lengths -> (B,H,dh)."""
    B, H, dh = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[None, None, :, None]
    if v_scale is not None:
        vf = vf * v_scale[None, None, :, None]
    qg = q.reshape(B, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, kf) * scale
    valid = jnp.arange(L)[None, None, None, :] < kv_valid[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgl,blhd->bhgd", p, vf)
    return o.reshape(B, H, dh).astype(q.dtype)


def gather_pages(pages, page_table):
    """(n_pages, ps, Hkv, dh) pool + (B, n_pp) table -> dense (B, L, Hkv, dh)."""
    B, n_pp = page_table.shape
    ps, Hkv, dh = pages.shape[1:]
    return pages[page_table].reshape(B, n_pp * ps, Hkv, dh)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, seq_lens, *,
                               scale: float, k_scale=None, v_scale=None):
    """Oracle for the paged kernel: gather pages densely, then dense ref."""
    kd = gather_pages(k_pages, page_table)
    vd = gather_pages(v_pages, page_table)
    return decode_attention_ref(q, kd, vd, seq_lens, scale=scale,
                                k_scale=k_scale, v_scale=v_scale)


def chunk_prefill_attention_ref(q, k_pages, v_pages, page_table, start,
                                n_valid, *, scale: float, k_scale=None,
                                v_scale=None):
    """Oracle for the chunk-prefill kernel: gather pages densely, causal
    mask by absolute position. q: (B, C, H, dh); start: scalar or (B,);
    n_valid: (B,) total valid tokens including this chunk."""
    B, C, H, dh = q.shape
    kd = gather_pages(k_pages, page_table).astype(jnp.float32)
    vd = gather_pages(v_pages, page_table).astype(jnp.float32)
    if k_scale is not None:
        kd = kd * k_scale[None, None, :, None]
    if v_scale is not None:
        vd = vd * v_scale[None, None, :, None]
    L, Hkv = kd.shape[1], kd.shape[2]
    g = H // Hkv
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (B,))
    qpos = start[:, None] + jnp.arange(C)[None, :]          # (B, C)
    qpos = jnp.minimum(qpos, n_valid[:, None] - 1)          # clip pad rows
    kpos = jnp.arange(L)
    mask = kpos[None, None, :] <= qpos[:, :, None]          # (B, C, L)
    qg = q.reshape(B, C, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bchgd,blhd->bhgcl", qg, kd) * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgcl,blhd->bchgd", p, vd)
    return o.reshape(B, C, H, dh).astype(q.dtype)


def spec_verify_attention_ref(q, k_pages, v_pages, page_table, seq_lens,
                              n_fed, *, scale: float, k_scale=None,
                              v_scale=None):
    """Oracle for the speculative-verify kernel (DESIGN.md SS14): row j of
    sequence b attends KV positions <= seq_lens[b] + min(j, n_fed[b]-1)
    — per-sequence window start, per-row causal frontier, padding rows
    clipped to the last real row."""
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    n_fed = jnp.asarray(n_fed, jnp.int32)
    return chunk_prefill_attention_ref(q, k_pages, v_pages, page_table,
                                       seq_lens, seq_lens + n_fed,
                                       scale=scale, k_scale=k_scale,
                                       v_scale=v_scale)
