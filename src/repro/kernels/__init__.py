"""Pallas TPU kernels for the paper's compute hot-spots.

flash_attention  — blocked causal/GQA prefill attention (VMEM tiling)
decode_attention — memory-bound KV-cache attention (bf16/int8 KV): the
                   paper's dominant decode kernel, with the int8 variant
                   realizing its "shrink attention traffic" insight on TPU,
                   plus a paged variant that gathers physical KV pages via a
                   scalar-prefetched page table (continuous batching)
ops              — jit'd wrappers with XLA fallbacks
ref              — pure-jnp oracles
"""
from repro.kernels import decode_attention, flash_attention, ops, ref
from repro.kernels.decode_attention import paged_decode_attention, quantize_kv

__all__ = ["decode_attention", "flash_attention", "ops", "ref",
           "paged_decode_attention", "quantize_kv"]
