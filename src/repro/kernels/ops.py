"""Jit'd public wrappers for the Pallas kernels with XLA fallbacks.

``try_*`` functions return ``None`` when the kernel is not eligible for the
given shapes/backend so callers can fall back to the XLA path. Eligibility
is decided from static shapes/dtypes only, never from traced values, so
the wrappers are safe to call inside ``jax.lax.scan`` bodies — the fused
multi-step decode (DESIGN.md SS12) traces them once per scan, and every
micro-step routes to the same kernel.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp


def _pallas_ok() -> bool:
    """Pallas TPU kernels lower only on TPU; interpret mode covers CPU."""
    if os.environ.get("REPRO_DISABLE_PALLAS"):
        return False
    return True


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _page_tile_ok(page_size: int, kv_dtype) -> bool:
    """A (page_size, dh) KV tile must meet the dtype's minimum sublane
    count (shared eligibility rule for every paged kernel)."""
    min_sublane = {1: 32, 2: 16}.get(jnp.dtype(kv_dtype).itemsize, 8)
    return page_size % min_sublane == 0


def try_flash_attention(q, k, v, *, mask_kind: str, window: int = 0,
                        prefix_len: int = 0, q_offset=0, kv_valid=None,
                        scale: float = 1.0, softcap: float = 0.0
                        ) -> Optional[jax.Array]:
    """Route to the Pallas flash kernel when shapes/masks are eligible."""
    if not _pallas_ok():
        return None
    B, S, H, dh = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    if mask_kind not in ("causal", "full") or softcap or kv_valid is not None:
        return None
    if S < 128 or L < 128 or dh % 128 != 0 or H % Hkv != 0:
        return None
    if isinstance(q_offset, jax.Array) or q_offset != 0 or S != L:
        return None
    from repro.kernels.flash_attention import flash_attention
    return flash_attention(q, k, v, causal=(mask_kind == "causal"),
                           scale=scale, interpret=_interpret())


def try_decode_attention(q, k_cache, v_cache, kv_valid, *, scale: float,
                         k_scale=None, v_scale=None) -> Optional[jax.Array]:
    """Route to the Pallas decode-attention kernel (bf16 or int8 KV)."""
    if not _pallas_ok():
        return None
    B, H, dh = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    if dh % 128 != 0 and dh not in (64, 128, 256):
        return None
    if L % 128 != 0 or H % Hkv != 0:
        return None
    from repro.kernels.decode_attention import decode_attention
    return decode_attention(q, k_cache, v_cache, kv_valid, scale=scale,
                            k_scale=k_scale, v_scale=v_scale,
                            interpret=_interpret())


def try_paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                               scale: float, k_scale=None, v_scale=None
                               ) -> Optional[jax.Array]:
    """Route to the paged Pallas decode kernel (page-table KV gather)."""
    if not _pallas_ok():
        return None
    B, H, dh = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    if dh % 128 != 0 and dh not in (64, 128, 256):
        return None
    if not _page_tile_ok(page_size, k_pages.dtype):
        return None
    if H % Hkv != 0:
        return None
    from repro.kernels.decode_attention import paged_decode_attention
    return paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens,
                                  scale=scale, k_scale=k_scale,
                                  v_scale=v_scale, interpret=_interpret())


def try_chunk_prefill_attention(q, k_pages, v_pages, page_table, start,
                                n_valid, *, scale: float, k_scale=None,
                                v_scale=None) -> Optional[jax.Array]:
    """Route to the chunked-prefill Pallas kernel (q-block x paged KV)."""
    if not _pallas_ok():
        return None
    B, C, H, dh = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    if dh % 128 != 0 and dh not in (64, 128, 256):
        return None
    if not _page_tile_ok(page_size, k_pages.dtype):
        return None
    if H % Hkv != 0:
        return None
    from repro.kernels.decode_attention import chunk_prefill_attention
    return chunk_prefill_attention(q, k_pages, v_pages, page_table, start,
                                   n_valid, scale=scale, k_scale=k_scale,
                                   v_scale=v_scale, interpret=_interpret())


def try_spec_verify_attention(q, k_pages, v_pages, page_table, seq_lens,
                              n_fed, *, scale: float, k_scale=None,
                              v_scale=None) -> Optional[jax.Array]:
    """Route to the speculative-verify kernel: a (B, C) query window at
    per-sequence positions ``seq_lens + j`` with per-row causal validity
    (DESIGN.md SS14). Same tile eligibility as the chunk kernel it
    shares its body with."""
    if not _pallas_ok():
        return None
    B, C, H, dh = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    if dh % 128 != 0 and dh not in (64, 128, 256):
        return None
    if not _page_tile_ok(page_size, k_pages.dtype):
        return None
    if H % Hkv != 0:
        return None
    from repro.kernels.decode_attention import spec_verify_attention
    return spec_verify_attention(q, k_pages, v_pages, page_table, seq_lens,
                                 n_fed, scale=scale, k_scale=k_scale,
                                 v_scale=v_scale, interpret=_interpret())
