"""Decode-attention Pallas TPU kernel — the paper's hot memory-bound kernel.

One new token per sequence attends to a long KV cache: a GEMV chain with
O(1) arithmetic intensity (the memory-wall regime of paper Sec. I). The
kernel streams KV blocks HBM->VMEM (BlockSpec tiling = the paper's
"hierarchical tiling towards on-chip registers") and supports an
**int8-quantized KV** variant with per-kv-head scales: the TPU-native
analogue of the paper's "restrict Q/K/V traffic to the fast tier" — it
halves the dominant traffic term instead of adding a physical tier.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _online_softmax_step(q, k, v, valid, base_pos, scale,
                         m_scr, l_scr, acc_scr):
    """One flash-attention block update against KV rows [base_pos, +len(k)).

    q: (rows, dh) f32; k/v: (bkv, dh) f32 (already dequantized); ``valid``
    masks KV at absolute position >= valid — a scalar for a shared limit or
    a (rows, 1) array for per-row (causal) limits. Shared by the
    dense-cache decode, paged decode, and chunk-prefill kernels."""
    bkv = k.shape[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = base_pos + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], bkv), 1)
    s = jnp.where(kpos < valid, s, NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p.astype(jnp.float32), v,
                                  preferred_element_type=jnp.float32))


def _init_scratch(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _finalize(o_ref, l_scr, acc_scr):
    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _kernel(valid_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, block_kv: int,
            n_kv: int, quantized: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    valid = valid_ref[0]
    run = ki * block_kv < valid

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        _online_softmax_step(q, k, v, valid, ki * block_kv, scale,
                             m_scr, l_scr, acc_scr)

    @pl.when(ki == n_kv - 1)
    def _out():
        _finalize(o_ref, l_scr, acc_scr)


def decode_attention(q, k_cache, v_cache, kv_valid, *, scale: float = None,
                     k_scale=None, v_scale=None, block_kv: int = 512,
                     interpret: bool = False):
    """q: (B,H,dh); k/v_cache: (B,L,Hkv,dh) (int8 when scales given);
    kv_valid: (B,) int32 -> (B,H,dh)."""
    B, H, dh = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    # non-multiple cache lengths: keep the lane-aligned block size and pad
    # the KV tail instead (padded rows sit at kpos >= L >= kv_valid, so the
    # kernel's validity mask already discards them) — shrinking block_kv to
    # a divisor of L would degenerate to 1-row blocks for prime L
    block_kv = min(block_kv, L)
    n_kv = -(-L // block_kv)
    quantized = k_scale is not None

    qt = q.reshape(B, Hkv, group, dh)                  # (B,Hkv,g,dh)
    kt = k_cache.transpose(0, 2, 1, 3)                 # (B,Hkv,L,dh)
    vt = v_cache.transpose(0, 2, 1, 3)
    if n_kv * block_kv != L:
        pad = ((0, 0), (0, 0), (0, n_kv * block_kv - L), (0, 0))
        kt = jnp.pad(kt, pad)
        vt = jnp.pad(vt, pad)
    if k_scale is None:
        k_scale = jnp.ones((Hkv,), jnp.float32)
        v_scale = jnp.ones((Hkv,), jnp.float32)

    grid = (B, Hkv, n_kv)
    kern = functools.partial(_kernel, scale=scale, block_kv=block_kv,
                             n_kv=n_kv, quantized=quantized)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, ki: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, ki: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, dh), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_valid.astype(jnp.int32), k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), qt, kt, vt)
    return out.reshape(B, H, dh)


def _paged_kernel(pt_ref, len_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                  page_size: int, n_pages_per_seq: int, quantized: bool):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    valid = len_ref[b]
    run = pi * page_size < valid

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page_size, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        _online_softmax_step(q, k, v, valid, pi * page_size, scale,
                             m_scr, l_scr, acc_scr)

    @pl.when(pi == n_pages_per_seq - 1)
    def _out():
        _finalize(o_ref, l_scr, acc_scr)


def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                           scale: float = None, k_scale=None, v_scale=None,
                           interpret: bool = False):
    """Decode attention over a page-table-indirected KV cache.

    q: (B, H, dh); k/v_pages: (n_pages, page_size, Hkv, dh) pooled pages
    (int8 when scales given); page_table: (B, n_pages_per_seq) int32 physical
    page ids (entries past a sequence's last used page may point anywhere —
    typically the reserved null page 0 — and are masked by ``seq_lens``);
    seq_lens: (B,) int32 valid tokens per sequence -> (B, H, dh).

    The page table is a scalar-prefetch operand: the BlockSpec ``index_map``
    reads it to gather each sequence's physical KV pages, so the kernel
    streams exactly the pages the sequence owns (the paper's hierarchical
    tiling, with one extra level of indirection for continuous batching).
    """
    B, H, dh = q.shape
    n_pages, page_size, Hkv = k_pages.shape[:3]
    n_pp = page_table.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    quantized = k_scale is not None

    qt = q.reshape(B, Hkv, group, dh)                  # (B,Hkv,g,dh)
    if k_scale is None:
        k_scale = jnp.ones((Hkv,), jnp.float32)
        v_scale = jnp.ones((Hkv,), jnp.float32)

    kern = functools.partial(_paged_kernel, scale=scale, page_size=page_size,
                             n_pages_per_seq=n_pp, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # page_table, seq_lens
        grid=(B, Hkv, n_pp),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, pi, pt, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, pi, pt, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, h, pi, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, ln: (pt[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, ln: (pt[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, h, pi, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      qt, k_pages, v_pages)
    return out.reshape(B, H, dh)


def _chunk_kernel(pt_ref, start_ref, len_ref, ksc_ref, vsc_ref, q_ref,
                  k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, page_size: int, n_pages_per_seq: int,
                  chunk: int, group: int, quantized: bool):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    start = start_ref[b]
    nv = len_ref[b]
    # pages strictly past the chunk's last query position hold no
    # attendable KV (causal) — skip them
    run = pi * page_size < start + chunk

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (chunk*group, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page_size, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        # per-row causal limit: query row r sits at absolute position
        # start + r // group and may attend KV positions <= its own,
        # clipped to the chunk's true (unpadded) extent
        rows = jax.lax.broadcasted_iota(jnp.int32, (chunk * group, 1), 0)
        valid = jnp.minimum(start + rows // group + 1, nv)
        _online_softmax_step(q, k, v, valid, pi * page_size, scale,
                             m_scr, l_scr, acc_scr)

    @pl.when(pi == n_pages_per_seq - 1)
    def _out():
        _finalize(o_ref, l_scr, acc_scr)


def chunk_prefill_attention(q, k_pages, v_pages, page_table, start, n_valid,
                            *, scale: float = None, k_scale=None,
                            v_scale=None, interpret: bool = False):
    """Chunked-prefill attention: a q-block against a page-table KV cache.

    q: (B, C, H, dh) — one fixed-size prefill chunk whose queries sit at
    absolute positions [start, start + C); k/v_pages: (n_pages, page_size,
    Hkv, dh) pooled pages (int8 when scales given) ALREADY containing the
    chunk's own KV at those positions; page_table: (B, n_pages_per_seq)
    int32 physical page ids; start: scalar or (B,) int32 first absolute
    position of the chunk; n_valid: (B,) int32 total valid tokens once this
    chunk lands (masks the chunk's right-padding). Returns (B, C, H, dh).

    Each query attends causally — KV positions <= its own — across every
    page the sequence owns, so a chunk sees the whole cached prefix (shared
    prefix pages included) plus the in-chunk causal triangle. The page
    table is a scalar-prefetch operand dereferenced by the K/V BlockSpec
    ``index_map`` (same indirection as ``paged_decode_attention``); pages
    past the chunk's last query are skipped, giving the flash-style
    diagonal-band block skipping of the dense prefill kernel.
    """
    B, C, H, dh = q.shape
    n_pages, page_size, Hkv = k_pages.shape[:3]
    n_pp = page_table.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    quantized = k_scale is not None

    # rows ordered (position, head-in-group): row r -> position r // group
    qt = (q.reshape(B, C, Hkv, group, dh).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, C * group, dh))
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (B,))
    if k_scale is None:
        k_scale = jnp.ones((Hkv,), jnp.float32)
        v_scale = jnp.ones((Hkv,), jnp.float32)

    kern = functools.partial(_chunk_kernel, scale=scale, page_size=page_size,
                             n_pages_per_seq=n_pp, chunk=C, group=group,
                             quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,        # page_table, start, n_valid
        grid=(B, Hkv, n_pp),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, pi, pt, st, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, pi, pt, st, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, C * group, dh),
                         lambda b, h, pi, pt, st, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, st, ln: (pt[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, st, ln: (pt[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C * group, dh),
                               lambda b, h, pi, pt, st, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * group,), jnp.float32),
            pltpu.VMEM((C * group,), jnp.float32),
            pltpu.VMEM((C * group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, C * group, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start, n_valid.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      qt, k_pages, v_pages)
    return (out.reshape(B, Hkv, C, group, dh).transpose(0, 2, 1, 3, 4)
            .reshape(B, C, H, dh))


def spec_verify_attention(q, k_pages, v_pages, page_table, seq_lens, n_fed,
                          *, scale: float = None, k_scale=None, v_scale=None,
                          interpret: bool = False):
    """Speculative-verify attention: a K-query block per sequence against
    the paged KV cache with per-row causal validity (DESIGN.md SS14).

    q: (B, C, H, dh) — the verify window ``[t_last, d_1 .. d_{C-1}]``
    whose queries sit at per-sequence absolute positions
    ``seq_lens[b] + j`` (the window's KV — including each draft token's
    own — is ALREADY scattered into the pages); page_table: (B,
    n_pages_per_seq); seq_lens: (B,) int32 landed tokens per sequence
    (the window starts there); n_fed: (B,) real fed window tokens per
    sequence (<= C — shorter per-slot draft lengths right-pad).

    Row j of sequence b may attend absolute KV positions
    ``<= seq_lens[b] + min(j, n_fed[b] - 1)`` — the same per-row causal
    frontier as chunked prefill, with a per-sequence (not scalar) window
    start. The implementation IS the chunk-prefill kernel: its
    ``_chunk_kernel`` body already takes a (B,) scalar-prefetch ``start``
    and computes ``valid = min(start + row + 1, n_valid)`` per row, which
    is exactly the verify semantics with ``n_valid = seq_lens + n_fed``.
    This wrapper pins those semantics down as a public entry so the
    verify path (model layer, ops routing, oracle, tests) does not lean
    on a prefill implementation detail."""
    n_valid = (jnp.asarray(seq_lens, jnp.int32)
               + jnp.asarray(n_fed, jnp.int32))
    return chunk_prefill_attention(q, k_pages, v_pages, page_table,
                                   jnp.asarray(seq_lens, jnp.int32), n_valid,
                                   scale=scale, k_scale=k_scale,
                                   v_scale=v_scale, interpret=interpret)


def quantize_kv(k, v):
    """Per-kv-head symmetric int8 quantization of a KV cache.

    k/v: (B, L, Hkv, dh) -> (k_i8, v_i8, k_scale, v_scale)."""
    def one(x):
        amax = jnp.maximum(jnp.abs(x.astype(jnp.float32)).max(
            axis=(0, 1, 3)), 1e-6)                     # (Hkv,)
        scale = amax / 127.0
        xi = jnp.clip(jnp.round(x.astype(jnp.float32)
                                / scale[None, None, :, None]),
                      -127, 127).astype(jnp.int8)
        return xi, scale
    ki, ks = one(k)
    vi, vs = one(v)
    return ki, vi, ks, vs
