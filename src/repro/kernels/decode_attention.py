"""Decode-attention Pallas TPU kernel — the paper's hot memory-bound kernel.

One new token per sequence attends to a long KV cache: a GEMV chain with
O(1) arithmetic intensity (the memory-wall regime of paper Sec. I). The
kernel streams KV blocks HBM->VMEM (BlockSpec tiling = the paper's
"hierarchical tiling towards on-chip registers") and supports an
**int8-quantized KV** variant with per-kv-head scales: the TPU-native
analogue of the paper's "restrict Q/K/V traffic to the fast tier" — it
halves the dominant traffic term instead of adding a physical tier.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _online_softmax_step(q, k, v, valid, base_pos, scale,
                         m_scr, l_scr, acc_scr):
    """One flash-attention block update against KV rows [base_pos, +len(k)).

    q: (group, dh) f32; k/v: (bkv, dh) f32 (already dequantized); ``valid``
    masks rows at absolute position >= valid. Shared by the dense-cache and
    the paged-cache decode kernels."""
    bkv = k.shape[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = base_pos + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], bkv), 1)
    s = jnp.where(kpos < valid, s, NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
    corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    m_scr[...] = m_new
    l_scr[...] = l_prev * corr + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p.astype(jnp.float32), v,
                                  preferred_element_type=jnp.float32))


def _init_scratch(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _finalize(o_ref, l_scr, acc_scr):
    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _kernel(valid_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, block_kv: int,
            n_kv: int, quantized: bool):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    valid = valid_ref[0]
    run = ki * block_kv < valid

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        _online_softmax_step(q, k, v, valid, ki * block_kv, scale,
                             m_scr, l_scr, acc_scr)

    @pl.when(ki == n_kv - 1)
    def _out():
        _finalize(o_ref, l_scr, acc_scr)


def decode_attention(q, k_cache, v_cache, kv_valid, *, scale: float = None,
                     k_scale=None, v_scale=None, block_kv: int = 512,
                     interpret: bool = False):
    """q: (B,H,dh); k/v_cache: (B,L,Hkv,dh) (int8 when scales given);
    kv_valid: (B,) int32 -> (B,H,dh)."""
    B, H, dh = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_kv = min(block_kv, L)
    n_kv = -(-L // block_kv)
    assert L % block_kv == 0
    quantized = k_scale is not None

    qt = q.reshape(B, Hkv, group, dh)                  # (B,Hkv,g,dh)
    kt = k_cache.transpose(0, 2, 1, 3)                 # (B,Hkv,L,dh)
    vt = v_cache.transpose(0, 2, 1, 3)
    if k_scale is None:
        k_scale = jnp.ones((Hkv,), jnp.float32)
        v_scale = jnp.ones((Hkv,), jnp.float32)

    grid = (B, Hkv, n_kv)
    kern = functools.partial(_kernel, scale=scale, block_kv=block_kv,
                             n_kv=n_kv, quantized=quantized)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, ki: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, ki: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, dh), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, dh), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_valid.astype(jnp.int32), k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), qt, kt, vt)
    return out.reshape(B, H, dh)


def _paged_kernel(pt_ref, len_ref, ksc_ref, vsc_ref, q_ref, k_ref, v_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                  page_size: int, n_pages_per_seq: int, quantized: bool):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    valid = len_ref[b]
    run = pi * page_size < valid

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page_size, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        _online_softmax_step(q, k, v, valid, pi * page_size, scale,
                             m_scr, l_scr, acc_scr)

    @pl.when(pi == n_pages_per_seq - 1)
    def _out():
        _finalize(o_ref, l_scr, acc_scr)


def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                           scale: float = None, k_scale=None, v_scale=None,
                           interpret: bool = False):
    """Decode attention over a page-table-indirected KV cache.

    q: (B, H, dh); k/v_pages: (n_pages, page_size, Hkv, dh) pooled pages
    (int8 when scales given); page_table: (B, n_pages_per_seq) int32 physical
    page ids (entries past a sequence's last used page may point anywhere —
    typically the reserved null page 0 — and are masked by ``seq_lens``);
    seq_lens: (B,) int32 valid tokens per sequence -> (B, H, dh).

    The page table is a scalar-prefetch operand: the BlockSpec ``index_map``
    reads it to gather each sequence's physical KV pages, so the kernel
    streams exactly the pages the sequence owns (the paper's hierarchical
    tiling, with one extra level of indirection for continuous batching).
    """
    B, H, dh = q.shape
    n_pages, page_size, Hkv = k_pages.shape[:3]
    n_pp = page_table.shape[1]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    quantized = k_scale is not None

    qt = q.reshape(B, Hkv, group, dh)                  # (B,Hkv,g,dh)
    if k_scale is None:
        k_scale = jnp.ones((Hkv,), jnp.float32)
        v_scale = jnp.ones((Hkv,), jnp.float32)

    kern = functools.partial(_paged_kernel, scale=scale, page_size=page_size,
                             n_pages_per_seq=n_pp, quantized=quantized)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # page_table, seq_lens
        grid=(B, Hkv, n_pp),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, pi, pt, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, pi, pt, ln: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, h, pi, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, ln: (pt[b, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, pi, pt, ln: (pt[b, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, h, pi, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      qt, k_pages, v_pages)
    return out.reshape(B, H, dh)


def quantize_kv(k, v):
    """Per-kv-head symmetric int8 quantization of a KV cache.

    k/v: (B, L, Hkv, dh) -> (k_i8, v_i8, k_scale, v_scale)."""
    def one(x):
        amax = jnp.maximum(jnp.abs(x.astype(jnp.float32)).max(
            axis=(0, 1, 3)), 1e-6)                     # (Hkv,)
        scale = amax / 127.0
        xi = jnp.clip(jnp.round(x.astype(jnp.float32)
                                / scale[None, None, :, None]),
                      -127, 127).astype(jnp.int8)
        return xi, scale
    ki, ks = one(k)
    vi, vs = one(v)
    return ki, vi, ks, vs
