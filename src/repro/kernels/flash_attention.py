"""FlashAttention-2-style Pallas TPU kernel (prefill/training attention).

Blocked (q_block x kv_block) online-softmax attention with explicit
BlockSpec VMEM tiling, GQA-aware, with TRUE causal block skipping (the
strictly-upper kv blocks are not computed — unlike the XLA fallback path,
which only masks them; see DESIGN.md SS7 and EXPERIMENTS.md SSPerf).

Layout: inputs are transposed to (B, heads, seq, head_dim) so the MXU
contraction dims (head_dim, kv block) are trailing and 128-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly above the diagonal band
    run = (ki * block_kv <= qi * block_q + block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, dh)
        v = v_ref[0, 0].astype(jnp.float32)            # (bkv, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
        corr = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        m_scr[...] = m_new
        l_scr[...] = l_prev * corr + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))

    @pl.when(ki == n_kv - 1)
    def _out():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale: float = None,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: bool = False):
    """q: (B,S,H,dh); k/v: (B,L,Hkv,dh) -> (B,S,H,dh). GQA via H//Hkv."""
    B, S, H, dh = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, S)
    block_kv = min(block_kv, L)
    n_q = -(-S // block_q)
    n_kv = -(-L // block_kv)
    assert S % block_q == 0 and L % block_kv == 0, (
        "pad seq lens to block multiples before calling the kernel")

    qt = q.transpose(0, 2, 1, 3)                       # (B, H, S, dh)
    kt = k.transpose(0, 2, 1, 3)                       # (B, Hkv, L, dh)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, n_q, n_kv)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_kv=block_kv, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)                   # (B, S, H, dh)
