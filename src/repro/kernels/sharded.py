"""Head-sharded execution of the paged attention kernels (DESIGN.md SS16).

One mesh axis ("model") partitions the KV-head dimension of the paged
pool. Each device runs the UNCHANGED single-device kernel over its own
Hkv/N head slice: the Pallas grids iterate (batch, kv_head, page) and
their scalar-prefetch index_maps only dereference the page table —
which replicates — so per-shard the kernels need no new index math.
Query heads partition in the same contiguous blocks (H/N = (Hkv/N) *
group, so the GQA group structure survives slicing), and the page
table / sequence lengths / window starts replicate: every shard attends
over the SAME pages, only the head slice differs.

The per-shard head outputs are all-gathered (tiled) back into full head
order before the replicated output projection. Per-head attention is
arithmetically independent and the gather restores exact head order, so
the sharded result is bitwise identical to the unsharded one — the
property the engine's token-identity acceptance leans on. (Sharding the
qkv/wo matmuls instead would reorder their reductions and break bitwise
equality; they stay replicated on purpose.)
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.jax_compat import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.jax_compat import shard_map as _shard_map

AXIS = "model"                    # the KV-head mesh axis


def head_shards(mesh, n_kv_heads: int) -> int:
    """Usable shard count: the mesh's "model" extent when it divides the
    KV-head count, else 0 (callers fall back to the replicated path)."""
    if mesh is None:
        return 0
    shape = getattr(mesh, "shape", None)
    if not shape or AXIS not in shape:
        return 0
    n = shape[AXIS]
    return n if n > 1 and n_kv_heads % n == 0 else 0


def _spec(ndim: int, shard_axis=None) -> P:
    s = [None] * ndim
    if shard_axis is not None:
        s[shard_axis] = AXIS
    return P(*s)


def sharded_attend(mesh, attend, q, k_pages, v_pages, k_scale, v_scale,
                   extras, *, q_head_axis: int):
    """Run ``attend`` — any per-head paged attention body — head-sharded.

    q partitions on ``q_head_axis``; k_pages/v_pages on axis 2 (pools
    are (n_pages, page_size, Hkv, dh)); the (Hkv,) scales on their only
    axis; every array in ``extras`` (page table, lengths, window starts)
    replicates. ``attend(q, kp, vp, ksc, vsc, *extras)`` runs once per
    shard on the local head slice and must return a tensor of q's rank
    with ``q_head_axis`` as its head dim; slices are all-gathered
    (tiled) back into full head order and returned replicated.
    """
    in_specs = (_spec(q.ndim, q_head_axis), _spec(k_pages.ndim, 2),
                _spec(v_pages.ndim, 2), P(AXIS), P(AXIS))
    in_specs += tuple(_spec(e.ndim) for e in extras)

    def body(q_l, kp_l, vp_l, ks_l, vs_l, *ex):
        out = attend(q_l, kp_l, vp_l, ks_l, vs_l, *ex)
        return jax.lax.all_gather(out, AXIS, axis=q_head_axis, tiled=True)

    fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                    out_specs=_spec(q.ndim), **{_CHECK_KW: False})
    return fn(q, k_pages, v_pages, k_scale, v_scale, *extras)
