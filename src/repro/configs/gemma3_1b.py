"""gemma3-1b [dense] — hf:google/gemma-3-1b-pt (unverified tier).

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5 local(sliding-512):1 global attention pattern, head_dim=256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    sliding_window=512,
    local_global_ratio=5,
    logit_softcap=30.0,
    tie_embeddings=True,
    gated_mlp=True,
    max_context=32768,
    notes="5:1 local:global; local layers cap KV at the 512 window.",
)
