"""mamba2-130m [ssm] — arXiv:2405.21060 (unverified tier). SSD, attention-free.

24L d_model=768 ssm_state=128 vocab=50280. d_inner=1536, 24 heads x 64.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    head_dim=1,  # unused (attention-free)
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128),
    gated_mlp=False,
    tie_embeddings=True,
    max_context=1 << 20,
    notes="Attention-free: no KV cache; paper's QKV-tier placement class "
          "is inapplicable (see DESIGN.md SSArch-applicability).",
)
