"""Reduced same-family configs for CPU smoke tests and examples."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, SSMConfig


def reduced(cfg: ArchConfig, *, d_model: int = 64, n_layers: int = 0,
            vocab: int = 256) -> ArchConfig:
    """Shrink width/depth/experts/tables while keeping the family structure
    (MoE stays MoE, MLA stays MLA, local:global pattern survives, ...)."""
    kw = dict(
        d_model=d_model,
        n_layers=n_layers or min(cfg.n_layers, 4),
        vocab=vocab,
        d_ff=d_model * 2,
        max_context=512,
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(max(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 1), 4)
        kw["head_dim"] = 16
    if cfg.moe is not None:
        kw["n_layers"] = n_layers or 3
        kw["moe"] = MoEConfig(
            n_experts=8, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=d_model // 2,
            n_shared=1 if cfg.moe.n_shared else 0,
            dense_residual=cfg.moe.dense_residual,
            first_dense=min(cfg.moe.first_dense, 1),
            d_ff_dense=d_model * 2 if cfg.moe.d_ff_dense else 0)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              rope_head_dim=8, qk_nope_head_dim=16,
                              v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16,
                              expand=cfg.ssm.expand, n_groups=1,
                              conv_width=cfg.ssm.conv_width, chunk=16)
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    if cfg.attn_every:
        kw["n_layers"] = n_layers or 4
        kw["attn_every"] = 2
    if cfg.enc_layers:
        kw["enc_layers"] = 2
        kw["source_len"] = 12
    if cfg.prefix_len:
        kw["prefix_len"] = 8
        kw["source_len"] = 8
    return dataclasses.replace(cfg, **kw)
