"""command-r-plus-104b [dense] — hf:CohereForAI/c4ai-command-r-plus (unverified).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no biases.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    qkv_bias=False,
    gated_mlp=True,
    tie_embeddings=True,
    max_context=131072,
    notes="Largest dense weight class in the pool; GQA 96:8.",
)
