"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base (hf-verified).

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 with a parallel dense residual FFN per layer.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
    gated_mlp=True,
    max_context=4096,
    notes="Dense-MoE hybrid residual: every layer = attn + dense FFN + MoE.",
)
