"""yi-6b [dense] — arXiv:2403.04652 (hf-verified). Llama-arch GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_model=4096,
    d_ff=11008,
    vocab=64000,
    gated_mlp=True,
    max_context=32768,
)
