"""Architecture configs shared by the analytical model and the JAX model zoo.

One ``ArchConfig`` describes a model family member precisely enough to
(a) build the analytical kernel graph (``repro.core.workload``),
(b) instantiate the pure-JAX model (``repro.models``), and
(c) derive sharding rules (``repro.sharding``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # always-active shared experts (DeepSeek-V2)
    dense_residual: bool = False  # parallel dense FFN next to MoE (Arctic)
    first_dense: int = 0          # first N layers use a dense FFN instead
    d_ff_dense: int = 0           # hidden dim of those dense layers / residual


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    @property
    def cache_width(self) -> int:
        # decode caches the compressed latent + the shared rope key
        return self.kv_lora_rank + self.rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block configuration."""
    state_dim: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention pattern
    sliding_window: int = 0     # >0: local layers use this window
    local_global_ratio: int = 0  # gemma3: N local layers per global layer
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    gated_mlp: bool = True      # SwiGLU (3 mats) vs GELU MLP (2 mats)
    tie_embeddings: bool = False
    # hybrid (zamba2-style): shared attention block every `attn_every` blocks
    attn_every: int = 0
    # encoder-decoder / multimodal frontends (stubs feed embeddings directly)
    enc_layers: int = 0
    source_len: int = 0         # whisper frames / vlm patches
    prefix_len: int = 0         # vlm prefix (image) tokens in the LM stream
    prefix_bidirectional: bool = False  # paligemma prefix-LM masking
    max_context: int = 131072
    dtype: str = "bfloat16"
    notes: str = ""

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_context(self) -> bool:
        """Can this arch run 500k-token decode without a full-attention KV?"""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -------------------------- parameter counts ---------------------- #
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.rope_head_dim)
            kv = d * (m.kv_lora_rank + m.rope_head_dim) + m.kv_lora_rank * (
                self.n_heads * (m.qk_nope_head_dim + m.v_head_dim))
            o = self.n_heads * m.v_head_dim * d
            return q + kv + o
        qo = d * self.n_heads * hd * 2
        kv = d * self.n_kv_heads * hd * 2
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return qo + kv + bias

    def _ffn_params(self, d_ff: int) -> int:
        n_mat = 3 if self.gated_mlp else 2
        return n_mat * self.d_model * d_ff

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        s, d = self.ssm, self.d_model
        di, nh, ng = s.d_inner(d), s.n_heads(d), s.n_groups
        in_proj = d * (2 * di + 2 * ng * s.state_dim + nh)
        conv = s.conv_width * (di + 2 * ng * s.state_dim)
        out_proj = di * d
        extra = nh * 2 + di  # A_log, D, norm
        return in_proj + conv + out_proj + extra

    def layer_params(self, layer_idx: int) -> int:
        """Parameter count of one decoder layer (by index, for MoE periods)."""
        d = self.d_model
        norm = 2 * d
        if self.family == "ssm":
            return self._ssm_params() + norm
        if self.family == "hybrid":
            # mamba2 backbone layer; shared attention counted separately
            return self._ssm_params() + norm
        attn = self._attn_params()
        if self.moe is not None and layer_idx >= self.moe.first_dense:
            m = self.moe
            ffn = (m.n_experts + m.n_shared) * self._ffn_params(m.d_ff_expert)
            ffn += m.n_experts * d  # router
            if m.dense_residual:
                ffn += self._ffn_params(m.d_ff_dense or self.d_ff)
        elif self.moe is not None:
            ffn = self._ffn_params(self.moe.d_ff_dense or self.d_ff)
        else:
            ffn = self._ffn_params(self.d_ff)
        return attn + ffn + norm

    def n_params(self) -> int:
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        body = sum(self.layer_params(i) for i in range(self.n_layers))
        if self.family == "hybrid" and self.attn_every:
            n_attn = self.n_layers // self.attn_every
            # one SHARED attention block (+ its in-projection from 2*d concat)
            shared = self._attn_params() + self.d_model * self.d_model
            body += shared + n_attn * self.d_model * self.d_model  # per-site proj
        if self.enc_layers:
            enc = self.enc_layers * (self._attn_params()
                                     + self._ffn_params(self.d_ff)
                                     + 2 * self.d_model)
            cross = self.n_layers * self._attn_params()  # decoder cross-attn
            body += enc + cross
        return emb + head + body + 2 * self.d_model

    def layer_active_params(self, layer_idx: int) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None or layer_idx < self.moe.first_dense:
            return self.layer_params(layer_idx)
        m = self.moe
        attn = self._attn_params()
        ffn = (m.top_k + m.n_shared) * self._ffn_params(m.d_ff_expert)
        ffn += m.n_experts * self.d_model
        if m.dense_residual:
            ffn += self._ffn_params(m.d_ff_dense or self.d_ff)
        return attn + ffn + 2 * self.d_model

    def n_active_params(self) -> int:
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        body = sum(self.layer_active_params(i) for i in range(self.n_layers))
        if self.family == "hybrid" and self.attn_every:
            n_attn = self.n_layers // self.attn_every
            body += (self._attn_params() + self.d_model * self.d_model
                     + n_attn * self.d_model * self.d_model)
        if self.enc_layers:
            body += self.enc_layers * (self._attn_params()
                                       + self._ffn_params(self.d_ff)
                                       + 2 * self.d_model)
            body += self.n_layers * self._attn_params()
        return emb + head + body + 2 * self.d_model

    # -------------------------- cache sizing -------------------------- #
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache (or SSM-state-equivalent) bytes appended per token."""
        if self.family == "ssm":
            return 0  # constant state, nothing grows per token
        if self.mla is not None:
            per_layer = self.mla.cache_width
        else:
            per_layer = 2 * self.n_kv_heads * self.head_dim
        n_cache_layers = self.n_attention_layers()
        return per_layer * n_cache_layers * dtype_bytes

    def n_attention_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid" and self.attn_every:
            return self.n_layers // self.attn_every
        return self.n_layers

    def attention_kind(self, layer_idx: int) -> str:
        """'global' | 'local' for this layer index (gemma3 5:1 pattern)."""
        if self.local_global_ratio and self.sliding_window:
            period = self.local_global_ratio + 1
            return "global" if (layer_idx % period == period - 1) else "local"
        return "global" if not self.sliding_window else "local"
