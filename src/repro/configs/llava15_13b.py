"""llava1.5-13b — the paper's LARGE-model test case (HBS experiments).

Llama-13B backbone as the paper models it: 40L d_model=5120 40H (MHA)
MLP = 2 matrices d -> 4d -> d (the paper's kernel list has W_MLP1/W_MLP2 only,
which also reproduces its ~13B parameter count and ~27 GB KV @ 33k ctx).
Vision tower is a stub (image tokens arrive as part of the prefill).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava15-13b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,          # paper models full MHA KV (27 GB @ 33k ctx)
    head_dim=128,
    d_ff=20480,             # 4*d, two-matrix MLP per the paper's Sec. II
    vocab=32000,
    prefix_len=576,         # CLIP ViT-L/14-336px patch tokens (stub)
    source_len=576,
    gated_mlp=False,
    max_context=32768 + 512,
    dtype="float16",        # paper runs single-precision FP16
    notes="Paper Fig.1-3 + Table I subject.",
)
