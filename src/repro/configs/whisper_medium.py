"""whisper-medium [audio] — arXiv:2212.04356 (unverified tier).

Enc-dec: 24+24L d_model=1024 16H d_ff=4096 vocab=51865. Conv frontend is a
STUB: input_specs() provides 1500 precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,            # decoder layers
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    source_len=1500,        # 30 s audio -> 1500 frames after conv stub
    qkv_bias=True,
    gated_mlp=False,        # GELU MLP (2 matrices)
    tie_embeddings=True,    # whisper ties proj_out to the token embedding
    # real whisper caps targets at 448; the 32k decode CELLS are lowered
    # structurally (pos table extended) per the assignment's shape grid
    max_context=32776,
    notes="Cross-KV computed once per request; self-KV grows per token. "
          "Real max target len is 448; 32k cells are structural.",
)
