"""paligemma-3b [vlm] — arXiv:2407.07726 (hf-verified).

Gemma-2B backbone: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
SigLIP frontend is a STUB: input_specs() provides 256 patch embeddings that
form a bidirectional prefix in the LM stream (prefix-LM masking).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    prefix_len=256,
    source_len=256,
    prefix_bidirectional=True,
    tie_embeddings=True,
    gated_mlp=True,
    max_context=8192,
)
