"""zamba2-2.7b [hybrid] — arXiv:2411.15242 (hf-verified).

54 Mamba2 blocks d_model=2560 ssm_state=64 + one SHARED attention block
(32H kv=32, d_ff=10240 MLP) applied every 6 backbone blocks. vocab=32000.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,            # shared attn block over concat width 2*d/ projected
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk=128),
    attn_every=6,
    gated_mlp=False,
    tie_embeddings=True,
    max_context=1 << 20,
    notes="Hybrid: O(1) SSM state + 9 shared-attn KV sites; sub-quadratic "
          "context => long_500k runs.",
)
