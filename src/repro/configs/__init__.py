"""Config registry: ``get_config(arch_id)`` + the assigned-architecture list."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

# arch-id -> module (one file per assigned architecture, + the paper's two)
_MODULES = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "arctic-480b": "repro.configs.arctic_480b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "yi-6b": "repro.configs.yi_6b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-medium": "repro.configs.whisper_medium",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    # the paper's own evaluation subjects
    "llava15-13b": "repro.configs.llava15_13b",
    "llama3.2-1b": "repro.configs.llama32_1b",
}

ASSIGNED_ARCHS: List[str] = [
    "deepseek-v2-236b", "arctic-480b", "gemma3-1b", "command-r-plus-104b",
    "qwen2.5-3b", "yi-6b", "mamba2-130m", "whisper-medium", "paligemma-3b",
    "zamba2-2.7b",
]

PAPER_ARCHS: List[str] = ["llava15-13b", "llama3.2-1b"]


def get_config(arch_id: str) -> ArchConfig:
    if arch_id == "llama3.2-1b-gqa":
        mod = importlib.import_module("repro.configs.llama32_1b")
        return mod.CONFIG_GQA
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in (*ASSIGNED_ARCHS, *PAPER_ARCHS)}


__all__ = ["ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "get_config",
           "all_configs", "ASSIGNED_ARCHS", "PAPER_ARCHS"]
