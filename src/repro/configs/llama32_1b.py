"""llama3.2-1b — the paper's SMALL-model test case (chiplet study).

16L d_model=2048 d_ff=8192 vocab=128256. The paper's workload model uses
full-width (MHA) KV: its quoted ~68 MB KV cache @ 512 ctx equals
2*512*2048*2B*16L; real Llama-3.2-1B uses GQA kv=8, which we also provide
via ``CONFIG_GQA`` for the beyond-paper comparison.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,          # paper's MHA-width KV (matches its 68 MB claim)
    head_dim=64,
    d_ff=8192,
    vocab=128256,
    gated_mlp=True,
    tie_embeddings=True,
    max_context=131072,
    dtype="float16",
    notes="Paper Fig.4 subject (chiplet study).",
)

CONFIG_GQA = CONFIG.replace(name="llama3.2-1b-gqa", n_kv_heads=8,
                            notes="Real HF config (GQA kv=8) for comparison.")
