"""deepseek-v2-236b [moe] — arXiv:2405.04434 (hf-verified).

60L d_model=5120 128H (MLA kv_lora=512) d_ff_expert=1536 vocab=102400,
MoE: 2 shared + 160 routed top-6, first layer dense (d_ff=12288).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: all heads share one latent cache
    head_dim=128,            # qk_nope head dim
    d_ff=12288,              # dense (first-layer) FFN hidden
    vocab=102400,
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  first_dense=1, d_ff_dense=12288),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    gated_mlp=True,
    max_context=131072,
    notes="MLA compressed KV (512+64 per token per layer); 236B total params.",
)
