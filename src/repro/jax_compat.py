"""Pinned names for JAX APIs that moved or renamed across releases.

Every version probe lives here once, instead of per-module copies:

    CompilerParams       pltpu.TPUCompilerParams -> pltpu.CompilerParams
    shard_map            jax.experimental.shard_map -> jax.shard_map
    SHARD_MAP_CHECK_KW   its check_rep kwarg -> check_vma
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
    SHARD_MAP_CHECK_KW = "check_vma"
except AttributeError:                      # pragma: no cover
    from jax.experimental.shard_map import shard_map
    SHARD_MAP_CHECK_KW = "check_rep"        # pre-promotion keyword name


def __getattr__(name: str):
    # lazy: keeps the heavyweight pallas import out of non-kernel users
    if name == "CompilerParams":
        from jax.experimental.pallas import tpu as pltpu
        return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    raise AttributeError(name)
