"""Canonical KV-tier DMA channel vocabulary — one table, three consumers.

The serving stack names every byte it moves with a directed ``"src->dst"``
channel label (DESIGN.md SS17): the runtime accounting in
``PagedKVManager._acct``, the per-link ``device_span`` labels the trace
records, the static analysis pass (``repro.analysis.checkers.accounting``)
that audits label literals, and ``scripts/check_trace.py --strict-vocab``
all draw from THIS module, so the vocabulary cannot drift between the
simulator, its trace artifacts, and the lint gate.

Tests build toy hierarchies with the same canonical tier names but
arbitrary capacities; ``make_label`` validates direction only when both
endpoints are canonical tiers, so synthetic names pass through.
"""
from __future__ import annotations

from typing import Tuple

# canonical tier names of the serving memory hierarchy, fastest first
# (mirrors kv_manager.DEFAULT_KV_TIERS; kv_manager imports from here)
KV_TIER_NAMES: Tuple[str, ...] = ("chiplet", "ddr", "hbs")

# Directed links the stack may charge bytes on. Migration always crosses
# ONE level boundary with DDR as the hub: chiplet<->hbs never transfer
# directly (a promotion out of HBS lands in DDR first, SS17).
CHANNEL_LABELS: Tuple[str, ...] = (
    "ddr->hbs",       # spill / dirty write-back across the HBS link
    "hbs->ddr",       # demand fetch / prefetch
    "ddr->chiplet",   # EMA hot-page promotion
    "chiplet->ddr",   # LRU demotion out of the chiplet level
)


def make_label(src: str, dst: str) -> str:
    """Build a ``"src->dst"`` channel label.

    When both endpoints are canonical tier names the pair must be a known
    link — a reversed or level-skipping label raises immediately at the
    accounting site instead of surfacing as reconcile drift later.
    """
    label = f"{src}->{dst}"
    if src in KV_TIER_NAMES and dst in KV_TIER_NAMES:
        if label not in CHANNEL_LABELS:
            raise ValueError(
                f"unknown KV channel {label!r}; known links: "
                f"{', '.join(CHANNEL_LABELS)}")
    return label


def is_canonical(label: str) -> bool:
    """True when ``label`` is in the fixed serving-channel vocabulary."""
    return label in CHANNEL_LABELS
