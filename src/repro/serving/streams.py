"""Virtual execution streams for the overlapped serve engine
(DESIGN.md SS16).

The continuous engine models its loop as two streams in the MaxText
offline-inference style: a *prefill worker* advancing admitted prompts by
chunks and a *decode worker* running fused K-step blocks over the running
batch, connected by a ready-queue (a request becomes decodable at the
virtual instant its last prefill chunk finishes). The host still issues
kernels one at a time — this is a CPU-rig simulation, like the SS13 tier
device — but each kernel's measured wall time is charged to ITS stream's
busy horizon, so prefill of the next admissions overlaps in virtual time
with the decode block in flight, and the serve makespan is
``max(stream.free)`` instead of the serialized sum. Everything downstream
(TTFT/ITL/TPS, the trace, the tier device's DMA horizons) reads this one
virtual clock, which starts at 0 per serve.

With ``overlap=False`` the engine binds BOTH roles to one stream: every
op serializes on a single horizon — the pre-SS16 loop — which is the
baseline the shard_sweep benchmark compares against.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class VirtualStream:
    """One in-order execution queue on the virtual clock.

    ``start(ready)`` is where the next op may begin: the stream is busy
    until ``free``, and the op's inputs exist only from ``ready`` (e.g. a
    decode block cannot start before some participant finished prefill on
    the OTHER stream). ``commit(t0, dur)`` retires the op, advancing the
    horizon; ``dur`` includes any absorbed fetch-wait stall so the stall
    stays inside the op's span."""
    name: str
    free: float = 0.0            # horizon: when the last op retires
    busy_s: float = 0.0          # summed op durations (utilization)

    def start(self, ready: float = 0.0) -> float:
        return max(self.free, ready)

    def commit(self, t0: float, dur: float) -> float:
        t1 = t0 + max(dur, 0.0)
        self.free = t1
        self.busy_s += t1 - t0
        return t1
