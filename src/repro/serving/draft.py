"""Draft-token proposers for speculative decoding (DESIGN.md SS14).

Two ways to guess the next K tokens cheaply so ONE target verify pass can
land several of them:

* ``NGramDraft`` — model-free prompt lookup: match the request's trailing
  n-gram against its own context (prompt + everything emitted so far) and
  propose the continuation of the latest earlier occurrence. Free on
  device, and strongest exactly where the paper's constrained-platform
  story needs it — shared-document QA, where answers restate spans of the
  prompt and decode loops through predictable continuations.
* ``ModelDraft`` — a small paged-KV model (e.g. a ``llama32_1b``-class
  reduction drafting for a larger target) greedily decodes K tokens per
  request. It owns a SECOND page pool and per-sequence page table over
  the same paged machinery as the target: chunked prefill to sync a new
  request, a multi-query catch-up pass to absorb tokens the target
  committed since the last block, and the fused decode scan to propose.
  Proposed-token KV is written under an all-or-nothing reservation and
  rolled back after every propose — the next catch-up re-feeds whatever
  the target actually accepted, so draft and target KV never disagree.

Both expose ``propose_all(items) -> {rid: [tokens]}`` (items: ``(Request,
k)`` pairs, k >= 0 the per-request max draft length) and ``drop(rid)``
for retirement. Proposals are deterministic given the request state —
the one-hot-draft assumption the leftover/rejection sampler relies on.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (RuntimeOptions, decode_steps_paged,
                          decode_verify_paged, init_paged_cache, init_params,
                          paged_supported, prefill_paged_chunk)
from repro.serving.kv_manager import PageAllocationError, PagedKVManager
from repro.serving.scheduler import Request


def _next_pow2(n: int) -> int:
    p = 1
    while p < max(n, 1):
        p *= 2
    return p


def _trace_proposals(drafter, items: List[Tuple[Request, int]],
                     out: Dict[int, List[int]]) -> Dict[int, List[int]]:
    """Stamp one ``spec_propose`` instant per drafted request (SS15). The
    engine wires ``drafter.tracer``/``drafter.clock`` per serve; both stay
    None when tracing is off."""
    if drafter.tracer is not None and drafter.clock is not None:
        t = drafter.clock()
        for req, k in items:
            drafter.tracer.instant(
                "spec_propose", t, rid=req.rid,
                args={"k": k, "n": len(out.get(req.rid, []))})
    return out


class NGramDraft:
    """Prompt-lookup draft: propose the continuation of the latest earlier
    occurrence of the request's trailing n-gram (longest n first).

    Keeps a per-request incremental index ``{n: {ngram: latest_start}}``
    over the request's full context, extended only over tokens that
    arrived since the last call — O(tokens * n_orders) total, never an
    O(L^2) rescan. Only starts with at least one continuation token are
    indexed, so a hit always yields a non-empty proposal."""

    def __init__(self, *, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.tracer = None                    # SS15: set by the engine
        self.clock = None
        self._idx: Dict[int, Dict[int, Dict[tuple, int]]] = {}
        self._seen: Dict[int, int] = {}       # rid -> tokens indexed

    def _extend(self, rid: int, toks: List[int]) -> None:
        idx = self._idx.setdefault(
            rid, {n: {} for n in range(self.min_ngram, self.max_ngram + 1)})
        old = self._seen.get(rid, 0)
        L = len(toks)
        for n in range(self.min_ngram, self.max_ngram + 1):
            # new valid starts: s <= L - n - 1 (continuation must exist),
            # including ones straddling the old/new boundary
            for s in range(max(0, old - n), L - n):
                idx[n][tuple(toks[s:s + n])] = s   # later s wins (latest)
        self._seen[rid] = L

    def propose(self, req: Request, k: int) -> List[int]:
        if k <= 0:
            return []
        toks = req.prefill_tokens
        self._extend(req.rid, toks)
        idx = self._idx[req.rid]
        # iterated rollout: after taking a continuation, re-match the NEW
        # trailing n-gram (context + proposal so far) against the index.
        # A single lookup truncates at the end of context — the latest
        # occurrence of a decode loop's tail sits right before L, leaving
        # under a period's worth of continuation — while re-matching
        # unrolls the cycle out to the full draft length.
        prop: List[int] = []
        while len(prop) < k:
            tail = toks + prop
            hit = None
            for n in range(min(self.max_ngram, len(tail)),
                           self.min_ngram - 1, -1):
                s = idx[n].get(tuple(tail[len(tail) - n:]))
                if s is not None:
                    hit = (s, n)
                    break
            if hit is None:
                break
            s, n = hit
            cont = toks[s + n:s + n + k - len(prop)]
            if not cont:
                break
            prop.extend(cont)
        return prop

    def propose_all(self, items: List[Tuple[Request, int]]
                    ) -> Dict[int, List[int]]:
        out = {req.rid: self.propose(req, k) for req, k in items}
        return _trace_proposals(self, items, out)

    def drop(self, rid: int) -> None:
        self._idx.pop(rid, None)
        self._seen.pop(rid, None)

    def take_host_syncs(self) -> int:
        """Prompt lookup never touches the device."""
        return 0


class ModelDraft:
    """Small-model draft over a second paged KV pool (DESIGN.md SS14).

    Per block, for each drafted request: (1) *sync* — a new request gets
    chunked-prefilled up to the target's landed extent; (2) *catch-up* —
    one batched multi-query pass (``decode_verify_paged``) feeds the
    tokens the target committed since the last block, writing their draft
    KV; (3) *propose* — the fused greedy scan decodes up to k tokens
    under a page reservation that is rolled back immediately (the draft's
    proposals are speculative even to itself: only what the target
    accepts ever becomes landed draft KV, via the next catch-up).

    The draft pool is sized for ``max_batch`` full-length sequences. The
    target engine can hold more *tracked* requests than that (preempted
    waiters keep their draft KV for free catch-up later), so on pool
    exhaustion the draft drops sequences not in the current batch and
    re-syncs them when they next run."""

    def __init__(self, cfg, params=None,
                 opts: Optional[RuntimeOptions] = None, *, page_size: int,
                 max_batch: int, max_len: int, seed: int = 1):
        reason = paged_supported(cfg)
        if reason:
            raise ValueError(f"draft config lacks the paged KV path: {reason}")
        self.cfg = cfg
        self.opts = opts if opts is not None else RuntimeOptions(
            dtype="float32")
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed), self.opts)
        self.page_size = page_size
        self.max_batch = max_batch
        self.max_len = max_len
        self.n_pp = -(-max_len // page_size)
        self.chunk = -(-32 // page_size) * page_size
        n_pages = 1 + max_batch * self.n_pp
        self.kv = PagedKVManager(n_pages, page_size)
        self.cache = init_paged_cache(cfg, n_pages, page_size, self.opts)
        from functools import partial
        self._prefill = jax.jit(
            partial(prefill_paged_chunk, cfg, opts=self.opts),
            donate_argnums=(2,))
        self._catchup = jax.jit(
            partial(decode_verify_paged, cfg, opts=self.opts),
            donate_argnums=(5,))
        self._propose = jax.jit(
            partial(decode_steps_paged, cfg, opts=self.opts, eos_id=None),
            static_argnames=("n_steps",), donate_argnums=(4,))
        self.tracer = None                    # SS15: set by the engine
        self.clock = None
        self._synced: Dict[int, bool] = {}    # rid -> has draft KV
        self.host_syncs = 0                   # drained by the engine

    # ------------------------------------------------------------------ #
    def _admit(self, req: Request) -> None:
        """Allocate + chunked-prefill a request's draft KV up to the
        target's landed extent (= context length - 1; the last token is
        fed by propose/catch-up, same protocol as the target engine)."""
        pf = req.prefill_tokens
        landed = len(pf) - 1
        padded = -(-max(landed, 1) // self.page_size) * self.page_size
        try:
            self.kv.allocate(req.rid, landed, reserve_tokens=padded)
        except PageAllocationError:
            # preempted waiters keep draft KV opportunistically; reclaim
            # theirs before giving up (they re-sync when they next run)
            for rid in [r for r in self._synced if r != req.rid]:
                self.drop(rid)
            self.kv.allocate(req.rid, landed, reserve_tokens=padded)
        C = self.chunk
        pt = jnp.asarray(self.kv.table_row(req.rid, self.n_pp)[None])
        for start in range(0, landed, C):
            n_real = min(C, landed - start)
            toks = np.zeros((1, C), np.int32)
            toks[0, :n_real] = pf[start:start + n_real]
            _, self.cache = self._prefill(
                self.params, jnp.asarray(toks), self.cache, pt,
                jnp.int32(start), jnp.asarray([start + n_real], jnp.int32))
        self._synced[req.rid] = True

    def propose_all(self, items: List[Tuple[Request, int]]
                    ) -> Dict[int, List[int]]:
        if not items:
            return {}
        B = self.max_batch
        assert len(items) <= B, "more drafted requests than draft slots"

        # ---- sync + catch-up bookkeeping (host) ---- #
        catchup: List[Tuple[int, Request, int, int]] = []  # slot, req, have, m
        for i, (req, _) in enumerate(items):
            if req.rid not in self._synced:
                self._admit(req)
            have = self.kv.seq_len(req.rid)
            landed = len(req.prefill_tokens) - 1
            m = landed - have
            if m > 0:
                catchup.append((i, req, have, m))

        # ---- one batched catch-up pass over everyone behind ---- #
        if catchup:
            Cc = _next_pow2(max(m for _, _, _, m in catchup))
            toks = np.zeros((B, Cc), np.int32)
            lens = np.zeros((B,), np.int32)
            fed = np.ones((B,), np.int32)     # inactive rows feed 1 pad
            tables = np.zeros((B, self.n_pp), np.int32)
            for i, req, have, m in catchup:
                pf = req.prefill_tokens
                toks[i, :m] = pf[have:have + m]
                lens[i] = have
                fed[i] = m
                self.kv.reserve_ahead(req.rid, m)
                tables[i] = self.kv.table_row(req.rid, self.n_pp)
            _, self.cache = self._catchup(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                jnp.asarray(fed), jnp.asarray(tables), self.cache)
            for i, req, have, m in catchup:
                self.kv.commit_tokens(req.rid, m)

        # ---- batched propose under a rolled-back reservation ---- #
        ks = [max(0, k) for _, k in items]
        k_top = max(ks)
        if k_top == 0:
            return _trace_proposals(self, items,
                                    {req.rid: [] for req, _ in items})
        tokens = np.zeros((B,), np.int32)
        lens = np.zeros((B,), np.int32)
        tables = np.zeros((B, self.n_pp), np.int32)
        quota = np.zeros((B,), np.int32)
        inactive = np.ones((B,), bool)
        for i, (req, k) in enumerate(items):
            if k <= 0:
                continue
            self.kv.reserve_ahead(req.rid, k)
            tokens[i] = req.prefill_tokens[-1]
            lens[i] = self.kv.seq_len(req.rid)
            tables[i] = self.kv.table_row(req.rid, self.n_pp)
            quota[i] = k
            inactive[i] = False
        n_steps = _next_pow2(k_top)
        blk, self.cache = self._propose(
            self.params, jnp.asarray(tokens), jnp.asarray(lens),
            jnp.asarray(tables), self.cache, n_steps=n_steps,
            done=jnp.asarray(inactive), quota=jnp.asarray(quota))
        blk_np = np.asarray(blk)
        self.host_syncs += 1       # the propose block's device->host pull
        out: Dict[int, List[int]] = {}
        for i, (req, k) in enumerate(items):
            out[req.rid] = [int(t) for t in blk_np[i, :k]] if k > 0 else []
            if k > 0:
                self.kv.release_reserved(req.rid)   # propose KV rolls back
        return _trace_proposals(self, items, out)

    def drop(self, rid: int) -> None:
        if self._synced.pop(rid, None):
            self.kv.free_seq(rid)

    def take_host_syncs(self) -> int:
        """Return and reset the syncs taken since the last drain; the
        engine folds them into ``ServeStats.host_syncs`` per spec block."""
        n = self.host_syncs
        self.host_syncs = 0
        return n
