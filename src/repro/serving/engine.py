"""Batched decode serving engine with tiered KV-cache placement.

The paper's technique as a runtime feature: the KV cache can live in a
"smaller/faster effective tier" via int8 quantization (kv_policy="int8" —
halves decode attention traffic, the TPU analogue of restricting Q/K/V
traffic to the fast tier, takeaway III), or plain bf16/f32
(kv_policy="native"). Throughput is reported in TPS — the paper's
interactivity metric — and the analytical model (repro.core) predicts the
same engine's behaviour on NPU+HBS/chiplet hierarchies.

Batching model: static batch waves over equal-length prompts (bucketed);
per-wave prefill then lock-step decode with early exit when every sequence
has emitted EOS. (Continuous batching is an acknowledged future extension —
DESIGN.md SS9.)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (RuntimeOptions, decode_step, init_cache,
                          init_params, prefill)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    new_tokens: int = 0
    requests: int = 0

    @property
    def tps(self) -> float:
        """Decode tokens/sec over the full request (paper's metric)."""
        t = self.prefill_s + self.decode_s
        return self.new_tokens / t if t > 0 else 0.0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None,
                 opts: RuntimeOptions = RuntimeOptions(dtype="float32"),
                 *, kv_policy: str = "native", max_len: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0):
        if kv_policy == "int8":
            import dataclasses
            opts = dataclasses.replace(opts, cache_dtype="int8")
        self.cfg = cfg
        self.opts = opts
        self.max_len = max_len
        self.eos_id = eos_id
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed), opts)
        self._prefill = jax.jit(partial(prefill, cfg, opts=opts))
        self._decode = jax.jit(partial(decode_step, cfg, opts=opts),
                               donate_argnums=(3,))
        self.stats = ServeStats()

    # ------------------------------------------------------------------ #
    def generate(self, prompts, max_new_tokens: int, *, prefix_emb=None,
                 greedy: bool = True, seed: int = 0) -> List[List[int]]:
        """prompts: (B, S) int array (equal lengths per wave)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        pfx = prefix_emb.shape[1] if prefix_emb is not None else 0
        total = S + pfx + max_new_tokens
        assert total <= self.max_len + pfx + max_new_tokens
        cache = init_cache(self.cfg, B, total, self.opts)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, prompts, cache,
                                      prefix_emb=prefix_emb)
        logits.block_until_ready()
        self.stats.prefill_s += time.perf_counter() - t0

        out = []
        done = np.zeros((B,), bool)
        key = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        tok = None
        for i in range(max_new_tokens):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(np.asarray(tok))
            if self.eos_id is not None:
                done |= np.asarray(tok) == self.eos_id
                if done.all():
                    break
            if i + 1 < max_new_tokens:
                logits, cache = self._decode(self.params, tok,
                                             jnp.int32(S + pfx + i), cache)
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.new_tokens += len(out) * B
        self.stats.requests += B
        seqs = np.stack(out, axis=1)
        return [row.tolist() for row in seqs]

    # ------------------------------------------------------------------ #
    def serve_bucketed(self, requests: List[List[int]],
                       max_new_tokens: int) -> Dict[int, List[List[int]]]:
        """Group ragged requests into equal-length waves and serve each."""
        buckets: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(len(r), []).append(i)
        results: Dict[int, List[int]] = {}
        for length, idxs in sorted(buckets.items()):
            wave = jnp.asarray([requests[i] for i in idxs], jnp.int32)
            outs = self.generate(wave, max_new_tokens)
            for i, o in zip(idxs, outs):
                results[i] = o
        return [results[i] for i in range(len(requests))]
