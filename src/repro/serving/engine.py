"""Batched decode serving engine with tiered KV-cache placement.

The paper's technique as a runtime feature: the KV cache can live in a
"smaller/faster effective tier" via int8 quantization (kv_policy="int8" —
halves decode attention traffic, the TPU analogue of restricting Q/K/V
traffic to the fast tier, takeaway III), or plain bf16/f32
(kv_policy="native"). Throughput is reported in TPS — the paper's
interactivity metric — and the analytical model (repro.core) predicts the
same engine's behaviour on NPU+HBS/chiplet hierarchies.

Two batching models (DESIGN.md SS9/SS10):

* ``scheduler="static"`` — batch waves over equal-length prompts
  (bucketed); per-wave prefill then lock-step decode with early exit when
  every sequence has emitted EOS.
* ``scheduler="continuous"`` — iteration-level batching over a paged,
  tiered KV cache: requests join/retire per decode step, pages come from a
  pool capped by a ``TierBudget`` derived from a ``MemoryHierarchy``, and
  pool exhaustion preempts the youngest request (recompute-style). When
  the budget has an offload tier (HBS), per-page residency is real: cold
  pages spill, a block-aligned prefetch runs ahead of the fused decode
  loop, and migration time the kernels outrun is charged as recorded
  stall on a virtual clock (DESIGN.md SS13) — TPS/TTFT/ITL then price the
  HBS bandwidth/latency envelope while outputs stay token-identical. With
  the native kv_policy, greedy outputs are token-identical to the static
  engine; under int8 the schedulers can diverge within quantization error,
  because the shared page pool calibrates scales once (first prefill)
  while the static engine recalibrates per wave (DESIGN.md SS3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (RuntimeOptions, copy_pages, decode_step,
                          decode_steps, decode_steps_paged, init_cache,
                          init_paged_cache, init_params, layer_dma_slices,
                          paged_supported, prefill, prefill_paged_chunk,
                          spec_decode_verify)
from repro.models import sampling
from repro.serving import metrics
from repro.serving.kv_manager import (PagedKVManager, SimulatedTierDevice,
                                      TierBudget, page_bytes)
from repro.serving.scheduler import (PREFILLING, RUNNING, AdaptiveSpecK,
                                     ContinuousScheduler, Request)
from repro.serving.streams import VirtualStream
from repro.serving.trace import DECODE, DRAFT, STALL, TraceRecorder


def _next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_pow2(items: List, pad_item) -> List:
    """Pad a work list to the next power-of-two length with inert filler so
    jitted consumers see O(log n) distinct shapes instead of one compile
    per batch size (used for COW copy batches; fused decode blocks clamp
    their step count through the same ``_next_pow2`` rounding)."""
    return list(items) + [pad_item] * (_next_pow2(len(items)) - len(items))


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    # serve makespan on the virtual stream clock (SS16): max over the
    # prefill/decode streams' horizons, summed across serve() calls. With
    # overlap it is LESS than prefill_s + decode_s — that gap is the
    # overlapped time, and what tps prices.
    serve_s: float = 0.0
    new_tokens: int = 0
    requests: int = 0
    decode_steps: int = 0
    preemptions: int = 0
    # chunked prefill + prefix sharing observability (continuous scheduler)
    prefill_tokens_computed: int = 0    # chunk tokens actually run
    cached_prefix_tokens: int = 0       # prompt tokens served from the cache
    pages_deduped: int = 0              # page allocations avoided by sharing
    cow_copies: int = 0
    peak_pages_used: int = 0            # max distinct in-use pages
    prefill_compiles: int = 0           # distinct jitted prefill shapes
    # fused multi-step decode observability (DESIGN.md SS12)
    host_syncs: int = 0                 # device->host round-trips taken
    decode_compiles: int = 0            # distinct jitted decode shapes
    # HBS page offload (DESIGN.md SS13): migration traffic + decode stalls
    # charged in virtual seconds by the SimulatedTierDevice
    stall_s: float = 0.0                # kernel launches waiting on fetches
    spill_bytes: float = 0.0            # dirty write-back traffic (out)
    fetch_bytes: float = 0.0            # offload -> fast migration traffic
    pages_spilled: int = 0
    pages_fetched: int = 0
    peak_fast_pages: int = 0            # max fast-tier (non-offload) pages
    prefetch_hits: int = 0              # fetches that beat their kernel
    prefetch_misses: int = 0            # fetches a kernel had to wait on
    # SS17: per-direction DMA bytes keyed "src->dst" at each link boundary
    # (write-back vs fetch vs chiplet promote/demote made visible)
    channel_bytes: Dict[str, float] = field(default_factory=dict)
    clean_demotions: int = 0            # spills that skipped write-back
    # chiplet promotion level (SS17)
    chiplet_promotions: int = 0
    chiplet_demotions: int = 0
    tier_touches: Dict[str, int] = field(default_factory=dict)
    # stall the layer-sliced overlap hid vs the whole-block barrier
    # counterfactual (0 when --no-layer-overlap)
    stall_saved_s: float = 0.0
    # runtime -> analytic bridge: the landed-page tier split observed at
    # peak occupancy, pin-able into core.concurrency.concurrent_inference
    kv_split_at_peak: tuple = ()
    # speculative decoding (DESIGN.md SS14)
    draft_proposed: int = 0             # draft tokens fed to verify passes
    draft_accepted: int = 0             # draft tokens the target kept
    spec_blocks: int = 0                # verify passes run
    # per-request attribution (SS13 deferred item): residency stall charged
    # to the requests whose pages actually gated each barrier
    stall_by_rid: Dict[int, float] = field(default_factory=dict)
    # per-request latency samples (seconds)
    ttft: List[float] = field(default_factory=list)
    itl: List[float] = field(default_factory=list)

    @property
    def prefetch_hit_rate(self) -> float:
        n = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / n if n else 1.0

    @property
    def chiplet_hit_rate(self) -> float:
        """Fraction of landed-page kernel reads served from the chiplet
        level (0.0 when no chiplet tier is configured)."""
        total = sum(self.tier_touches.values())
        return (self.tier_touches.get("chiplet", 0) / total
                if total else 0.0)

    @property
    def acceptance_rate(self) -> float:
        return (self.draft_accepted / self.draft_proposed
                if self.draft_proposed else 0.0)

    @property
    def tps(self) -> float:
        """Decode tokens/sec over the full request (paper's metric):
        tokens over the stream-clock makespan when one was recorded
        (continuous engine), else over summed phase time (static
        engine, where the two coincide)."""
        t = (self.serve_s if self.serve_s > 0
             else self.prefill_s + self.decode_s)
        return self.new_tokens / t if t > 0 else 0.0

    def _pct(self, xs: List[float], q: float) -> float:
        return metrics.percentile(xs, q)

    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttft, 50)

    @property
    def ttft_p95(self) -> float:
        return self._pct(self.ttft, 95)

    @property
    def itl_p50(self) -> float:
        return self._pct(self.itl, 50)

    @property
    def itl_p95(self) -> float:
        return self._pct(self.itl, 95)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None,
                 opts: RuntimeOptions = RuntimeOptions(dtype="float32"),
                 *, kv_policy: str = "native", max_len: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0,
                 scheduler: str = "static", page_size: int = 16,
                 max_batch: int = 8, n_pages: Optional[int] = None,
                 hierarchy=None, prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 prefix_cache: bool = True, decode_lookahead: int = 8,
                 offload: bool = True, hbs_gbps: Optional[float] = None,
                 hbs_latency_us: Optional[float] = None,
                 chiplet_gbps: Optional[float] = None,
                 chiplet_latency_us: Optional[float] = None,
                 layer_overlap: bool = True,
                 writeback_link: str = "dedicated",
                 spec_mode: str = "off", spec_k: int = 4, draft_cfg=None,
                 draft_params=None, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0, sample_seed: int = 0,
                 shards: int = 1, overlap: bool = True):
        import dataclasses
        if kv_policy == "int8":
            opts = dataclasses.replace(opts, cache_dtype="int8")
        # ---- head-sharded multi-device serving (DESIGN.md SS16) ---- #
        # an N-way mesh partitions the paged pool's KV-head dim; each
        # device runs the unchanged kernels on its Hkv/N head slice and
        # the per-head outputs are all-gathered, so outputs stay bitwise
        # identical to shards=1 while per-device page bytes shrink by N
        if shards < 1:
            raise ValueError(f"shards ({shards}) must be >= 1")
        self.mesh = None
        if shards > 1:
            if scheduler != "continuous":
                raise ValueError("head-sharded serving (shards > 1) runs "
                                 "on the paged continuous engine; use "
                                 "scheduler='continuous'")
            if cfg.n_kv_heads % shards:
                raise ValueError(f"shards ({shards}) must divide "
                                 f"n_kv_heads ({cfg.n_kv_heads}) for head "
                                 f"sharding")
            ndev = len(jax.devices())
            if ndev < shards:
                raise ValueError(
                    f"shards={shards} needs {shards} devices but jax sees "
                    f"{ndev}; on CPU export XLA_FLAGS=--xla_force_host_"
                    f"platform_device_count={shards} before importing jax")
            self.mesh = jax.make_mesh((shards,), ("model",),
                                      devices=jax.devices()[:shards])
            opts = dataclasses.replace(opts, kv_shard_mesh=self.mesh)
        self.shards = shards
        self.overlap = overlap
        if scheduler not in ("static", "continuous"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if scheduler == "continuous":
            reason = paged_supported(cfg)
            if reason:
                raise NotImplementedError(
                    f"continuous scheduler needs the paged KV path: {reason}")
        # ---- speculative decoding / sampling configuration (SS14) ---- #
        if spec_mode not in ("off", "ngram", "model"):
            raise ValueError(f"spec_mode must be one of off|ngram|model, "
                             f"got {spec_mode!r}")
        if spec_mode != "off" and scheduler != "continuous":
            raise ValueError("speculative decoding runs on the paged "
                             "continuous engine; use scheduler='continuous' "
                             "or spec_mode='off'")
        if spec_mode != "off" and spec_k < 1:
            raise ValueError(f"spec_k ({spec_k}) must be >= 1")
        if spec_mode == "model" and draft_cfg is None:
            raise ValueError("spec_mode='model' needs a draft_cfg "
                             "(a small paged-KV-capable ArchConfig)")
        if draft_cfg is not None and spec_mode != "model":
            raise ValueError(f"draft_cfg is only meaningful with "
                             f"spec_mode='model' (got {spec_mode!r})")
        if temperature < 0.0:
            raise ValueError(f"temperature ({temperature}) must be >= 0")
        if top_k < 0:
            raise ValueError(f"top_k ({top_k}) must be >= 0")
        if not 0.0 < top_p <= 1.0:
            raise ValueError(f"top_p ({top_p}) must be in (0, 1]")
        if temperature == 0.0 and (top_k or top_p < 1.0):
            raise ValueError("top_k/top_p filter a stochastic sample; they "
                             "need temperature > 0 (temperature 0 is greedy)")
        self.spec_mode = spec_mode
        self.spec_k = spec_k
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.sample_seed = sample_seed
        self.cfg = cfg
        self.opts = opts
        self.max_len = max_len
        self.eos_id = eos_id
        self.scheduler = scheduler
        self.page_size = page_size
        self.max_batch = max_batch
        if decode_lookahead < 1:
            raise ValueError(f"decode_lookahead ({decode_lookahead}) must "
                             f"be >= 1")
        self.decode_lookahead = decode_lookahead
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed), opts)
        self._prefill = jax.jit(partial(prefill, cfg, opts=opts))
        self._decode = jax.jit(partial(decode_step, cfg, opts=opts),
                               donate_argnums=(3,))
        # fused K-step greedy decode over the dense cache (static engine).
        # temperature/top_k/top_p are compile-time sampling config — the
        # body branches on them on the host, so they must be static (a
        # traced temperature would hit a concretization error)
        self._decode_block = jax.jit(partial(decode_steps, cfg, opts=opts),
                                     static_argnames=("n_steps",
                                                      "temperature",
                                                      "top_k", "top_p"),
                                     donate_argnums=(3,))
        # paged path (continuous scheduler); chunk right-padding needs no
        # reserve headroom — positions past a prompt's pages spill into the
        # reserved null page
        self.prefill_chunk = (prefill_chunk if prefill_chunk is not None
                              else max(2 * page_size, 32))
        if self.prefill_chunk % page_size:
            raise ValueError(
                f"prefill_chunk ({self.prefill_chunk}) must be a multiple "
                f"of page_size ({page_size})")
        self.prefill_budget = prefill_budget
        self.prefix_cache = prefix_cache
        self.n_pages_per_seq = -(-max_len // page_size)
        # active KV element width (int8 -> 1 via dtype); threaded through
        # the manager so occupancy/migration pricing never assumes bf16
        self.kv_dtype_bytes = (jnp.dtype(opts.cache_dtype).itemsize
                               if opts.cache_dtype else opts.jdtype.itemsize)
        self.page_nbytes = page_bytes(cfg, page_size, self.kv_dtype_bytes)
        # per-device page slice (SS16): each shard holds Hkv/N heads of
        # every page, so capacity AND migration traffic are charged at
        # page_bytes/N per device — the constrained resource
        self.page_nbytes_shard = self.page_nbytes / shards
        self.tier_budget = (None if hierarchy is None else
                            TierBudget.from_hierarchy(
                                hierarchy, cfg, page_size,
                                self.kv_dtype_bytes, shards=shards))
        # HBS offload timing: migrations between the fast KV tiers and the
        # budget's slowest tier are charged in virtual time (DESIGN.md
        # SS13). ``hbs_gbps``/``hbs_latency_us`` override the hierarchy's
        # offload-level numbers (the CLI/bench sweep lever). A fresh device
        # is built per serve() so channel horizons reset between runs.
        if writeback_link not in ("dedicated", "shared"):
            raise ValueError(f"writeback_link must be 'dedicated' or "
                             f"'shared', got {writeback_link!r}")
        self.writeback_link = writeback_link
        self._tier_device_args = None
        if (offload and hierarchy is not None and self.tier_budget is not None
                and self.tier_budget.offload_tier is not None):
            self._tier_device_args = (hierarchy,
                                      self.tier_budget.offload_tier,
                                      hbs_gbps, hbs_latency_us)
        # chiplet promotion level (DESIGN.md SS17): when the budget's
        # leading tier is promotion-only (the hierarchy carries a chiplet
        # side tier), migrations over the bonded chiplet link are charged
        # on their own device with independent in/out queues
        self._chiplet_device_args = None
        if (hierarchy is not None and self.tier_budget is not None
                and self.tier_budget.n_promote):
            self._chiplet_device_args = (hierarchy,
                                         self.tier_budget.tiers[0][0],
                                         chiplet_gbps, chiplet_latency_us)
        # layer-sliced migration overlapped with the layer loop (SS17):
        # demand fetches become chained descriptors of n_layers slices
        # pipelined against per-layer compute; off -> the whole-block
        # barrier baseline (--no-layer-overlap)
        self.layer_overlap = layer_overlap
        self.n_layer_slices = layer_dma_slices(cfg) if layer_overlap else 1
        # requested pool size; PagedKVManager clamps it to the tier budget
        self.n_pages = (n_pages if n_pages is not None
                        else max_batch * self.n_pages_per_seq + 1)
        self._prefill_chunk = jax.jit(
            partial(prefill_paged_chunk, cfg, opts=opts),
            static_argnames=("calibrate",), donate_argnums=(2,))
        # fused K-step decode over the paged pool: sample + EOS-latch on
        # device, one host sync per (B, K) token block (DESIGN.md SS12)
        self._decode_fused = jax.jit(
            partial(decode_steps_paged, cfg, opts=opts, eos_id=eos_id,
                    temperature=temperature, top_k=top_k, top_p=top_p),
            static_argnames=("n_steps",), donate_argnums=(4,))
        # speculative verify: one paged multi-query pass scores the whole
        # draft window, leftover/rejection sampling accepts on device (SS14)
        self._spec_verify = jax.jit(
            partial(spec_decode_verify, cfg, opts=opts,
                    temperature=temperature, top_k=top_k, top_p=top_p),
            donate_argnums=(5,))
        # per-request sampling keys: fold (rid, tokens-emitted) into the
        # serve seed, so a request's randomness is independent of batch
        # composition and survives recompute preemption bit-for-bit
        _base = jax.random.PRNGKey(sample_seed)

        def _bk(rids, emitted):
            def one(r, e):
                return jax.random.fold_in(jax.random.fold_in(_base, r), e)
            return jax.vmap(one)(rids, emitted)
        self._block_keys = jax.jit(_bk)
        self._sample1 = jax.jit(partial(sampling.sample,
                                        temperature=temperature,
                                        top_k=top_k, top_p=top_p))
        self._copy_pages = jax.jit(partial(copy_pages, cfg),
                                   donate_argnums=(0,))
        self._chunk_shapes: set = set()   # distinct jitted prefill shapes
        self._decode_shapes: set = set()  # distinct jitted decode shapes
        self.kv_manager: Optional[PagedKVManager] = None  # set per serve()
        # structured trace of the LAST serve_continuous run (SS15), plus
        # its reconcile report (trace audited against ServeStats deltas)
        self.trace: Optional[TraceRecorder] = None
        self.trace_report: Optional[dict] = None
        self.stats = ServeStats()

    # ------------------------------------------------------------------ #
    def generate(self, prompts, max_new_tokens: int, *, prefix_emb=None,
                 greedy: bool = True, seed: int = 0) -> List[List[int]]:
        """prompts: (B, S) int array (equal lengths per wave).

        Greedy decode runs through the fused K-step path (DESIGN.md SS12):
        the host pulls one (B, K) token block per sync instead of one
        token, with K = ``decode_lookahead``. Emitted columns are identical
        for every K — blocks may overrun the EOS stopping point on device,
        but the host truncates at exactly the step the per-token loop
        would have stopped at."""
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        pfx = prefix_emb.shape[1] if prefix_emb is not None else 0
        total = S + pfx + max_new_tokens
        assert total <= self.max_len, (
            f"prompt({S}) + prefix({pfx}) + new({max_new_tokens}) = {total} "
            f"exceeds max_len={self.max_len}")
        K = self.decode_lookahead if greedy else 1
        n_blocks = -(-max(max_new_tokens - 1, 0) // K)
        # the last fused block may overrun the token budget; headroom keeps
        # its (discarded) writes in-bounds instead of clamp-corrupting
        cache = init_cache(self.cfg, B, S + pfx + 1 + n_blocks * K,
                           self.opts)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, prompts, cache,
                                      prefix_emb=prefix_emb)
        logits.block_until_ready()
        self.stats.host_syncs += 1
        self.stats.prefill_s += time.perf_counter() - t0

        out: List[np.ndarray] = []
        done = np.zeros((B,), bool)
        t0 = time.perf_counter()
        launched = 0                        # device decode micro-steps
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pending = tok[:, None]          # device columns not yet pulled
            n_sent = 1                      # tokens produced on device
            stop = False
            while True:
                cols = np.asarray(pending)
                self.stats.host_syncs += 1
                for j in range(cols.shape[1]):
                    if len(out) >= max_new_tokens:
                        break
                    out.append(cols[:, j])
                    if self.eos_id is not None:
                        done |= cols[:, j] == self.eos_id
                        if done.all():
                            stop = True
                            break
                if stop or len(out) >= max_new_tokens:
                    break
                # tail blocks run short (power-of-two clamp, O(log K)
                # compiled shapes) instead of overrunning the budget
                k_eff = min(K, _next_pow2(max_new_tokens - len(out)))
                self._decode_shapes.add(("dense", B, k_eff))
                pending, cache = self._decode_block(
                    self.params, tok, jnp.int32(S + pfx + n_sent - 1),
                    cache, n_steps=k_eff)
                tok = pending[:, -1]
                n_sent += k_eff
                launched += k_eff
        else:
            key = jax.random.PRNGKey(seed)
            for i in range(max_new_tokens):
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
                out.append(np.asarray(tok))
                self.stats.host_syncs += 1
                if self.eos_id is not None:
                    done |= out[-1] == self.eos_id
                    if done.all():
                        break
                if i + 1 < max_new_tokens:
                    logits, cache = self._decode(
                        self.params, tok, jnp.int32(S + pfx + i), cache)
                    launched += 1
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.new_tokens += len(out) * B
        self.stats.requests += B
        # launched device micro-steps (may exceed emitted-1: blocks can
        # overrun EOS) — the same semantics as the continuous engine
        self.stats.decode_steps += launched
        self.stats.decode_compiles = len(self._decode_shapes)
        seqs = np.stack(out, axis=1)
        return [row.tolist() for row in seqs]

    # ------------------------------------------------------------------ #
    def serve(self, requests: List[List[int]],
              max_new_tokens: int) -> List[List[int]]:
        """Serve ragged requests with the configured scheduler."""
        if self.scheduler == "continuous":
            return self.serve_continuous(requests, max_new_tokens)
        return self.serve_bucketed(requests, max_new_tokens)

    def serve_bucketed(self, requests: List[List[int]],
                       max_new_tokens: int) -> List[List[int]]:
        """Group ragged requests into equal-length waves and serve each."""
        buckets: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(len(r), []).append(i)
        results: Dict[int, List[int]] = {}
        for length, idxs in sorted(buckets.items()):
            wave = jnp.asarray([requests[i] for i in idxs], jnp.int32)
            outs = self.generate(wave, max_new_tokens)
            for i, o in zip(idxs, outs):
                results[i] = o
        return [results[i] for i in range(len(requests))]

    # ------------------------------------------------------------------ #
    def serve_continuous(self, requests: List[List[int]],
                         max_new_tokens: int) -> List[List[int]]:
        """Continuous batching over the paged, tiered, prefix-shared KV
        pool with chunked prefill (DESIGN.md SS10/SS11).

        Admissions do not monopolize the loop: each step spends at most
        ``prefill_budget`` tokens advancing PREFILLING slots by fixed-size
        chunks, then runs one fused ``decode_lookahead``-step decode block
        over the RUNNING slots (on-device sampling + EOS latch, KV pages
        reserved ahead all-or-nothing, one host sync per block; SS12).
        Prompts sharing an already-seen prefix skip both the recompute and
        the pages (refcounted reuse; COW on mid-page divergence)."""
        ps, n_pp = self.page_size, self.n_pages_per_seq
        B = self.max_batch
        C = self.prefill_chunk
        # virtual stream clock (SS13/SS16), t = 0 at serve start: a
        # prefill worker and a decode worker, each an in-order
        # ``VirtualStream`` charging its ops' measured wall time plus any
        # absorbed migration stall to its own horizon. With overlap the
        # streams advance independently — chunked prefill of admitted
        # requests proceeds in virtual time while the fused decode block
        # of running requests is in flight — and the serve makespan is
        # ``max(free)``; without, both names bind one stream and every op
        # serializes (the pre-SS16 loop). TTFT/ITL/TPS, the trace and the
        # tier device's DMA horizons all read this clock.
        pstream = VirtualStream("prefill")
        dstream = VirtualStream("decode") if self.overlap else pstream
        # the prefill -> decode ready queue: rid -> virtual instant its
        # last prefill chunk finished (set at finish_prefill); a decode
        # block only includes requests ready by its start time
        decode_ready: Dict[int, float] = {}
        # a preemption victim's re-prefill cannot begin before the
        # (decode-stream) instant of the reservation that evicted it
        svc_floor: Dict[int, float] = {}
        # scheduler/drafter clock: admissions stamp at the lagging
        # stream's horizon (never later than any upcoming op start);
        # during a decode-side reservation the engine pins it to the
        # block's start so preemption instants land at eviction time
        sched_t = [0.0]

        def now() -> float:
            return max(sched_t[0], min(pstream.free, dstream.free))

        # structured trace (SS15): one recorder per serve, threaded through
        # the scheduler / KV manager / tier device / drafter; ServeStats is
        # audited against it when the run finishes (reconcile below)
        trace = TraceRecorder()
        self.trace = trace
        # stats accumulate across serve() calls but the trace covers only
        # this one — snapshot now, reconcile against the deltas
        snap_stall = self.stats.stall_s
        snap_ttft, snap_itl = len(self.stats.ttft), len(self.stats.itl)
        snap_tokens = self.stats.new_tokens
        snap_srid = dict(self.stats.stall_by_rid)
        device = (SimulatedTierDevice.from_hierarchy(
                      self._tier_device_args[0], self._tier_device_args[1],
                      bw_gbps=self._tier_device_args[2],
                      latency_us=self._tier_device_args[3],
                      duplex=(self.writeback_link == "dedicated"))
                  if self._tier_device_args is not None else None)
        if device is not None:
            device.tracer = trace
        # bonded chiplet link (SS17): its own device with independent
        # in/out queues — promotions/demotions never contend with the
        # offload link, and never gate a kernel
        cdev = (SimulatedTierDevice.from_hierarchy(
                    self._chiplet_device_args[0],
                    self._chiplet_device_args[1],
                    bw_gbps=self._chiplet_device_args[2],
                    latency_us=self._chiplet_device_args[3],
                    link="chiplet")
                if self._chiplet_device_args is not None else None)
        if cdev is not None:
            cdev.tracer = trace
        kv = PagedKVManager(self.n_pages, ps, tier_budget=self.tier_budget,
                            enable_prefix_cache=self.prefix_cache,
                            dtype_bytes=self.kv_dtype_bytes,
                            page_nbytes=self.page_nbytes_shard,
                            tier_device=device, chiplet_device=cdev,
                            tracer=trace)
        self.kv_manager = kv
        sched = ContinuousScheduler(kv, B, prefill_chunk=C,
                                    prefill_budget=self.prefill_budget,
                                    tracer=trace, clock=now)
        # draft proposer + acceptance-adaptive window sizing (SS14); fresh
        # per serve() so lookup indices / draft KV never leak across runs
        draft = adaptive = None
        if self.spec_mode == "ngram":
            from repro.serving.draft import NGramDraft
            draft = NGramDraft()
            adaptive = AdaptiveSpecK(self.spec_k)
        elif self.spec_mode == "model":
            from repro.serving.draft import ModelDraft
            draft = ModelDraft(self.draft_cfg, self.draft_params,
                               page_size=ps, max_batch=B,
                               max_len=self.max_len)
            self.draft_params = draft.params    # reuse across serve() calls
            adaptive = AdaptiveSpecK(self.spec_k)
        if draft is not None:
            draft.tracer, draft.clock = trace, now
        cache = init_paged_cache(self.cfg, kv.n_pages, ps, self.opts)
        if self.mesh is not None:
            # land the pool head-sharded up front so the jitted shard_map
            # callers never reshard it (the page scatter is elementwise on
            # the unsharded pages axis; GSPMD keeps the layout)
            from repro.sharding import rules
            from jax.sharding import NamedSharding
            cache = jax.device_put(cache, jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s),
                rules.paged_cache_pspecs(cache, self.mesh)))
        calibrated = self.opts.cache_dtype != "int8"  # only int8 calibrates

        def stall_plan(reqs: List[Request], t0: float):
            """Pre-kernel half of the fetch-wait barrier (SS17): decide
            swaps/spills and charge write-back now, defer the demand-fetch
            issue until the kernel's wall time is known so the fetch can
            be layer-sliced against the layer loop."""
            return kv.plan_residency([r.rid for r in reqs], t0)

        def stall_charge(plan, reqs: List[Request], t0: float, dw: float,
                         track: str) -> float:
            """Post-kernel half: issue the planned fetch (layer-sliced
            when overlap is on), compute the pipelined stall, and
            attribute it — the batch absorbs the stall into the issuing
            stream's next op (the caller folds the return into the op's
            duration), each request is charged its OWN pages' wait scaled
            by the overlap savings (SS13/SS17)."""
            per: Dict[int, float] = {}
            s, barrier = kv.charge_residency(
                plan, t0, n_slices=self.n_layer_slices, compute_s=dw,
                per_seq=per)
            if s > 0:
                self.stats.stall_s += s
            self.stats.stall_saved_s += max(0.0, barrier - s)
            trace.absorbed_stall(t0, s, track=track)
            for r in reqs:
                v = per.get(r.rid, 0.0)
                if v > 0:
                    r.stall_s += v
                    self.stats.stall_by_rid[r.rid] = (
                        self.stats.stall_by_rid.get(r.rid, 0.0) + v)
                    trace.span(r.rid, STALL, t0, t0 + v)
            return s

        for i, r in enumerate(requests):
            total = len(r) + max_new_tokens
            if total > self.max_len:
                raise ValueError(f"request {i}: prompt({len(r)}) + "
                                 f"new({max_new_tokens}) exceeds "
                                 f"max_len={self.max_len}")
            req = Request(rid=i, prompt=list(r),
                          max_new_tokens=max_new_tokens)
            req.t_submit = now()
            trace.submit(req.rid, req.t_submit)
            sched.submit(req)

        def finished(req: Request, tok: int) -> bool:
            return (req.remaining <= 0
                    or (self.eos_id is not None and tok == self.eos_id))

        def emit(req: Request, tok: int, at: float) -> float:
            # ``at``: attributed emission time on the issuing stream —
            # fused blocks spread their span evenly over produced tokens
            if not req.out:                      # very first token: TTFT
                self.stats.ttft.append(at - req.t_submit)
            elif req.t_last:
                self.stats.itl.append(at - req.t_last)
            req.t_last = at
            req.out.append(tok)
            self.stats.new_tokens += 1
            trace.token(req.rid, at, tok)
            return at

        def note_peak():
            # snapshot the landed-page split whenever occupancy peaks —
            # prefill-time peaks included (a run may never decode, e.g.
            # every request finishing at its first token)
            if (self.tier_budget is not None
                    and kv.n_used >= self.stats.peak_pages_used):
                self.stats.kv_split_at_peak = kv.kv_tier_split()
            self.stats.peak_pages_used = max(self.stats.peak_pages_used,
                                             kv.n_used)
            self.stats.peak_fast_pages = max(self.stats.peak_fast_pages,
                                             kv.fast_pages_used)

        def apply_copies():
            nonlocal cache
            pairs = kv.drain_copies()
            if pairs:
                # pad to a power-of-two batch with null-page self-copies so
                # the jitted scatter sees O(log) distinct shapes, not one
                # compile per COW-batch size
                pairs = _pad_pow2(pairs, (0, 0))
                cache = self._copy_pages(cache,
                                         jnp.asarray(pairs, jnp.int32))

        while sched.has_work:
            admitted = sched.admit()
            if admitted:
                # start migrating any offload-resident cached-prefix pages
                # toward the fast tiers before their first prefill chunk
                kv.prefetch_seqs([r.rid for _, r in admitted], now())
            apply_copies()       # COW copies must land before any KV write

            # ---- prefill worker: chunked, bounded by the budget ---- #
            budget = sched.prefill_budget
            for slot, req in sched.prefilling():
                if budget < C:
                    break
                pf = req.prefill_tokens
                F = len(pf)
                while budget >= C and req.state == PREFILLING:
                    start = req.n_prefilled
                    n_real = min(C, F - start)
                    toks = np.zeros((1, C), np.int32)
                    toks[0, :n_real] = pf[start:start + n_real]
                    pt = kv.table_row(req.rid, n_pp)[None]
                    self._chunk_shapes.add(((1, C), not calibrated))
                    t0 = pstream.start(svc_floor.get(req.rid, 0.0))
                    # cached prefix pages may be offload-resident: plan
                    # their migration now, issue the fetch layer-sliced
                    # against the chunk's layer loop after the kernel's
                    # wall time is measured (SS17)
                    plan = stall_plan([req], t0)
                    w0 = time.perf_counter()
                    logits, cache = self._prefill_chunk(
                        self.params, jnp.asarray(toks), cache,
                        jnp.asarray(pt), jnp.int32(start),
                        jnp.asarray([start + n_real], jnp.int32),
                        calibrate=not calibrated)
                    logits.block_until_ready()
                    dw = time.perf_counter() - w0
                    s = stall_charge(plan, [req], t0, dw, "prefill")
                    self.stats.host_syncs += 1
                    calibrated = True
                    t1 = pstream.commit(t0, s + dw)
                    self.stats.prefill_s += t1 - t0
                    trace.engine_span(
                        "prefill_chunk", t0, t1,
                        {"rid": req.rid, "tokens": [start, start + n_real]},
                        track="prefill")
                    # recompute/prefill split by the request's computed
                    # high-water mark (re-prefill after preemption)
                    trace.prefill_span(req.rid, t0, t1, start,
                                       start + n_real)
                    self.stats.prefill_tokens_computed += n_real
                    budget -= C
                    req.n_prefilled = start + n_real
                    kv.mark_written(req.rid, req.n_prefilled)
                    # index finished full pages right away so concurrent
                    # shared-prefix admissions hit them mid-prefill
                    kv.register_prefix(req.rid, pf,
                                       n_valid=req.n_prefilled)
                    if req.n_prefilled >= F:
                        sched.finish_prefill(slot)
                        decode_ready[req.rid] = t1   # decodable from t1
                        if self.temperature > 0:
                            # first token of the request: sampled from the
                            # (rid, 0) key so it is schedule-independent
                            k1 = self._block_keys(
                                jnp.asarray([req.rid], jnp.int32),
                                jnp.zeros((1,), jnp.int32))
                            tok = int(np.asarray(self._sample1(
                                logits[:, F - 1 - start], k1))[0])
                        else:
                            tok = int(np.argmax(
                                np.asarray(logits[0, F - 1 - start])))
                        # the first-token pull is its own device->host
                        # round trip, after the chunk's barrier sync
                        self.stats.host_syncs += 1
                        t_e = emit(req, tok, t1)
                        if finished(req, tok):
                            sched.retire(slot)
                            trace.retire(req.rid, t_e)
                            if draft is not None:
                                draft.drop(req.rid)

            running = sched.running()
            note_peak()
            if not running:
                if sched.has_work:
                    continue     # prefills advance / admissions retry
                break

            # ---- decode worker: one block over the READY running slots.
            # The block starts no earlier than the earliest ready instant
            # (so at least one request always qualifies); requests whose
            # prefill finished after that sit the block out — an inactive
            # slot with zero quota, which the device neither samples nor
            # writes for, so sitting out delays a request's tokens
            # without changing them (per-slot determinism) — and join the
            # next block once the decode stream catches up. Serialized
            # (overlap=False), the shared stream's horizon is past every
            # ready instant and everyone always qualifies.
            t0 = dstream.start(min(decode_ready.get(r.rid, 0.0)
                                   for _, r in running))
            parts = [(s, r) for s, r in running
                     if decode_ready.get(r.rid, 0.0) <= t0]

            if self.spec_mode != "off":
                # ==== speculative decode block (DESIGN.md SS14) ==== #
                # draft proposes up to k tokens per request; ONE verify
                # pass streams weights+KV once and lands n_acc+1 tokens
                items = [(req, min(adaptive.k_for(req), req.remaining - 1))
                         for _, req in parts]
                w0 = time.perf_counter()
                props = draft.propose_all(items)
                # a model draft pulls its proposed block to the host; the
                # n-gram draft is host-only and reports zero
                self.stats.host_syncs += draft.take_host_syncs()
                td = dstream.commit(t0, time.perf_counter() - w0)
                trace.engine_span("spec_propose", t0, td,
                                  {"n_seqs": len(items)}, track="decode")
                for _, r in parts:
                    # the whole batch waits out the proposal pass
                    trace.span(r.rid, DRAFT, t0, td)
                # reserve draft_len+1 KV writes per slot, all-or-nothing;
                # LIFO preemption may evict ANY slot — diff the full table
                before = dict(sched.slots)
                sched_t[0] = td       # evictions stamp at reservation time
                for slot, req in parts:
                    if slot in sched.slots:
                        sched.reserve_lookahead(
                            slot, len(props.get(req.rid, ())) + 1)
                sched_t[0] = 0.0
                evicted = [r for s, r in before.items()
                           if s not in sched.slots]
                for r in evicted:
                    svc_floor[r.rid] = td
                self.stats.preemptions += len(evicted)
                parts = [(s, r) for s, r in parts
                         if s in sched.slots and r.state == RUNNING]
                apply_copies()
                note_peak()
                if not parts:
                    continue
                # clamp the verify window to the largest live draft,
                # rounded up to a power of two (O(log K) compiled shapes)
                max_dl = max(len(props.get(r.rid, ())) for _, r in parts)
                n_tok = min(self.spec_k + 1, _next_pow2(max_dl + 1))
                tokens = np.zeros((B, n_tok), np.int32)
                draft_len = np.zeros((B,), np.int32)
                seq_lens = np.zeros((B,), np.int32)
                tables = np.zeros((B, n_pp), np.int32)
                rids = np.zeros((B,), np.int32)
                emitted = np.zeros((B,), np.int32)
                for slot, req in parts:
                    pr = list(props.get(req.rid, ()))[:n_tok - 1]
                    tokens[slot, 0] = req.out[-1]
                    if pr:
                        tokens[slot, 1:1 + len(pr)] = pr
                    draft_len[slot] = len(pr)
                    seq_lens[slot] = kv.seq_len(req.rid)  # landed extent
                    tables[slot] = kv.table_row(req.rid, n_pp)
                    rids[slot] = req.rid
                    emitted[slot] = len(req.out)
                keys = self._block_keys(jnp.asarray(rids),
                                        jnp.asarray(emitted))
                self._decode_shapes.add(("spec", B, n_tok))
                tb = dstream.start()
                plan = stall_plan([r for _, r in parts], tb)
                w0 = time.perf_counter()
                out, n_acc, _, cache = self._spec_verify(
                    self.params, jnp.asarray(tokens),
                    jnp.asarray(draft_len), jnp.asarray(seq_lens),
                    jnp.asarray(tables), cache, keys)
                out_np = np.asarray(out)
                nacc_np = np.asarray(n_acc)
                dw = time.perf_counter() - w0
                s = stall_charge(plan, [r for _, r in parts], tb, dw,
                                 "decode")
                tv = dstream.commit(tb, s + dw)
                dt = tv - t0
                trace.engine_span("spec_verify", tb, tv,
                                  {"n_tok": n_tok, "n_seqs": len(parts)},
                                  track="decode")
                self.stats.host_syncs += 1
                self.stats.decode_s += dt
                self.stats.decode_steps += 1    # one streaming pass
                self.stats.spec_blocks += 1

                # distribute: accepted prefix + correction/bonus token; the
                # pass wall time is attributed evenly over ACCEPTED tokens
                # (the whole point: ITL shrinks with acceptance); rejected
                # suffix pages roll back via commit_speculative
                for slot, req in parts:
                    dl = int(draft_len[slot])
                    acc = int(nacc_np[slot])
                    self.stats.draft_proposed += dl
                    self.stats.draft_accepted += acc
                    req.draft_proposed += dl
                    req.draft_accepted += acc
                    adaptive.update(req, dl, acc)
                    m = acc + 1
                    fin = False
                    n_written = 0
                    for j in range(m):
                        tok = int(out_np[slot, j])
                        n_written += 1
                        emit(req, tok, at=t0 + dt * (j + 1) / m)
                        if finished(req, tok):
                            fin = True
                            break
                    t_end = t0 + dt * (n_written / m)
                    trace.span(req.rid, DECODE, t0, t_end)
                    trace.instant("spec_commit", t_end, rid=req.rid,
                                  args={"proposed": dl, "accepted": acc})
                    kv.commit_speculative(req.rid, n_written)
                    if fin:
                        sched.retire(slot)
                        trace.retire(req.rid, t_end)
                        draft.drop(req.rid)
            else:
                # ---- reserve the block's KV writes up front (may
                # preempt): K lookahead writes per slot, all-or-nothing;
                # LIFO preemption may evict ANY slot, including a
                # just-admitted PREFILLING one — diff the full slot table
                K = self.decode_lookahead
                before = dict(sched.slots)
                sched_t[0] = t0       # evictions stamp at the block start
                for slot, req in parts:
                    if slot in sched.slots:     # may have been preempted
                        sched.reserve_lookahead(slot, min(K, req.remaining))
                sched_t[0] = 0.0
                evicted = [r for s, r in before.items()
                           if s not in sched.slots]
                for r in evicted:
                    svc_floor[r.rid] = t0
                self.stats.preemptions += len(evicted)
                parts = [(s, r) for s, r in parts
                         if s in sched.slots and r.state == RUNNING]
                apply_copies()   # COW from reservations lands pre-scan
                note_peak()
                if not parts:
                    continue

                # ---- one fused K-step decode block over the ready slots:
                # sampling, EOS latching, and length advance happen on
                # device; one host sync per (B, K) block (DESIGN.md SS12)
                tokens = np.zeros((B,), np.int32)
                seq_lens = np.zeros((B,), np.int32)
                tables = np.zeros((B, n_pp), np.int32)
                quota = np.zeros((B,), np.int32)
                inactive = np.ones((B,), bool)
                for slot, req in parts:
                    tokens[slot] = req.out[-1]
                    seq_lens[slot] = kv.seq_len(req.rid)  # write position
                    tables[slot] = kv.table_row(req.rid, n_pp)
                    quota[slot] = min(K, req.remaining)
                    inactive[slot] = False
                # clamp the block to the largest live quota, rounded up to
                # a power of two: a tail block (everyone nearly done) runs
                # short instead of decoding K wasted pad steps
                n_steps = min(K, _next_pow2(int(quota.max())))
                self._decode_shapes.add(("paged", B, n_steps))
                # fetch-wait barrier (SS13/SS17): every page this block
                # attends over must be fast-resident — or its layer slice
                # landed — before the layer consumes it; a block that
                # outruns its prefetch absorbs the residual as recorded
                # stall, shrunk by the layer-loop overlap
                plan = stall_plan([r for _, r in parts], t0)
                w0 = time.perf_counter()
                if self.temperature > 0:
                    rids = np.zeros((B,), np.int32)
                    emitted = np.zeros((B,), np.int32)
                    for slot, req in parts:
                        rids[slot] = req.rid
                        emitted[slot] = len(req.out)
                    keys = self._block_keys(jnp.asarray(rids),
                                            jnp.asarray(emitted))
                    blk, cache, _ = self._decode_fused(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(seq_lens), jnp.asarray(tables), cache,
                        n_steps=n_steps, keys=keys,
                        done=jnp.asarray(inactive), quota=jnp.asarray(quota))
                else:
                    blk, cache = self._decode_fused(
                        self.params, jnp.asarray(tokens),
                        jnp.asarray(seq_lens), jnp.asarray(tables), cache,
                        n_steps=n_steps, done=jnp.asarray(inactive),
                        quota=jnp.asarray(quota))
                blk_np = np.asarray(blk)
                dw = time.perf_counter() - w0
                s = stall_charge(plan, [r for _, r in parts], t0, dw,
                                 "decode")
                tv = dstream.commit(t0, s + dw)
                dt = tv - t0
                trace.engine_span("decode_block", t0, tv,
                                  {"n_steps": n_steps,
                                   "n_seqs": len(parts)}, track="decode")
                self.stats.host_syncs += 1
                self.stats.decode_s += dt
                self.stats.decode_steps += n_steps

                # distribute the block: per-token ITL is attributed evenly
                # from the block wall time; retire/commit at boundaries
                for slot, req in parts:
                    fin = False
                    n_written = 0            # device-side KV writes taken
                    for j in range(int(quota[slot])):
                        tok = int(blk_np[slot, j])
                        n_written += 1
                        emit(req, tok, at=t0 + dt * (j + 1) / n_steps)
                        if finished(req, tok):
                            fin = True
                            break
                    t_end = t0 + dt * (n_written / n_steps)
                    trace.span(req.rid, DECODE, t0, t_end)
                    kv.commit_tokens(req.rid, n_written)
                    if fin:
                        sched.retire(slot)   # frees surplus reserved pages
                        trace.retire(req.rid, t_end)

            # prefetch AHEAD of the next block, backdated to this block's
            # launch: the next block reads the same sequences' pages, so
            # any of them demoted to (or streamed from) the offload tier
            # migrates while this block was computing — at generous HBS
            # bandwidth the next barrier then sees zero stall. When the
            # fetch channel would otherwise sit idle, the lookahead arg
            # additionally promotes the deepest still-prefilling
            # sequence's pages (queue-aware prefetch, ROADMAP item 5)
            cont = [r.rid for s, r in running if s in sched.slots]
            if cont:
                kv.prefetch_seqs(cont, t0, lookahead_seqs=[
                    r.rid for _, r in sched.prefilling()])

        self.stats.requests += len(requests)
        self.stats.cached_prefix_tokens += kv.dedup_tokens
        self.stats.pages_deduped += kv.dedup_hits
        self.stats.cow_copies += kv.cow_copies
        self.stats.spill_bytes += kv.spill_bytes
        self.stats.fetch_bytes += kv.fetch_bytes
        self.stats.pages_spilled += kv.n_spills
        self.stats.pages_fetched += kv.n_fetches
        self.stats.prefetch_hits += kv.prefetch_hits
        self.stats.prefetch_misses += kv.prefetch_misses
        self.stats.clean_demotions += kv.clean_demotions
        self.stats.chiplet_promotions += kv.chiplet_promotions
        self.stats.chiplet_demotions += kv.chiplet_demotions
        for ch, nb in kv.channel_bytes.items():
            self.stats.channel_bytes[ch] = (
                self.stats.channel_bytes.get(ch, 0.0) + nb)
        for tier, n in kv.tier_touches.items():
            self.stats.tier_touches[tier] = (
                self.stats.tier_touches.get(tier, 0) + n)
        self.stats.prefill_compiles = len(self._chunk_shapes)
        self.stats.decode_compiles = len(self._decode_shapes)
        assert not sched.waiting and not sched.slots, "unserved requests"
        assert kv.n_used == 0, "page leak: retired sequences kept pages"
        # serve makespan: the later stream's horizon (== the serialized
        # sum when overlap is off; less when prefill hid behind decode)
        self.stats.serve_s += max(pstream.free, dstream.free)
        # close the trace and audit the aggregate counters against it:
        # phase sums == e2e per request, stall totals and samples match
        # this serve's ServeStats deltas (raises on drift — SS15)
        trace.finalize(max(pstream.free, dstream.free))
        self.trace_report = trace.reconcile(
            stall_s=self.stats.stall_s - snap_stall,
            ttft=self.stats.ttft[snap_ttft:],
            itl=self.stats.itl[snap_itl:],
            new_tokens=self.stats.new_tokens - snap_tokens,
            stall_by_rid={rid: v - snap_srid.get(rid, 0.0)
                          for rid, v in self.stats.stall_by_rid.items()},
            channel_bytes=dict(kv.channel_bytes))
        by_rid = {req.rid: req.out for req in sched.done}
        return [by_rid[i] for i in range(len(requests))]
