"""Batched decode serving engine with tiered KV-cache placement.

The paper's technique as a runtime feature: the KV cache can live in a
"smaller/faster effective tier" via int8 quantization (kv_policy="int8" —
halves decode attention traffic, the TPU analogue of restricting Q/K/V
traffic to the fast tier, takeaway III), or plain bf16/f32
(kv_policy="native"). Throughput is reported in TPS — the paper's
interactivity metric — and the analytical model (repro.core) predicts the
same engine's behaviour on NPU+HBS/chiplet hierarchies.

Two batching models (DESIGN.md SS9/SS10):

* ``scheduler="static"`` — batch waves over equal-length prompts
  (bucketed); per-wave prefill then lock-step decode with early exit when
  every sequence has emitted EOS.
* ``scheduler="continuous"`` — iteration-level batching over a paged,
  tiered KV cache: requests join/retire per decode step, pages come from a
  pool capped by a ``TierBudget`` derived from a ``MemoryHierarchy``, and
  pool exhaustion preempts the youngest request (recompute-style). With
  the native kv_policy, greedy outputs are token-identical to the static
  engine; under int8 the schedulers can diverge within quantization error,
  because the shared page pool calibrates scales once (first prefill)
  while the static engine recalibrates per wave (DESIGN.md SS3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (RuntimeOptions, decode_step, decode_step_paged,
                          init_cache, init_paged_cache, init_params,
                          paged_supported, prefill, prefill_paged)
from repro.serving.kv_manager import PagedKVManager, TierBudget
from repro.serving.scheduler import ContinuousScheduler, Request


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    new_tokens: int = 0
    requests: int = 0
    decode_steps: int = 0
    preemptions: int = 0

    @property
    def tps(self) -> float:
        """Decode tokens/sec over the full request (paper's metric)."""
        t = self.prefill_s + self.decode_s
        return self.new_tokens / t if t > 0 else 0.0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params=None,
                 opts: RuntimeOptions = RuntimeOptions(dtype="float32"),
                 *, kv_policy: str = "native", max_len: int = 512,
                 eos_id: Optional[int] = None, seed: int = 0,
                 scheduler: str = "static", page_size: int = 16,
                 max_batch: int = 8, n_pages: Optional[int] = None,
                 hierarchy=None):
        if kv_policy == "int8":
            import dataclasses
            opts = dataclasses.replace(opts, cache_dtype="int8")
        if scheduler not in ("static", "continuous"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if scheduler == "continuous":
            reason = paged_supported(cfg)
            if reason:
                raise NotImplementedError(
                    f"continuous scheduler needs the paged KV path: {reason}")
        self.cfg = cfg
        self.opts = opts
        self.max_len = max_len
        self.eos_id = eos_id
        self.scheduler = scheduler
        self.page_size = page_size
        self.max_batch = max_batch
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed), opts)
        self._prefill = jax.jit(partial(prefill, cfg, opts=opts))
        self._decode = jax.jit(partial(decode_step, cfg, opts=opts),
                               donate_argnums=(3,))
        # paged path (continuous scheduler)
        self.n_pages_per_seq = -(-max_len // page_size)
        kv_bytes = (jnp.dtype(opts.cache_dtype).itemsize if opts.cache_dtype
                    else opts.jdtype.itemsize)     # int8 -> 1 via dtype
        self.tier_budget = (None if hierarchy is None else
                            TierBudget.from_hierarchy(
                                hierarchy, cfg, page_size, kv_bytes))
        # requested pool size; PagedKVManager clamps it to the tier budget
        self.n_pages = (n_pages if n_pages is not None
                        else max_batch * self.n_pages_per_seq + 1)
        self._prefill_paged = jax.jit(
            partial(prefill_paged, cfg, opts=opts),
            static_argnames=("calibrate",), donate_argnums=(2,))
        self._decode_paged = jax.jit(
            partial(decode_step_paged, cfg, opts=opts), donate_argnums=(4,))
        self.kv_manager: Optional[PagedKVManager] = None  # set per serve()
        self.stats = ServeStats()

    # ------------------------------------------------------------------ #
    def generate(self, prompts, max_new_tokens: int, *, prefix_emb=None,
                 greedy: bool = True, seed: int = 0) -> List[List[int]]:
        """prompts: (B, S) int array (equal lengths per wave)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        pfx = prefix_emb.shape[1] if prefix_emb is not None else 0
        total = S + pfx + max_new_tokens
        assert total <= self.max_len, (
            f"prompt({S}) + prefix({pfx}) + new({max_new_tokens}) = {total} "
            f"exceeds max_len={self.max_len}")
        cache = init_cache(self.cfg, B, total, self.opts)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, prompts, cache,
                                      prefix_emb=prefix_emb)
        logits.block_until_ready()
        self.stats.prefill_s += time.perf_counter() - t0

        out = []
        done = np.zeros((B,), bool)
        key = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        tok = None
        for i in range(max_new_tokens):
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(np.asarray(tok))
            if self.eos_id is not None:
                done |= np.asarray(tok) == self.eos_id
                if done.all():
                    break
            if i + 1 < max_new_tokens:
                logits, cache = self._decode(self.params, tok,
                                             jnp.int32(S + pfx + i), cache)
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.new_tokens += len(out) * B
        self.stats.requests += B
        self.stats.decode_steps += max(len(out) - 1, 0)  # prefill made tok 0
        seqs = np.stack(out, axis=1)
        return [row.tolist() for row in seqs]

    # ------------------------------------------------------------------ #
    def serve(self, requests: List[List[int]],
              max_new_tokens: int) -> List[List[int]]:
        """Serve ragged requests with the configured scheduler."""
        if self.scheduler == "continuous":
            return self.serve_continuous(requests, max_new_tokens)
        return self.serve_bucketed(requests, max_new_tokens)

    def serve_bucketed(self, requests: List[List[int]],
                       max_new_tokens: int) -> List[List[int]]:
        """Group ragged requests into equal-length waves and serve each."""
        buckets: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault(len(r), []).append(i)
        results: Dict[int, List[int]] = {}
        for length, idxs in sorted(buckets.items()):
            wave = jnp.asarray([requests[i] for i in idxs], jnp.int32)
            outs = self.generate(wave, max_new_tokens)
            for i, o in zip(idxs, outs):
                results[i] = o
        return [results[i] for i in range(len(requests))]

    # ------------------------------------------------------------------ #
    def serve_continuous(self, requests: List[List[int]],
                         max_new_tokens: int) -> List[List[int]]:
        """Continuous batching over the paged, tiered KV pool."""
        ps, n_pp = self.page_size, self.n_pages_per_seq
        B = self.max_batch
        kv = PagedKVManager(self.n_pages, ps, tier_budget=self.tier_budget)
        self.kv_manager = kv
        sched = ContinuousScheduler(kv, B)
        cache = init_paged_cache(self.cfg, kv.n_pages, ps, self.opts)
        calibrated = self.opts.cache_dtype != "int8"  # only int8 calibrates

        for i, r in enumerate(requests):
            total = len(r) + max_new_tokens
            if total > self.max_len:
                raise ValueError(f"request {i}: prompt({len(r)}) + "
                                 f"new({max_new_tokens}) exceeds "
                                 f"max_len={self.max_len}")
            sched.submit(Request(rid=i, prompt=list(r),
                                 max_new_tokens=max_new_tokens))

        def finished(req: Request, tok: int) -> bool:
            return (req.remaining <= 0
                    or (self.eos_id is not None and tok == self.eos_id))

        while sched.has_work:
            # ---- admit + prefill newly joined requests ---- #
            for slot, req in sched.admit():
                pf = req.prefill_tokens
                # the pages admit() reserved are the single source of truth
                # for the page-aligned prefill length
                padded = len(kv.seq_pages(req.rid)) * ps
                toks = np.zeros((1, padded), np.int32)
                toks[0, :len(pf)] = pf
                pt = kv.table_row(req.rid, padded // ps)[None]
                t0 = time.perf_counter()
                logits, cache = self._prefill_paged(
                    self.params, jnp.asarray(toks), cache, jnp.asarray(pt),
                    jnp.asarray([len(pf)], jnp.int32),
                    calibrate=not calibrated)
                logits.block_until_ready()
                calibrated = True
                self.stats.prefill_s += time.perf_counter() - t0
                tok = int(np.argmax(np.asarray(logits[0])))
                req.out.append(tok)
                self.stats.new_tokens += 1
                if finished(req, tok):
                    sched.retire(slot)

            if not sched.slots:
                if sched.waiting:      # nothing running yet pool blocked:
                    continue           # admit() will retry (pages now free)
                break

            # ---- account the pending token's KV write (may preempt) ---- #
            before = dict(sched.slots)
            for slot in list(sched.slots):
                if slot in sched.slots:     # may have been preempted
                    sched.grow_seq(slot)
            self.stats.preemptions += sum(
                1 for s in before if s not in sched.slots)

            # ---- one ragged decode step over all active slots ---- #
            tokens = np.zeros((B,), np.int32)
            seq_lens = np.zeros((B,), np.int32)
            tables = np.zeros((B, n_pp), np.int32)
            for slot, req in sched.slots.items():
                tokens[slot] = req.out[-1]
                seq_lens[slot] = kv.seq_len(req.rid) - 1  # write position
                row = kv.table_row(req.rid, n_pp)
                tables[slot] = row
            t0 = time.perf_counter()
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(tokens), jnp.asarray(seq_lens),
                jnp.asarray(tables), cache)
            logits_np = np.asarray(logits)
            self.stats.decode_s += time.perf_counter() - t0
            self.stats.decode_steps += 1

            for slot in list(sched.slots):
                req = sched.slots[slot]
                tok = int(np.argmax(logits_np[slot]))
                req.out.append(tok)
                self.stats.new_tokens += 1
                if finished(req, tok):
                    sched.retire(slot)

        self.stats.requests += len(requests)
        assert not sched.waiting and not sched.slots, "unserved requests"
        assert kv.n_used == 0, "page leak: retired sequences kept pages"
        by_rid = {req.rid: req.out for req in sched.done}
        return [by_rid[i] for i in range(len(requests))]
