"""Continuous-batching request scheduler (DESIGN.md SS10).

Iteration-level scheduling over a fixed set of batch *slots*: requests join
the running batch the moment a slot and enough KV pages are free, and
retire individually (EOS / token budget), so short requests never wait for
the longest member of a wave — the failure mode of the static bucketed
engine under the paper's concurrent-inference pressure.

When the page pool is exhausted mid-decode the scheduler preempts the
most-recently admitted running request (LIFO, vLLM-style recompute
preemption): its pages are freed and its prompt *plus the tokens it already
emitted* are requeued as a new prefill, which makes preemption invisible in
the final output (greedy decode is deterministic).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.kv_manager import PageAllocationError, PagedKVManager

WAITING, RUNNING, DONE = "waiting", "running", "done"


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    out: List[int] = field(default_factory=list)
    state: str = WAITING
    n_preemptions: int = 0
    admit_order: int = -1      # monotone stamp of the LAST admission

    @property
    def prefill_tokens(self) -> List[int]:
        """What a (re)prefill must feed: prompt + already-emitted tokens."""
        return self.prompt + self.out

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.out)


class ContinuousScheduler:
    """Owns the waiting queue, the slot table, and preemption policy."""

    def __init__(self, kv: PagedKVManager, max_batch: int):
        self.kv = kv
        self.max_batch = max_batch
        self.waiting: Deque[Request] = deque()
        self.slots: Dict[int, Request] = {}      # slot index -> request
        self.done: List[Request] = []
        self._admit_stamp = 0

    # ------------------------------ queries ---------------------------- #
    @property
    def n_running(self) -> int:
        return len(self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.slots)

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if i not in self.slots]

    # ------------------------------ submit ----------------------------- #
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if not self.kv.fits_at_all(total):
            raise ValueError(
                f"request {req.rid} needs {self.kv.pages_needed(total)} pages"
                f" but the pool only has {self.kv.n_pages - 1}")
        self.waiting.append(req)

    # ------------------------------ admit ------------------------------ #
    def admit(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests while a slot + pages are available.

        Reserves pages for the padded prefill plus one headroom page so an
        admission cannot immediately deadlock the next decode step."""
        admitted: List[Tuple[int, Request]] = []
        free = self.free_slots()
        while free and self.waiting:
            req = self.waiting[0]
            pf_len = len(req.prefill_tokens)
            padded = -(-pf_len // self.kv.page_size) * self.kv.page_size
            # a solo admission may take the whole pool (``submit`` proved the
            # request fits it end-to-end); otherwise keep one headroom page
            # so the next decode write cannot instantly deadlock
            solo = not self.slots and not admitted
            if not self.kv.can_admit(padded, headroom_pages=0 if solo else 1):
                break                      # FCFS: don't starve the head
            self.waiting.popleft()
            slot = free.pop(0)
            self.kv.allocate(req.rid, pf_len, reserve_tokens=padded)
            req.state = RUNNING
            req.admit_order = self._admit_stamp
            self._admit_stamp += 1
            self.slots[slot] = req
            admitted.append((slot, req))
        return admitted

    # ----------------------------- retire ------------------------------ #
    def retire(self, slot: int) -> Request:
        req = self.slots.pop(slot)
        req.state = DONE
        self.kv.free_seq(req.rid)
        self.done.append(req)
        return req

    # ---------------------------- preemption --------------------------- #
    def preempt_one(self, protect: Optional[int] = None) -> Optional[int]:
        """Evict the most recently admitted running request (except the
        ``protect`` slot); its pages return to the pool and it rejoins the
        FRONT of the waiting queue for recompute. Returns the slot freed."""
        candidates = [(req.admit_order, slot) for slot, req in
                      self.slots.items() if slot != protect]
        if not candidates:
            return None
        _, slot = max(candidates)
        req = self.slots.pop(slot)
        self.kv.free_seq(req.rid)
        req.state = WAITING
        req.n_preemptions += 1
        req.admit_order = -1
        self.waiting.appendleft(req)
        return slot

    def grow_seq(self, slot: int) -> None:
        """Account one appended token for the request in ``slot``, preempting
        others (LIFO) until the page pool can take the write."""
        req = self.slots[slot]
        while True:
            try:
                self.kv.append_token(req.rid)
                return
            except PageAllocationError:
                if self.preempt_one(protect=slot) is None:
                    raise
