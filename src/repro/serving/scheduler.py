"""Continuous-batching request scheduler with chunked prefill
(DESIGN.md SS10/SS11).

Iteration-level scheduling over a fixed set of batch *slots*: requests join
the running batch the moment a slot and enough KV pages are free, and
retire individually (EOS / token budget), so short requests never wait for
the longest member of a wave — the failure mode of the static bucketed
engine under the paper's concurrent-inference pressure.

Prefill is *chunked*: an admitted request does not monopolize the engine
for its whole prompt. It enters a PREFILLING state and advances by
fixed-size chunks inside the decode loop, limited by a per-step prefill
token budget, so in-flight decodes keep emitting while a long prompt
streams in (the prefill/decode-interference fix; LIMINAL,
arXiv:2507.14397). Fixed chunk shapes also mean the jitted prefill
compiles once instead of once per padded prompt length.

When the page pool is exhausted mid-decode the scheduler preempts the
most-recently admitted running request (LIFO, vLLM-style recompute
preemption): its pages are freed and its prompt *plus the tokens it already
emitted* are requeued as a new prefill, which makes preemption invisible in
the final output (greedy decode is deterministic). With the prefix cache
enabled, a victim's full pages are registered before the free, so its
re-admission — and any request sharing its prefix — hits the cache instead
of recomputing.

Admission is against the pool's TOTAL capacity — every tier of the
``TierBudget``, fast tiers plus the HBS offload tier — not the fast tiers
alone (DESIGN.md SS13). A long-context request whose KV exceeds the fast
budget is admitted and runs with its cold pages spilled to the offload
tier; the engine's per-block prefetch/fetch-wait barrier charges the
migration time as decode stall instead of this scheduler preempting it.
Preemption remains the response to *total* exhaustion only.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.kv_manager import PageAllocationError, PagedKVManager

WAITING, PREFILLING, RUNNING, DONE = ("waiting", "prefilling", "running",
                                      "done")


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    out: List[int] = field(default_factory=list)
    state: str = WAITING
    n_preemptions: int = 0
    admit_order: int = -1      # monotone stamp of the LAST admission
    n_prefilled: int = 0       # prompt tokens whose KV is cached (chunked)
    t_submit: float = 0.0      # engine timestamps (TTFT / inter-token)
    t_last: float = 0.0
    stall_s: float = 0.0       # HBS residency stall attributed to THIS
                               # request's pages (SS13/SS14)
    draft_proposed: int = 0    # speculative decoding counters (SS14)
    draft_accepted: int = 0
    accept_ema: float = 1.0    # EMA of per-verify-pass acceptance rate

    @property
    def prefill_tokens(self) -> List[int]:
        """What a (re)prefill must feed: prompt + already-emitted tokens."""
        return self.prompt + self.out

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.out)


class AdaptiveSpecK:
    """Acceptance-rate-adaptive draft length (DESIGN.md SS14).

    Each verify pass costs one full weight + KV streaming round whatever
    K is, but rejected draft positions waste verify-window compute and
    reserved pages. Track a per-request EMA of the acceptance *rate*
    (accepted / proposed per pass) and size the next window as
    ``clamp(round(ema * k_max), k_min, k_max)`` — a request whose context
    predicts well (shared-document QA) keeps the full window, one that
    keeps rejecting shrinks toward ``k_min`` and degrades gracefully to
    near-plain decode."""

    def __init__(self, k_max: int, *, k_min: int = 1, beta: float = 0.5):
        if k_max < 1:
            raise ValueError("k_max must be >= 1")
        self.k_max = k_max
        self.k_min = max(1, min(k_min, k_max))
        self.beta = beta

    def k_for(self, req: Request) -> int:
        k = int(round(req.accept_ema * self.k_max))
        return max(self.k_min, min(self.k_max, k))

    def update(self, req: Request, proposed: int, accepted: int) -> None:
        if proposed <= 0:
            return
        rate = accepted / proposed
        req.accept_ema = ((1.0 - self.beta) * req.accept_ema
                          + self.beta * rate)


class ContinuousScheduler:
    """Owns the waiting queue, the slot table, prefill chunking state, and
    the preemption policy."""

    def __init__(self, kv: PagedKVManager, max_batch: int, *,
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 tracer=None, clock=None):
        """``prefill_chunk``: tokens per prefill chunk (None: the engine
        prefills whole prompts in one shot — legacy mode). ``prefill_budget``
        caps prefill tokens per engine step (default: one chunk).
        ``tracer``/``clock``: optional TraceRecorder + virtual-clock callable
        (SS15); admissions and preemptions stamp instant events."""
        self.kv = kv
        self.max_batch = max_batch
        self.tracer = tracer
        self.clock = clock
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget or prefill_chunk or 0
        if prefill_chunk and self.prefill_budget < prefill_chunk:
            raise ValueError("prefill_budget must cover at least one chunk")
        self.waiting: Deque[Request] = deque()
        self.slots: Dict[int, Request] = {}      # slot index -> request
        self.done: List[Request] = []
        self._admit_stamp = 0

    # ------------------------------ queries ---------------------------- #
    @property
    def n_running(self) -> int:
        return len(self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.slots)

    def free_slots(self) -> List[int]:
        return [i for i in range(self.max_batch) if i not in self.slots]

    def prefilling(self) -> List[Tuple[int, Request]]:
        """PREFILLING slots, FCFS by admission order."""
        return sorted(((s, r) for s, r in self.slots.items()
                       if r.state == PREFILLING),
                      key=lambda sr: sr[1].admit_order)

    def running(self) -> List[Tuple[int, Request]]:
        return sorted((s, r) for s, r in self.slots.items()
                      if r.state == RUNNING)

    # ------------------------------ submit ----------------------------- #
    def submit(self, req: Request) -> None:
        # sized against TOTAL capacity (fast tiers + offload tier): a
        # request bigger than the fast budget runs spilled, not rejected
        total = len(req.prompt) + req.max_new_tokens
        if not self.kv.fits_at_all(total):
            raise ValueError(
                f"request {req.rid} needs {self.kv.pages_needed(total)} pages"
                f" but the pool only has {self.kv.n_pages - 1} across all "
                f"tiers")
        self.waiting.append(req)

    def _should_defer(self, req: Request) -> bool:
        """Hold a request back while an in-flight prefill is still computing
        a prefix the request could reuse: admitting it now would recompute
        (and re-store) pages that are about to appear in the cache."""
        pf = req.prefill_tokens
        ps = self.kv.page_size
        cap = (len(pf) - 1) // ps * ps      # reusable extent (full pages)
        avail = self.kv.lookup_prefix(pf)
        for other in self.slots.values():
            if other.state != PREFILLING:
                continue
            common = 0
            for a, b in zip(pf, other.prefill_tokens):
                if a != b:
                    break
                common += 1
            if min(common // ps * ps, cap) > avail:
                return True
        return False

    # ------------------------------ admit ------------------------------ #
    def admit(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests while a slot + pages are available.

        Reserves pages for the worst-case prefill extent plus one headroom
        page so an admission cannot immediately deadlock the next decode
        step. With the prefix cache, matched prefix pages are shared by
        reference and ``req.n_prefilled`` starts past them."""
        admitted: List[Tuple[int, Request]] = []
        free = self.free_slots()
        while free and self.waiting:
            req = self.waiting[0]
            pf_len = len(req.prefill_tokens)
            padded = -(-pf_len // self.kv.page_size) * self.kv.page_size
            # a solo admission may take the whole pool (``submit`` proved the
            # request fits it end-to-end); otherwise keep one headroom page
            # so the next decode write cannot instantly deadlock. Chunk
            # right-padding needs no extra pages: positions past the reserve
            # spill into the null page.
            solo = not self.slots and not admitted
            if not self.kv.can_admit(padded, headroom_pages=0 if solo else 1):
                break                      # FCFS: don't starve the head
            if (self.kv.enable_prefix_cache and self.prefill_chunk
                    and self._should_defer(req)):
                break                      # its prefix is being prefilled
            self.waiting.popleft()
            slot = free.pop(0)
            if self.kv.enable_prefix_cache:
                alloc = self.kv.allocate_shared(req.rid, req.prefill_tokens,
                                                reserve_tokens=padded)
                req.n_prefilled = alloc.n_cached
            else:
                self.kv.allocate(req.rid, pf_len, reserve_tokens=padded)
                req.n_prefilled = 0
            if self.prefill_chunk:
                # chunked mode: only the cached prefix holds KV so far —
                # un-prefilled prompt pages must not be priced as traffic
                self.kv.mark_written(req.rid, req.n_prefilled)
            req.state = PREFILLING if self.prefill_chunk else RUNNING
            req.admit_order = self._admit_stamp
            self._admit_stamp += 1
            self.slots[slot] = req
            admitted.append((slot, req))
            if self.tracer is not None:
                self.tracer.admit(req.rid, self.clock(),
                                  cached_tokens=req.n_prefilled, slot=slot)
        return admitted

    def finish_prefill(self, slot: int) -> None:
        self.slots[slot].state = RUNNING

    # ----------------------------- retire ------------------------------ #
    def retire(self, slot: int) -> Request:
        req = self.slots.pop(slot)
        req.state = DONE
        # leave the finished sequence's full pages in the prefix cache
        # (refcount 0, evictable) for later shared-prefix requests
        self.kv.register_prefix(req.rid, req.prefill_tokens,
                                n_valid=self.kv.seq_len(req.rid))
        self.kv.free_seq(req.rid)
        self.done.append(req)
        return req

    # ---------------------------- preemption --------------------------- #
    def preempt_one(self, protect: Optional[int] = None) -> Optional[int]:
        """Evict the most recently admitted request (except the ``protect``
        slot); its pages return to the pool and it rejoins the FRONT of the
        waiting queue for recompute. Valid full pages are registered first
        so the re-admission hits the prefix cache. Returns the slot freed."""
        candidates = [(req.admit_order, slot) for slot, req in
                      self.slots.items() if slot != protect]
        if not candidates:
            return None
        _, slot = max(candidates)
        req = self.slots.pop(slot)
        # valid KV extent: mid-prefill it is the chunk progress; mid-decode
        # every token but the last emitted one has its KV landed (the last
        # one's write happens in the next decode block). register_prefix
        # additionally caps this by the manager's landed length, which
        # covers the legacy grow-then-write accounting too.
        n_valid = (req.n_prefilled if req.state == PREFILLING
                   else max(len(req.prefill_tokens) - 1, 0))
        if self.tracer is not None:
            # n_valid raises the recorder's computed high-water mark so the
            # victim's re-prefill is attributed as recompute, not prefill
            self.tracer.preempt(req.rid, self.clock(), n_valid=n_valid)
        self.kv.register_prefix(req.rid, req.prefill_tokens, n_valid=n_valid)
        self.kv.free_seq(req.rid)
        req.state = WAITING
        req.n_preemptions += 1
        req.admit_order = -1
        req.n_prefilled = 0
        self.waiting.appendleft(req)
        return slot

    def grow_seq(self, slot: int) -> None:
        """Account one appended token for the request in ``slot``, preempting
        others (LIFO) until the page pool can take the write."""
        req = self.slots[slot]
        while True:
            try:
                self.kv.append_token(req.rid)
                return
            except PageAllocationError:
                if self.preempt_one(protect=slot) is None:
                    raise

    def reserve_lookahead(self, slot: int, k: int) -> None:
        """Reserve ``k`` decode KV writes for ``slot`` before a fused
        decode block (DESIGN.md SS12), preempting others (LIFO) until the
        all-or-nothing reservation fits. A solo request always fits: its
        lookahead window never extends past the prompt+budget extent that
        ``submit`` proved the pool holds."""
        req = self.slots[slot]
        while True:
            try:
                self.kv.reserve_ahead(req.rid, k)
                return
            except PageAllocationError:
                if self.preempt_one(protect=slot) is None:
                    raise
