"""Paged, tiered KV-cache manager with shared-prefix page reuse and real
per-page tier residency (DESIGN.md SS10/SS11/SS13).

The runtime half of the paper's capacity-pressure story: the KV cache is a
pool of fixed-size pages shared by all in-flight sequences, indirected
through per-sequence page tables. A ``TierBudget`` derived from a
``repro.core.MemoryHierarchy`` caps the pool at what the hierarchy's KV
tiers can physically hold, and reports the pool's occupancy *as a tier
split* — the same ``((level, fraction), ...)`` shape the analytical
placement model consumes — so runtime admission pressure and analytical
spill predictions are computed from one source of truth.

Tier residency is *real*, not an accounting fiction (SS13): every
assigned page lives in exactly one tier of the budget, tracked in a
per-page residency map. New pages land in the fastest tier with room and
overflow into the slowest ("offload") tier; a block-aligned rebalance
pass (``prefetch_seqs`` / ``residency_stall``) promotes the pages a
scheduled sequence is about to attend over back into the fast tiers,
demoting LRU-cold pages to the offload tier to make room. Migration time
is charged by a ``SimulatedTierDevice`` in *virtual seconds* — per-batch
issue latency plus bytes/bandwidth on independent spill/fetch DMA
channels — so a decode block that outruns its prefetch records the
residual as stall time instead of silently winning. The page payloads
themselves never move (the device pool is one array); only the residency
map and the virtual clock change, which keeps offload runs token-identical
to no-offload runs by construction.

Prefix sharing (SS11) attacks the capacity term directly: pages are
refcounted, full pages of completed prefixes are registered in a
hash-chained index (block content + every block before it), and a new
request whose prompt matches a chain *reuses the physical pages* instead
of recomputing and re-storing identical KV. Divergence mid-page is handled
copy-on-write: the manager hands the sequence a private copy of the
partially-matching page and records the (src, dst) device copy for the
engine to apply. Retired prefixes stay cached at refcount 0 (evictable,
LRU) until allocation pressure reclaims them.

Host-side bookkeeping is plain Python (free list + dicts); the page pool
arrays themselves live in the model cache (``models.init_paged_cache``).
Page 0 is reserved as the null page: padded page-table entries point at it,
inactive slots write into it, and nothing ever reads it unmasked.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.channels import KV_TIER_NAMES, make_label

# tiers a KV page may occupy, preferred (fastest) first; mirrors the
# placement policies in repro.core.placement and the channel vocabulary
# in repro.serving.channels (one table, no drift)
DEFAULT_KV_TIERS = KV_TIER_NAMES


def page_bytes(cfg: ArchConfig, page_size: int, dtype_bytes: int = 2) -> int:
    """Bytes one KV page holds across all layers (k + v)."""
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * dtype_bytes
    return per_tok * page_size


@dataclass
class SimulatedTierDevice:
    """Virtual-time migration engine between the fast KV tiers and the
    offload tier (DESIGN.md SS13).

    Two DMA channels — ``"in"`` (fetch: offload -> fast) and ``"out"``
    (spill/write-back: fast -> offload) — each a single queue whose busy
    horizon advances by the offload tier's issue latency once per
    *batched* migration plus ``bytes / bandwidth``. A dedicated-HBS link
    is full duplex (independent queues); a shared link (PCIe-attached
    SSD style, ``duplex=False``) serializes both directions through one
    queue, so write-back pressure delays fetches. All times are virtual
    seconds on the caller's clock (the engine passes
    ``perf_counter() + accumulated_stall``); the device never sleeps and
    never moves data — it only answers "when would this transfer have
    completed on real HBS", which the engine converts into decode stalls.
    """
    bandwidth: float                     # bytes/s across the offload link
    latency: float                       # seconds per migration batch issue
    tracer: Optional[object] = None      # TraceRecorder: DMA-track spans
    link: str = "hbs"                    # link name for trace track routing
    duplex: bool = True                  # False: in/out share one queue
    _free: Dict[str, float] = field(
        default_factory=lambda: {"in": 0.0, "out": 0.0, "io": 0.0})
    busy_s: Dict[str, float] = field(
        default_factory=lambda: {"in": 0.0, "out": 0.0})

    @classmethod
    def from_hierarchy(cls, hier, offload_tier: str, *,
                       bw_gbps: Optional[float] = None,
                       latency_us: Optional[float] = None,
                       duplex: bool = True,
                       link: Optional[str] = None
                       ) -> "SimulatedTierDevice":
        """Timing from the hierarchy's offload level, with CLI-style
        overrides (``bw_gbps`` in GB/s, ``latency_us`` in µs)."""
        lv = hier.level(offload_tier)
        bw = lv.bandwidth if bw_gbps is None else bw_gbps * 1e9
        lat = lv.latency if latency_us is None else latency_us * 1e-6
        if bw <= 0:
            raise ValueError(f"offload tier {offload_tier!r} needs a "
                             f"positive bandwidth, got {bw}")
        return cls(bandwidth=bw, latency=max(lat, 0.0),
                   duplex=duplex, link=link or offload_tier)

    def _qkey(self, channel: str) -> str:
        return channel if self.duplex else "io"

    def idle(self, channel: str, now: float) -> bool:
        """True when the channel's queue has drained by ``now``."""
        return self._free.get(self._qkey(channel), 0.0) <= now

    def transfer(self, channel: str, n_bytes: float, now: float,
                 label: Optional[str] = None) -> float:
        """Enqueue one batched migration; returns its completion time."""
        q = self._qkey(channel)
        start = max(self._free.get(q, 0.0), now)
        done = start + self.latency + n_bytes / self.bandwidth
        self.busy_s[channel] += done - start
        self._free[q] = done
        if self.tracer is not None:
            self.tracer.device_span(channel, start, done, n_bytes,
                                    link=self.link, label=label)
        return done

    def transfer_sliced(self, channel: str, n_bytes: float, now: float,
                        n_slices: int, label: Optional[str] = None
                        ) -> List[float]:
        """Enqueue one migration as a chained DMA descriptor of
        ``n_slices`` equal slices (DESIGN.md SS17: one slice per model
        layer). Issue latency is charged ONCE — the chain is a single
        queued command — and slice ``l`` completes at ``start + latency +
        (l+1) * bytes / (n_slices * bandwidth)``, so a consumer walking
        the slices in order (the layer loop) can start on slice 0 while
        the tail still streams. The final slice lands exactly when the
        equivalent bulk ``transfer`` would, which is what makes
        layer-overlap never worse than the whole-block barrier. Returns
        the per-slice completion times."""
        if n_slices <= 1:
            return [self.transfer(channel, n_bytes, now, label=label)]
        q = self._qkey(channel)
        start = max(self._free.get(q, 0.0), now)
        per = n_bytes / self.bandwidth / n_slices
        dones = [start + self.latency + (i + 1) * per
                 for i in range(n_slices)]
        self.busy_s[channel] += dones[-1] - start
        self._free[q] = dones[-1]
        if self.tracer is not None:
            prev = start
            for i, d in enumerate(dones):
                self.tracer.device_span(channel, prev, d,
                                        n_bytes / n_slices,
                                        link=self.link, label=label,
                                        slice_idx=i)
                prev = d
        return dones


@dataclass(frozen=True)
class TierBudget:
    """Per-tier page counts, preferred (fastest) tier first.

    The leading ``n_promote`` tiers are PROMOTION-ONLY cache levels
    (DESIGN.md SS17: the bonded global-buffer chiplet): fresh pages are
    never assigned there — residency is earned by the EMA hot-page
    promotion pass and lost by LRU demotion back to the base tier. The
    remaining ordered levels behave as before: fresh pages land in the
    fastest base tier with room and overflow into the last ("offload")
    tier."""
    tiers: Tuple[Tuple[str, int], ...]     # ((level_name, n_pages), ...)
    n_promote: int = 0                     # leading promotion-only levels

    def __post_init__(self):
        if not (0 <= self.n_promote < len(self.tiers)):
            raise ValueError(
                f"n_promote ({self.n_promote}) must leave at least one "
                f"base tier out of {len(self.tiers)}")

    @property
    def total_pages(self) -> int:
        return sum(n for _, n in self.tiers)

    @property
    def promote_tiers(self) -> Tuple[Tuple[str, int], ...]:
        return self.tiers[:self.n_promote]

    @property
    def base_tiers(self) -> Tuple[Tuple[str, int], ...]:
        return self.tiers[self.n_promote:]

    @property
    def offload_tier(self) -> Optional[str]:
        """The slowest tier — spill target when the faster tiers are over
        budget. None when the budget has a single base tier (a promotion
        cache is not spill capacity — nowhere to spill)."""
        return (self.tiers[-1][0]
                if len(self.tiers) - self.n_promote > 1 else None)

    @property
    def fast_pages(self) -> int:
        """Pages the non-offload ("fast") tiers hold together, promotion
        levels included."""
        if self.offload_tier is None:
            return self.total_pages
        return sum(n for _, n in self.tiers[:-1])

    @classmethod
    def from_hierarchy(cls, hier, cfg: ArchConfig, page_size: int,
                       dtype_bytes: int = 2,
                       kv_tiers: Sequence[str] = DEFAULT_KV_TIERS,
                       reserve_bytes: Dict[str, float] = None,
                       uncapped_pages: Optional[int] = None,
                       shards: int = 1) -> "TierBudget":
        """Pages per tier from the hierarchy's KV-eligible capacities.

        ``reserve_bytes`` subtracts non-KV residency (weights, activations)
        per level before converting the remainder to pages — e.g. the output
        of ``workload.resident_bytes`` routed through a placement. A tier
        with ``capacity=None`` has no physical page count; admission checks
        built on ``total_pages`` would be meaningless, so it raises unless
        the caller supplies an explicit ``uncapped_pages`` cap for it.

        ``shards``: head-sharded serving (DESIGN.md SS16). Each device of
        an N-way mesh holds 1/N of every page (its Hkv/N head slice), so
        the hierarchy describes ONE device and a page costs ``page_bytes /
        N`` against it — an N-device mesh admits ~N× the pages within the
        same per-chip fast budget (the paper's per-chip constraint, not a
        fictitious pooled one). Shards are symmetric, so one budget models
        every device.

        A KV tier that is a SIDE tier of the hierarchy (attached beside
        the chain via ``with_side_tier`` — the bonded chiplet in
        ``npu_hierarchy(chiplet=...)``) becomes a promotion-only level:
        leading side tiers set ``n_promote`` so fresh pages skip them and
        residency there is earned by the hot-page promotion pass."""
        if shards < 1:
            raise ValueError(f"shards ({shards}) must be >= 1")
        if cfg.n_kv_heads % shards:
            raise ValueError(f"shards ({shards}) must divide n_kv_heads "
                             f"({cfg.n_kv_heads})")
        pb = page_bytes(cfg, page_size, dtype_bytes) / shards
        reserve = reserve_bytes or {}
        tiers: List[Tuple[str, int]] = []
        for name in kv_tiers:
            try:
                lv = hier.level(name)
            except KeyError:
                continue
            cap = lv.capacity
            if cap is None:
                if uncapped_pages is None:
                    raise ValueError(
                        f"tier {name!r} has no capacity; pass an explicit "
                        f"uncapped_pages= cap (a made-up huge page count "
                        f"would make total_pages-based admission "
                        f"meaningless)")
                tiers.append((name, uncapped_pages))
                continue
            avail = max(cap - reserve.get(name, 0.0), 0.0)
            n = int(avail // pb)
            if n > 0:
                tiers.append((name, n))
        if not tiers:
            raise ValueError(
                f"no KV-eligible tier in {kv_tiers} can hold even one "
                f"{pb}-byte page")
        side = set(getattr(hier, "side_tiers", {}) or {})
        n_promote = 0
        while (n_promote < len(tiers) - 1
               and tiers[n_promote][0] in side):
            n_promote += 1
        return cls(tuple(tiers), n_promote=n_promote)


class PageAllocationError(RuntimeError):
    """Raised when the pool cannot satisfy an allocation (caller preempts)."""


@dataclass
class _SeqAlloc:
    pages: List[int] = field(default_factory=list)
    n_tokens: int = 0
    # tokens whose KV has actually been written ("landed"). Defaults to
    # n_tokens for direct-manager users (allocate == prefill imminent);
    # the chunked-prefill scheduler resets it via mark_written so pages
    # the prefill has not reached yet are capacity, not traffic.
    n_written: int = 0


@dataclass(frozen=True)
class PrefixAllocation:
    """Result of a prefix-aware allocation."""
    pages: Tuple[int, ...]       # the sequence's full page list
    n_cached: int                # leading tokens whose KV is already valid


@dataclass
class ResidencyPlan:
    """Pre-kernel half of the fetch-wait barrier (DESIGN.md SS17): tier
    swaps are done, write-back is charged, and the demand fetches are
    identified but NOT yet issued. Produced by ``plan_residency`` before
    a kernel launches; after the kernel the engine knows its measured
    compute time and calls ``charge_residency`` to issue the fetch —
    bulk, or layer-sliced when overlap is on — and convert only the
    un-hidden remainder into stall. Every plan must be charged exactly
    once (fetch byte accounting lives in the charge)."""
    seq_ids: Tuple[int, ...]
    need: List[int]              # content-bearing offload pages to fetch
    inflight_ready: float        # completion of earlier in-flight fetches


def _chain_digest(parent: bytes, block: Sequence[int]) -> bytes:
    """Position-aware content hash: a block's key commits to every token
    before it, so identical blocks at different depths never collide."""
    h = hashlib.sha256(parent)
    h.update(np.asarray(block, np.int64).tobytes())
    return h.digest()


class PagedKVManager:
    """Refcounted free-list page allocator with per-sequence page tables
    and an optional shared-prefix page cache.

    Invariants (tested): every page is free, evictable (cached at
    refcount 0), or referenced by >=1 sequence; ``n_free + n_evictable +
    n_used == n_pages - 1`` (page 0 reserved); a page's refcount equals the
    number of sequences holding it; ``free_seq`` drops exactly one
    reference per page the sequence held.
    """

    def __init__(self, n_pages: int, page_size: int, *,
                 tier_budget: Optional[TierBudget] = None,
                 enable_prefix_cache: bool = False,
                 dtype_bytes: int = 2,
                 page_nbytes: Optional[float] = None,
                 tier_device: Optional[SimulatedTierDevice] = None,
                 chiplet_device: Optional[SimulatedTierDevice] = None,
                 ema_decay: float = 0.5,
                 promote_threshold: float = 1.5,
                 tracer: Optional[object] = None):
        if tier_budget is not None:
            n_pages = min(n_pages, tier_budget.total_pages + 1)
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.tier_budget = tier_budget
        self.enable_prefix_cache = enable_prefix_cache
        # active KV element width (int8 cache -> 1); prices occupancy and
        # migration traffic — never hardcode 2 downstream of this
        self.dtype_bytes = dtype_bytes
        self.page_nbytes = float(page_nbytes or 0.0)
        self.tier_device = tier_device
        # optional TraceRecorder (SS15): prefetch hit/miss instants land on
        # the DMA-in track as they are consumed by the fetch-wait barrier
        self.tracer = tracer
        # --- per-page tier residency (SS13) --- #
        # every ASSIGNED page (referenced or cached-evictable) lives in
        # exactly one budget tier; free pages are unassigned
        self._tier: Dict[int, str] = {}
        self._tier_used: Dict[str, int] = (
            {name: 0 for name, _ in tier_budget.tiers}
            if tier_budget is not None else {})
        self._offload = (tier_budget.offload_tier
                         if tier_budget is not None else None)
        # promotion-only cache levels (SS17): the chiplet sits between the
        # base fast tier and the offload tier; residency there is earned
        # by the EMA pass below, never assigned fresh
        self._promote_set = (frozenset(n for n, _ in
                                       tier_budget.promote_tiers)
                             if tier_budget is not None else frozenset())
        self._chip = (tier_budget.tiers[0][0]
                      if tier_budget is not None and tier_budget.n_promote
                      else None)
        self._base = (tier_budget.base_tiers[0][0]
                      if tier_budget is not None else None)
        self.chiplet_device = chiplet_device
        self.ema_decay = ema_decay
        self.promote_threshold = promote_threshold
        self._ema: Dict[int, float] = {}      # page -> touch EMA
        self._ema_round: Dict[int, int] = {}  # page -> round of last bump
        self._round = 0                       # rebalance round counter
        self._lru: Dict[int, int] = {}        # page -> last-touch stamp
        self._stamp = 0
        self._ready_at: Dict[int, float] = {} # in-flight fetch completion
        self._fetch_pending: set = set()      # fetched, not yet waited on
        # dirty = content NOT mirrored at the offload tier: written since
        # allocation or since its last charged write-back. Spilling a
        # clean content page is a residency flip (the offload copy is
        # still valid) — only dirty content pays write-back bytes.
        self._dirty: set = set()
        # offload observability (engine folds these into ServeStats)
        self.spill_bytes = 0.0
        self.fetch_bytes = 0.0
        self.n_spills = 0
        self.n_fetches = 0
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.clean_demotions = 0   # content spills that skipped write-back
        self.chiplet_promotions = 0
        self.chiplet_demotions = 0
        # per-direction DMA bytes keyed "src->dst" at each link boundary
        # (reconciled against the trace's per-label span bytes)
        self.channel_bytes: Dict[str, float] = {}
        # landed-page reads per residency tier at each kernel barrier —
        # the chiplet hit-rate numerator/denominator
        self.tier_touches: Dict[str, int] = {}
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1
        self._seqs: Dict[int, _SeqAlloc] = {}
        self._ref: Dict[int, int] = {}                 # page -> refcount
        self._n_used = 0                               # O(1) distinct in-use
        # prefix cache: chain digest -> page; reverse map; per-parent
        # children (for partial-page matching); block token contents
        self._index: Dict[bytes, int] = {}
        self._page_key: Dict[int, bytes] = {}
        self._children: Dict[bytes, Dict[bytes, int]] = {}
        self._parent_key: Dict[bytes, bytes] = {}      # O(1) unregister
        self._block_tokens: Dict[bytes, Tuple[int, ...]] = {}
        self._evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        # device copies the engine must apply before the next KV write
        self._pending_copies: List[Tuple[int, int]] = []
        # observability (reset by the engine per serve)
        self.dedup_hits = 0        # pages reused instead of recomputed
        self.dedup_tokens = 0      # prompt tokens whose prefill was skipped
        self.cow_copies = 0
        self.evictions = 0

    # ------------------------------ queries ---------------------------- #
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_evictable(self) -> int:
        return len(self._evictable)

    @property
    def n_allocatable(self) -> int:
        """Pages an allocation may claim: free + evictable cached pages."""
        return len(self._free) + len(self._evictable)

    @property
    def n_used(self) -> int:
        """Distinct pages referenced by >=1 sequence. O(1) (maintained
        counter — this runs inside the per-step ``kv_tier_split`` path)."""
        return self._n_used

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int, headroom_pages: int = 0) -> bool:
        return (self.pages_needed(n_tokens) + headroom_pages
                <= self.n_allocatable)

    def fits_at_all(self, n_tokens: int) -> bool:
        """Could the request EVER run, with the whole pool to itself?"""
        return self.pages_needed(n_tokens) <= self.n_pages - 1

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def page_ref(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_cached(self, page: int) -> bool:
        return page in self._page_key

    # --------------------------- page lifecycle ------------------------ #
    def _take_page(self) -> int:
        """Claim a page: free list first, else evict the LRU cached page."""
        if self._free:
            return self._free.pop()
        if self._evictable:
            page, _ = self._evictable.popitem(last=False)
            self._unregister_page(page)
            self.evictions += 1
            # reused as a fresh page: its old residency is meaningless
            self._drop_residency(page)
            return page
        raise PageAllocationError("page pool exhausted")

    def _incref(self, page: int) -> None:
        if self._ref.get(page, 0) == 0:
            self._evictable.pop(page, None)   # revived from the cache
            self._n_used += 1
            if page not in self._tier:        # fresh claim: assign a tier
                self._assign_tier(page)
            else:                             # cache revival keeps its tier
                self._touch(page)
        self._ref[page] = self._ref.get(page, 0) + 1

    def _decref(self, page: int) -> None:
        r = self._ref[page] - 1
        if r < 0:
            raise AssertionError(f"page {page} double-freed")
        if r == 0:
            del self._ref[page]
            self._n_used -= 1
            if page in self._page_key:        # stays cached, evictable
                self._evictable[page] = None  # (keeps its tier residency)
                # cancel any in-flight fetch: the owner is gone, and a
                # stale pending entry would both shield the page from
                # spill forever and hand a later revival a phantom hit
                self._fetch_pending.discard(page)
                self._ready_at.pop(page, None)
            else:
                self._drop_residency(page)
                self._free.append(page)
        else:
            self._ref[page] = r

    def _unregister_page(self, page: int) -> None:
        """Eviction runs on the per-token allocation path — O(1)."""
        key = self._page_key.pop(page, None)
        if key is None:
            return
        self._index.pop(key, None)
        self._block_tokens.pop(key, None)
        parent = self._parent_key.pop(key)
        kids = self._children.get(parent)
        if kids is not None:
            kids.pop(key, None)
            if not kids:
                del self._children[parent]

    # --------------------------- tier residency ------------------------ #
    # Every assigned page lives in exactly one budget tier (DESIGN.md
    # SS13). New pages land in the fastest tier with room and overflow
    # into the offload (slowest) tier; the block-aligned rebalance below
    # swaps LRU-cold fast pages against the offload-resident pages a
    # scheduled sequence is about to attend over.

    def page_tier(self, page: int) -> Optional[str]:
        """Residency tier of an assigned page (None: free/untracked)."""
        return self._tier.get(page)

    def tier_occupancy_pages(self) -> Dict[str, int]:
        """Assigned pages per tier (referenced + cached-evictable)."""
        return dict(self._tier_used)

    @property
    def fast_pages_used(self) -> int:
        """Assigned pages resident in the non-offload tiers."""
        if self._offload is None:
            return sum(self._tier_used.values())
        return sum(n for t, n in self._tier_used.items()
                   if t != self._offload)

    def _touch(self, page: int) -> None:
        self._stamp += 1
        self._lru[page] = self._stamp

    def _drop_residency(self, page: int) -> None:
        tier = self._tier.pop(page, None)
        if tier is not None:
            self._tier_used[tier] -= 1
        self._lru.pop(page, None)
        self._ready_at.pop(page, None)
        self._fetch_pending.discard(page)
        self._dirty.discard(page)
        self._ema.pop(page, None)
        self._ema_round.pop(page, None)

    def _mark_dirty(self, pages) -> None:
        """Record that the given pages' content is (about to be) written
        and therefore no longer mirrored at the offload tier. Over-marking
        an empty page is harmless: write-back is only charged for victims
        that carry content AND are dirty."""
        self._dirty.update(pages)

    def _acct(self, src: Optional[str], dst: Optional[str],
              n_bytes: float) -> None:
        if src is None or dst is None or n_bytes <= 0:
            return
        key = make_label(src, dst)
        self.channel_bytes[key] = self.channel_bytes.get(key, 0.0) + n_bytes

    def _assign_tier(self, page: int) -> None:
        """Fastest BASE tier with budget room; overflow goes straight to
        the offload tier (no churn during bulk prefill allocation — the
        rebalance pass promotes what the kernels actually touch).
        Promotion-only levels are skipped — chiplet residency is earned
        by the EMA pass — except as a last resort when every base tier is
        full (the pool is clamped to total_pages, which includes the
        promote levels, so they must be able to absorb the tail)."""
        if self.tier_budget is None:
            return
        b = self.tier_budget
        for name, cap in b.tiers[b.n_promote:] + b.tiers[:b.n_promote]:
            if self._tier_used[name] < cap:
                self._tier[page] = name
                self._tier_used[name] += 1
                self._touch(page)
                return
        raise AssertionError(
            "page pool exceeds the tier budget (pool is clamped to "
            "total_pages + 1 at construction)")

    def _spill_victims(self, pinned: set) -> List[int]:
        """LRU-cold spill candidates, coldest first: BASE-fast-resident
        pages that are neither pinned by the sequences being prepared nor
        have a fetch in flight (demoting a page mid-migration would let
        its owner consume a stale hit and attend over it for free).
        Promotion-level residents are not spill capacity — they leave the
        chiplet only via LRU demotion back to the base tier. One sorted
        pass per rebalance, popped in order, instead of a full scan per
        needed page."""
        return [p for _, p in sorted(
            (self._lru.get(p, 0), p) for p, tier in self._tier.items()
            if tier != self._offload and tier not in self._promote_set
            and p not in pinned and p not in self._fetch_pending)]

    def _promote_pass(self, hot_candidates: set, now: float) -> None:
        """EMA hot-page promotion into the chiplet level (DESIGN.md SS17).

        Every rebalance round bumps a per-page touch EMA for the pinned
        LANDED pages (``ema = ema * decay^rounds_since + 1``); a
        base-tier-resident page whose EMA crosses the threshold — touched
        on consecutive rounds — is promoted into the chiplet, demoting
        the chiplet's LRU-cold unpinned resident back to the base tier
        when it is full (the swap keeps per-tier counts). Migrations are
        charged on the dedicated chiplet link ("in" promote / "out"
        demote) but never gate a kernel: the page stays readable in its
        source tier while the copy streams, so the charge is link
        occupancy and trace visibility, not stall."""
        if self._chip is None or not hot_candidates:
            return
        self._round += 1
        rnd = self._round
        chip = self._chip
        cap = dict(self.tier_budget.tiers)[chip]
        decay = self.ema_decay
        hot: List[Tuple[float, int]] = []
        for p in hot_candidates:
            last = self._ema_round.get(p, rnd)
            e = self._ema.get(p, 0.0) * (decay ** (rnd - last)) + 1.0
            self._ema[p] = e
            self._ema_round[p] = rnd
            tier = self._tier.get(p)
            if (e >= self.promote_threshold and tier is not None
                    and tier != chip and tier != self._offload):
                hot.append((e, p))
        if not hot:
            return
        hot.sort(reverse=True)
        cold = [p for _, p in sorted(
            (self._lru.get(p, 0), p) for p, t in self._tier.items()
            if t == chip and p not in hot_candidates
            and p not in self._fetch_pending)]
        ci = 0
        n_promoted = 0
        n_demoted = 0
        for _, p in hot:
            src = self._tier[p]
            if self._tier_used[chip] < cap:
                self._tier[p] = chip
                self._tier_used[src] -= 1
                self._tier_used[chip] += 1
            elif ci < len(cold):
                victim = cold[ci]
                ci += 1
                self._tier[victim] = src     # swap keeps per-tier counts
                self._tier[p] = chip
                n_demoted += 1
            else:
                break                        # chiplet full of hot pages
            n_promoted += 1
        pb = self.page_nbytes
        base = self._base
        if n_promoted:
            self.chiplet_promotions += n_promoted
            self._acct(base, chip, n_promoted * pb)
            if self.chiplet_device is not None:
                self.chiplet_device.transfer("in", n_promoted * pb, now,
                                             label=make_label(base, chip))
        if n_demoted:
            self.chiplet_demotions += n_demoted
            self._acct(chip, base, n_demoted * pb)
            if self.chiplet_device is not None:
                self.chiplet_device.transfer("out", n_demoted * pb, now,
                                             label=make_label(chip, base))

    def plan_residency(self, seq_ids: Sequence[int], now: float
                       ) -> ResidencyPlan:
        """Rebalance tiers for the given sequences' pages and charge the
        out-channel traffic, WITHOUT issuing the demand fetch: each
        offload-resident LANDED page swaps tiers with an LRU-cold
        unpinned base-fast page, and becomes a fetch the returned plan
        carries for ``charge_residency`` to issue. Traffic follows
        content, not capacity: reserved-but-unwritten pages (lookahead
        windows, un-prefilled tails) hold no KV, so they are pinned
        against spill and promoted for free when room remains, but never
        charge fetch bytes — mirroring the ``kv_tier_split`` landed-pages
        rule. A spill victim is only charged if it carries content
        (landed or cached-evictable) AND is dirty — a clean victim's
        offload copy is still valid, so its demotion is a free residency
        flip (``clean_demotions``). Pages that cannot fit — the pinned
        working set itself exceeds the fast budget — stay
        offload-resident and are *streamed*: the read is charged per
        block. Ends with the EMA chiplet promotion pass."""
        seq_ids = tuple(seq_ids)
        if self.tier_budget is None:
            return ResidencyPlan(seq_ids, [], now)
        landed = self._landed_pages()
        pinned: set = set()
        need: List[int] = []                 # content-bearing: charged
        empty: List[int] = []                # write targets: free promote
        for sid in seq_ids:
            for p in self._seqs[sid].pages:
                if p in pinned:
                    continue
                pinned.add(p)
                if self._offload is None:
                    continue
                if self._tier.get(p) != self._offload:
                    continue
                # skip pages whose fetch is already in flight (or landed
                # but not yet consumed by a wait) — re-issuing would
                # double-charge a streamed page per block
                if p in self._fetch_pending:
                    continue
                (need if p in landed else empty).append(p)
        ready = now
        for p in pinned:
            t = self._ready_at.get(p)
            if t is not None and t > ready:
                ready = t                    # prefetch still in flight
        if need or empty:
            victims = self._spill_victims(pinned)
            # evictable cached pages hold real KV too — spilling them costs
            content = landed | set(self._evictable)
            vi = 0
            n_spilled = 0
            n_clean = 0
            for p in need + empty:           # recurring reads fill first
                if vi >= len(victims):
                    break                    # fast full of pinned: stream
                victim = victims[vi]
                vi += 1
                fast_tier = self._tier[victim]
                self._tier[victim] = self._offload
                self._tier[p] = fast_tier    # swap keeps per-tier counts
                if victim in content:
                    if victim in self._dirty:
                        n_spilled += 1       # write-back: content diverged
                        self._dirty.discard(victim)
                    else:
                        n_clean += 1         # offload copy still valid
            for p in pinned:                 # touch AFTER victim selection
                self._touch(p)
            pb = self.page_nbytes
            if self.tier_device is not None and n_spilled:
                self.tier_device.transfer(
                    "out", n_spilled * pb, now,
                    label=make_label(self._base, self._offload))
            self.n_spills += n_spilled
            self.spill_bytes += n_spilled * pb
            self.clean_demotions += n_clean
            self._acct(self._base, self._offload, n_spilled * pb)
        self._promote_pass(pinned & landed, now)
        return ResidencyPlan(seq_ids, need, ready)

    def _issue_fetch(self, plan: ResidencyPlan, now: float,
                     n_slices: int = 1) -> List[float]:
        """Charge the plan's demand fetch on the in-channel — one bulk
        batch, or one chained descriptor of ``n_slices`` layer slices —
        and mark the pages in flight. Returns per-slice completion times
        (empty when the plan carries no fetch)."""
        need = plan.need
        if not need:
            return []
        pb = self.page_nbytes
        self.n_fetches += len(need)
        self.fetch_bytes += len(need) * pb
        self._acct(self._offload, self._base, len(need) * pb)
        label = make_label(self._offload, self._base)
        if self.tier_device is None:
            dones = [now]
        elif n_slices > 1:
            dones = self.tier_device.transfer_sliced(
                "in", len(need) * pb, now, n_slices, label=label)
        else:
            dones = [self.tier_device.transfer(
                "in", len(need) * pb, now, label=label)]
        for p in need:
            self._ready_at[p] = dones[-1]
            self._fetch_pending.add(p)
        return dones

    def _ensure_fast(self, seq_ids: Sequence[int], now: float
                     ) -> Tuple[float, int]:
        """Plan + bulk fetch in one step (the whole-block barrier shape):
        returns ``(ready_time, n_pages_fetched)``; ``ready_time`` also
        covers still-in-flight fetches issued by an earlier prefetch."""
        plan = self.plan_residency(seq_ids, now)
        dones = self._issue_fetch(plan, now)
        done = dones[-1] if dones else now
        return max(plan.inflight_ready, done), len(plan.need)

    def charge_residency(self, plan: ResidencyPlan, now: float, *,
                         n_slices: int = 1, compute_s: float = 0.0,
                         per_seq: Optional[Dict[int, float]] = None
                         ) -> Tuple[float, float]:
        """Post-kernel half of the fetch-wait barrier: issue the plan's
        demand fetch and return ``(stall, barrier_stall)``.

        With ``n_slices > 1`` and a measured ``compute_s`` the fetch is a
        chained descriptor of layer slices pipelined against the layer
        loop (SS17): layer ``l`` computes as soon as its slice has landed
        and the previous layer is done, so the stall is only the
        un-hidden remainder ``max(0, pipeline_end - (now + compute_s))``.
        ``barrier_stall`` is the whole-block counterfactual (what
        ``n_slices=1`` would have stalled) — never smaller, reported so
        the engine can attribute the savings. Consumes the prefetch
        hit/miss accounting and counts per-tier landed-page touches (the
        chiplet hit rate).

        ``per_seq`` (optional out-param) receives each sequence's OWN
        stall — its barrier wait scaled by the block's actual-to-barrier
        stall ratio, so per-request attribution still sums to the block's
        recorded stall under overlap (SS13 per-request accounting)."""
        dones = self._issue_fetch(
            plan, now, n_slices=n_slices if compute_s > 0 else 1)
        base_ready = max(plan.inflight_ready, now)
        bulk = dones[-1] if dones else now
        barrier_stall = max(0.0, max(base_ready, bulk) - now)
        if len(dones) > 1:
            c = compute_s / len(dones)
            t = now
            for d in dones:
                # layer l starts when its slice landed (inflight bulk
                # transfers from an earlier prefetch gate every layer)
                t = max(t, d, base_ready) + c
            stall = max(0.0, t - (now + compute_s))
        else:
            stall = barrier_stall
        if per_seq is not None:
            scale = (stall / barrier_stall) if barrier_stall > 1e-12 else 0.0
            for sid in plan.seq_ids:
                own = now
                for p in self._seqs[sid].pages:
                    t = self._ready_at.get(p)
                    if t is not None and t > own:
                        own = t
                per_seq[sid] = (per_seq.get(sid, 0.0)
                                + max(0.0, own - now) * scale)
        for sid in plan.seq_ids:
            s = self._seqs[sid]
            for p in s.pages[:self.pages_needed(s.n_written)]:
                tier = self._tier.get(p)
                if tier is not None:
                    self.tier_touches[tier] = (
                        self.tier_touches.get(tier, 0) + 1)
            for p in s.pages:
                if p not in self._fetch_pending:
                    continue
                self._fetch_pending.discard(p)
                hit = self._ready_at.get(p, now) <= now
                if hit:
                    self.prefetch_hits += 1
                    self._ready_at.pop(p, None)
                else:
                    self.prefetch_misses += 1
                if self.tracer is not None:
                    self.tracer.prefetch(p, hit, now)
        return stall, barrier_stall

    def prefetch_seqs(self, seq_ids: Sequence[int], now: float,
                      lookahead_seqs: Sequence[int] = ()) -> float:
        """Block-aligned prefetch, issued *ahead* of the fused decode loop:
        start migrating every page the given sequences attend over toward
        the fast tiers, without waiting. ``now`` may be backdated to the
        previous kernel's launch time so the transfer overlaps compute.
        Returns the virtual completion time.

        ``lookahead_seqs``: queue-aware prefetch beyond the next block.
        When the fetch channel is otherwise idle at ``now`` — the primary
        prefetch issued nothing and nothing earlier is still in flight —
        the deepest (most landed KV) scheduled sequence gets its pages
        promoted too, backdated to ``now``: typically the next prefill
        chunk's cached-prefix pages, migrating during the decode block
        that would otherwise leave the channel dark (ROADMAP item 5)."""
        ready, n_fetched = self._ensure_fast(seq_ids, now)
        if (lookahead_seqs and self.tier_device is not None
                and n_fetched == 0
                and self.tier_device.idle("in", now)):
            deepest = max(lookahead_seqs,
                          key=lambda s: self._seqs[s].n_written)
            self._ensure_fast([deepest], now)
        return ready

    def residency_stall(self, seq_ids: Sequence[int], now: float, *,
                        per_seq: Optional[Dict[int, float]] = None) -> float:
        """Fetch-wait barrier before a kernel launch: demand-fetches any
        page still offload-resident (a prefetch miss) and returns the
        stall the kernel must absorb until every page's migration
        completes. The whole-block-barrier composition of
        ``plan_residency`` + ``charge_residency`` — the engine's
        ``--no-layer-overlap`` baseline and the direct-manager API."""
        plan = self.plan_residency(seq_ids, now)
        stall, _ = self.charge_residency(plan, now, per_seq=per_seq)
        return stall

    # ---------------------------- allocation --------------------------- #
    def allocate(self, seq_id: int, n_tokens: int, *,
                 reserve_tokens: Optional[int] = None) -> List[int]:
        """Claim fresh pages for a prefill. Pages are sized for
        ``reserve_tokens`` (e.g. the page-aligned padded prompt) while
        ``n_tokens`` records the real sequence length. Raises on
        exhaustion."""
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already allocated")
        need = self.pages_needed(max(reserve_tokens or 0, n_tokens))
        if need > self.n_allocatable:
            raise PageAllocationError(
                f"need {need} pages for seq {seq_id}, "
                f"only {self.n_allocatable} allocatable")
        pages = []
        for _ in range(need):
            p = self._take_page()
            self._incref(p)
            pages.append(p)
        self._seqs[seq_id] = _SeqAlloc(pages=pages, n_tokens=n_tokens,
                                       n_written=n_tokens)
        self._mark_dirty(pages)      # fresh KV: nothing mirrored offload
        return list(pages)

    def allocate_shared(self, seq_id: int, tokens: Sequence[int], *,
                        reserve_tokens: Optional[int] = None
                        ) -> PrefixAllocation:
        """Prefix-aware allocation: reuse cached pages for the longest
        indexed prefix of ``tokens`` (full pages shared by reference,
        a partially-matching page copy-on-write), fresh pages for the rest.

        ``n_cached`` is capped at ``len(tokens) - 1`` so at least the last
        token is always recomputed (its logits seed generation). Raises on
        exhaustion with nothing claimed."""
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already allocated")
        ps = self.page_size
        n_tokens = len(tokens)
        if not self.enable_prefix_cache:
            pages = self.allocate(seq_id, n_tokens,
                                  reserve_tokens=reserve_tokens)
            return PrefixAllocation(tuple(pages), 0)

        # walk the chain over full blocks (cap: keep >=1 token to compute)
        shared: List[int] = []
        parent = b""
        for b in range((n_tokens - 1) // ps):
            key = _chain_digest(parent, tokens[b * ps:(b + 1) * ps])
            page = self._index.get(key)
            if page is None:
                break
            shared.append(page)
            parent = key
        n_cached = len(shared) * ps

        # partial-page match: a cached child block sharing a strict prefix
        # of the request's next block -> copy-on-write a private page
        # (a full-block match is impossible here — the chain walk above
        # would have taken it)
        cow_src: Optional[int] = None
        partial = 0
        rest = tuple(tokens[n_cached:n_cached + ps])
        for key in self._children.get(parent, {}):
            blk = self._block_tokens.get(key, ())
            t = 0
            for a, c in zip(blk, rest):
                if a != c:
                    break
                t += 1
            t = min(t, n_tokens - 1 - n_cached)
            if t > partial:
                cow_src, partial = self._index[key], t
        if partial <= 0:
            cow_src = None

        need_total = self.pages_needed(max(reserve_tokens or 0, n_tokens))

        # atomic claim: check capacity up front (reviving an evictable
        # shared page shrinks the allocatable set without a _take_page)
        need_fresh = need_total - len(shared)   # incl. the COW copy, if any
        revived = sum(1 for p in shared if p in self._evictable)
        if need_fresh + revived > self.n_allocatable:
            raise PageAllocationError(
                f"need {need_fresh} pages for seq {seq_id}, only "
                f"{self.n_allocatable - revived} allocatable")
        for p in shared:
            self._incref(p)
        pages = list(shared)
        if cow_src is not None:
            dst = self._take_page()
            self._incref(dst)
            self._pending_copies.append((cow_src, dst))
            self.cow_copies += 1
            pages.append(dst)
            need_fresh -= 1
        for _ in range(need_fresh):
            p = self._take_page()
            self._incref(p)
            pages.append(p)
        self._seqs[seq_id] = _SeqAlloc(pages=pages, n_tokens=n_tokens,
                                       n_written=n_tokens)
        # fresh + COW pages will be written; reused shared pages keep
        # whatever dirty state their history earned
        self._mark_dirty(pages[len(shared):])
        self.dedup_hits += len(shared)
        self.dedup_tokens += n_cached + partial
        return PrefixAllocation(tuple(pages), n_cached + partial)

    def ensure_writable(self, seq_id: int, pos: int
                        ) -> Optional[Tuple[int, int]]:
        """Make the page covering token ``pos`` privately writable.

        Shared pages (refcount > 1) are copied-on-write: a fresh page is
        claimed, the (src, dst) device copy is queued, and the sequence's
        table is rewritten. A cached-but-exclusive page is unregistered
        instead (writing would silently diverge it from its content hash).
        Returns the (src, dst) pair when a copy was made, else None."""
        s = self._seqs[seq_id]
        idx = pos // self.page_size
        page = s.pages[idx]
        if self._ref.get(page, 0) > 1:
            dst = self._take_page()
            self._incref(dst)
            self._decref(page)
            s.pages[idx] = dst
            self._pending_copies.append((page, dst))
            self.cow_copies += 1
            self._mark_dirty((dst,))
            return (page, dst)
        if page in self._page_key:
            self._unregister_page(page)
        self._mark_dirty((page,))    # about to be written in place
        return None

    # ------------------------ lookahead reservation --------------------- #
    def reserve_ahead(self, seq_id: int, k: int) -> List[int]:
        """All-or-nothing reservation for the next ``k`` token writes
        (DESIGN.md SS12): after this returns, positions ``[n_tokens,
        n_tokens + k)`` are page-backed and privately writable, so a fused
        K-step decode can scatter KV without host intervention. Claims
        fresh pages past the sequence's current extent, copies-on-write any
        shared page inside the write window (the copies land in
        ``drain_copies``), and unregisters exclusively-owned cached pages
        there (their content is about to diverge from their hash).

        Does NOT advance ``n_tokens`` — the host commits the block's actual
        write count afterwards (``commit_tokens``); a preempted or retired
        sequence releases everything via ``free_seq``. Raises on exhaustion
        with nothing claimed (the scheduler preempts and retries). Returns
        the newly claimed page ids (fresh + COW copies)."""
        s = self._seqs[seq_id]
        if k <= 0:
            return []
        ps = self.page_size
        need_total = self.pages_needed(s.n_tokens + k)
        first = s.n_tokens // ps
        window_have = range(first, min(len(s.pages), need_total))
        cow_idx = [i for i in window_have
                   if self._ref.get(s.pages[i], 0) > 1]
        n_fresh = max(need_total - len(s.pages), 0)
        if n_fresh + len(cow_idx) > self.n_allocatable:
            raise PageAllocationError(
                f"lookahead({k}) for seq {seq_id} needs "
                f"{n_fresh + len(cow_idx)} pages, only "
                f"{self.n_allocatable} allocatable")
        claimed: List[int] = []
        for i in cow_idx:
            src = s.pages[i]
            dst = self._take_page()
            self._incref(dst)
            self._decref(src)
            s.pages[i] = dst
            self._pending_copies.append((src, dst))
            self.cow_copies += 1
            claimed.append(dst)
        for i in window_have:         # now-private pages must leave the index
            if s.pages[i] in self._page_key:
                self._unregister_page(s.pages[i])
        for _ in range(n_fresh):
            p = self._take_page()
            self._incref(p)
            s.pages.append(p)
            claimed.append(p)
        # every page in the write window is about to diverge from any
        # offload mirror it had
        self._mark_dirty(s.pages[i] for i in window_have)
        self._mark_dirty(claimed)
        return claimed

    def commit_tokens(self, seq_id: int, n: int) -> None:
        """Advance the landed-KV length by ``n`` after a fused decode block
        wrote ``n`` tokens into previously reserved pages."""
        s = self._seqs[seq_id]
        if self.pages_needed(s.n_tokens + n) > len(s.pages):
            raise ValueError(
                f"commit of {n} tokens for seq {seq_id} exceeds its "
                f"reserved pages (reserve_ahead first)")
        lo = s.n_tokens // self.page_size
        s.n_tokens += n
        s.n_written = s.n_tokens
        self._mark_dirty(s.pages[lo:self.pages_needed(s.n_tokens)])

    def commit_speculative(self, seq_id: int, n_accepted: int) -> int:
        """Partial rollback after a speculative verify pass (DESIGN.md
        SS14): the pass reserved ``draft_len + 1`` positions and wrote KV
        for every fed token, but only ``n_accepted`` of them (accepted
        draft prefix + the corrected/bonus token) survive. Commit those
        and return every reserved page past the new landed extent to the
        pool — the rejected suffix's KV stays as garbage inside still-
        owned pages (overwritten by the next pass before any read) or on
        released pages (reclaimable immediately).

        Returns the number of pages rolled back. Equivalent to
        ``commit_tokens(n_accepted)`` + ``release_reserved()``; a single
        entry point so the invariant "landed extent == emitted tokens"
        cannot be split across a preemption window."""
        self.commit_tokens(seq_id, n_accepted)
        return self.release_reserved(seq_id)

    def mark_written(self, seq_id: int, n: int) -> None:
        """Set the landed-KV extent to ``n`` tokens (clamped to the
        tracked length). The chunked-prefill scheduler resets this to the
        cached-prefix length at admission and advances it per chunk, so
        pages the prefill has not reached yet are priced as capacity, not
        attention/migration traffic (the ``_landed_pages`` rule)."""
        s = self._seqs[seq_id]
        lo = s.n_written // self.page_size
        s.n_written = max(0, min(n, s.n_tokens))
        if s.n_written > lo * self.page_size:
            self._mark_dirty(s.pages[lo:self.pages_needed(s.n_written)])

    def release_reserved(self, seq_id: int) -> int:
        """Return reserved-but-unwritten pages (past the landed extent) to
        the pool; the inverse of ``reserve_ahead`` for a sequence that
        stays resident. Preemption/retirement need no explicit release —
        ``free_seq`` drops reserved pages with the rest."""
        s = self._seqs[seq_id]
        keep = self.pages_needed(s.n_tokens)
        n = 0
        while len(s.pages) > keep:
            self._decref(s.pages.pop())
            n += 1
        return n

    def append_token(self, seq_id: int) -> Optional[int]:
        """Extend a sequence by one token; returns the newly claimed page id
        when a page boundary is crossed, else None. Writes into a shared
        page trigger copy-on-write (the copy lands in ``drain_copies``).
        Raises on exhaustion (the scheduler preempts and retries)."""
        s = self._seqs[seq_id]
        new_page = None
        if self.pages_needed(s.n_tokens + 1) > len(s.pages):
            new_page = self._take_page()
            self._incref(new_page)
            s.pages.append(new_page)
            self._mark_dirty((new_page,))
        else:
            self.ensure_writable(seq_id, s.n_tokens)  # marks dirty
        s.n_tokens += 1
        s.n_written = s.n_tokens
        return new_page

    def free_seq(self, seq_id: int) -> int:
        """Drop a retired/preempted sequence's references. Cached pages
        whose refcount hits zero become evictable; the rest return to the
        free list. Pages are released deepest-first so LRU eviction
        reclaims the END of a cached chain before its head — a chain is
        only matchable through its prefix, so head pages are the valuable
        ones."""
        s = self._seqs.pop(seq_id)
        if self._pending_copies:
            # purge queued COW copies targeting this sequence's pages: the
            # dst was private to it, and once released it may be re-claimed
            # and re-targeted before the engine drains — duplicate dst
            # entries in one copy_pages batch scatter in undefined order
            released = set(s.pages)
            self._pending_copies = [(src, dst) for src, dst
                                    in self._pending_copies
                                    if dst not in released]
        for p in reversed(s.pages):
            self._decref(p)
        return len(s.pages)

    def drain_copies(self) -> List[Tuple[int, int]]:
        """(src, dst) page copies queued by COW since the last drain. The
        engine must apply them to the device pool before the next write."""
        out, self._pending_copies = self._pending_copies, []
        return out

    # --------------------------- prefix cache -------------------------- #
    def register_prefix(self, seq_id: int, tokens: Sequence[int],
                        n_valid: Optional[int] = None) -> int:
        """Index the sequence's full pages under their chained block hashes
        so later prompts can reuse them. ``n_valid`` caps how many leading
        tokens actually hold valid KV (defaults to the tracked length).
        Returns the number of newly indexed pages."""
        if not self.enable_prefix_cache:
            return 0
        s = self._seqs[seq_id]
        limit = min(len(tokens), s.n_tokens,
                    n_valid if n_valid is not None else s.n_tokens)
        ps = self.page_size
        parent = b""
        added = 0
        for b in range(limit // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            key = _chain_digest(parent, block)
            if key not in self._index:
                page = s.pages[b]
                if page in self._page_key:
                    # page already indexed under another chain (e.g. the
                    # request itself reused it) — leave that entry alone
                    parent = key
                    continue
                self._index[key] = page
                self._page_key[page] = key
                self._children.setdefault(parent, {})[key] = page
                self._parent_key[key] = parent
                self._block_tokens[key] = block
                added += 1
            parent = key
        return added

    def lookup_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens of ``tokens`` a prefix-aware allocation would reuse
        (full-page matches only; does not claim anything)."""
        if not self.enable_prefix_cache:
            return 0
        ps = self.page_size
        parent = b""
        n = 0
        for b in range(min(len(tokens) // ps, (len(tokens) - 1) // ps)):
            key = _chain_digest(parent, tokens[b * ps:(b + 1) * ps])
            if key not in self._index:
                break
            n += ps
            parent = key
        return n

    # --------------------------- table export -------------------------- #
    def table_row(self, seq_id: int, n_pages_per_seq: int) -> np.ndarray:
        """Padded int32 page-table row (null page 0 past the last page)."""
        pages = self._seqs[seq_id].pages
        row = np.zeros((n_pages_per_seq,), np.int32)
        row[:len(pages)] = pages
        return row

    # --------------------------- tier feedback ------------------------- #
    def _landed_pages(self) -> set:
        """Pages holding written KV a kernel would read: each sequence's
        pages up to its written extent. Reserved-but-unwritten lookahead
        pages (``reserve_ahead``) and prompt pages the chunked prefill has
        not reached yet (``mark_written``) are excluded — they occupy
        capacity but carry no attention traffic, so pricing them would
        overstate the traffic mass. Shared pages count once."""
        landed: set = set()
        for s in self._seqs.values():
            landed.update(s.pages[:self.pages_needed(s.n_written)])
        return landed

    def kv_tier_split(self) -> Tuple[Tuple[str, float], ...]:
        """Landed pages as a tier split, by REAL per-page residency.

        Matches the ``Placement.splits`` shape so the analytical model can
        price attention traffic with the tier placement the runtime pool
        actually produced (spills, prefetches and all) — not an analytic
        fast-tier-first fill. Shared pages count once — prefix dedup
        shrinks the split's mass; reserved lookahead pages are capacity,
        not traffic, and are excluded."""
        if self.tier_budget is None:
            raise ValueError(
                "kv_tier_split() needs tier information: construct the "
                "manager with tier_budget=TierBudget.from_hierarchy(...)")
        landed = self._landed_pages()
        if not landed:
            return ()
        counts: Dict[str, int] = {}
        for p in landed:
            tier = self._tier.get(p)
            if tier is not None:
                counts[tier] = counts.get(tier, 0) + 1
        total = len(landed)
        return tuple((name, counts[name] / total)
                     for name, _ in self.tier_budget.tiers
                     if counts.get(name))

    def tier_occupancy_bytes(self, cfg: Optional[ArchConfig] = None,
                             dtype_bytes: Optional[int] = None
                             ) -> Dict[str, float]:
        """Landed-KV bytes per tier, priced at the ACTIVE cache width
        (``self.dtype_bytes``, e.g. 1 for an int8 cache) unless the caller
        overrides — an int8 pool must not be priced at bf16 widths."""
        if self.page_nbytes and dtype_bytes is None:
            pb = self.page_nbytes
        else:
            if cfg is None:
                raise ValueError("pass cfg= (or construct the manager with "
                                 "page_nbytes=) to price occupancy")
            pb = page_bytes(cfg, self.page_size,
                            self.dtype_bytes if dtype_bytes is None
                            else dtype_bytes)
        n_landed = len(self._landed_pages())
        return {name: frac * n_landed * pb
                for name, frac in self.kv_tier_split()}
