"""Paged, tiered KV-cache manager (DESIGN.md SS10).

The runtime half of the paper's capacity-pressure story: the KV cache is a
pool of fixed-size pages shared by all in-flight sequences, indirected
through per-sequence page tables. A ``TierBudget`` derived from a
``repro.core.MemoryHierarchy`` caps the pool at what the hierarchy's KV
tiers can physically hold, and reports the pool's occupancy *as a tier
split* — the same ``((level, fraction), ...)`` shape the analytical
placement model consumes — so runtime admission pressure and analytical
spill predictions are computed from one source of truth.

Host-side bookkeeping is plain Python (free list + dicts); the page pool
arrays themselves live in the model cache (``models.init_paged_cache``).
Page 0 is reserved as the null page: padded page-table entries point at it,
inactive slots write into it, and nothing ever reads it unmasked.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig

# tiers a KV page may occupy, preferred (fastest) first; mirrors the
# placement policies in repro.core.placement
DEFAULT_KV_TIERS = ("chiplet", "ddr", "hbs")


def page_bytes(cfg: ArchConfig, page_size: int, dtype_bytes: int = 2) -> int:
    """Bytes one KV page holds across all layers (k + v)."""
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * dtype_bytes
    return per_tok * page_size


@dataclass(frozen=True)
class TierBudget:
    """Per-tier page counts, preferred (fastest) tier first."""
    tiers: Tuple[Tuple[str, int], ...]     # ((level_name, n_pages), ...)

    @property
    def total_pages(self) -> int:
        return sum(n for _, n in self.tiers)

    @classmethod
    def from_hierarchy(cls, hier, cfg: ArchConfig, page_size: int,
                       dtype_bytes: int = 2,
                       kv_tiers: Sequence[str] = DEFAULT_KV_TIERS,
                       reserve_bytes: Dict[str, float] = None) -> "TierBudget":
        """Pages per tier from the hierarchy's KV-eligible capacities.

        ``reserve_bytes`` subtracts non-KV residency (weights, activations)
        per level before converting the remainder to pages — e.g. the output
        of ``workload.resident_bytes`` routed through a placement."""
        pb = page_bytes(cfg, page_size, dtype_bytes)
        reserve = reserve_bytes or {}
        tiers: List[Tuple[str, int]] = []
        for name in kv_tiers:
            try:
                lv = hier.level(name)
            except KeyError:
                continue
            cap = lv.capacity
            if cap is None:
                tiers.append((name, 1 << 30))
                continue
            avail = max(cap - reserve.get(name, 0.0), 0.0)
            n = int(avail // pb)
            if n > 0:
                tiers.append((name, n))
        if not tiers:
            raise ValueError(
                f"no KV-eligible tier in {kv_tiers} can hold even one "
                f"{pb}-byte page")
        return cls(tuple(tiers))


class PageAllocationError(RuntimeError):
    """Raised when the pool cannot satisfy an allocation (caller preempts)."""


@dataclass
class _SeqAlloc:
    pages: List[int] = field(default_factory=list)
    n_tokens: int = 0


class PagedKVManager:
    """Free-list page allocator with per-sequence page tables.

    Invariants (tested): every page is either free or owned by exactly one
    sequence; ``n_free + n_used == n_pages - 1`` (page 0 reserved);
    ``free_seq`` returns every page a sequence owned.
    """

    def __init__(self, n_pages: int, page_size: int, *,
                 tier_budget: Optional[TierBudget] = None):
        if tier_budget is not None:
            n_pages = min(n_pages, tier_budget.total_pages + 1)
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.tier_budget = tier_budget
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1
        self._seqs: Dict[int, _SeqAlloc] = {}

    # ------------------------------ queries ---------------------------- #
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return sum(len(s.pages) for s in self._seqs.values())

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int, headroom_pages: int = 0) -> bool:
        return self.pages_needed(n_tokens) + headroom_pages <= self.n_free

    def fits_at_all(self, n_tokens: int) -> bool:
        """Could the request EVER run, with the whole pool to itself?"""
        return self.pages_needed(n_tokens) <= self.n_pages - 1

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    # ---------------------------- allocation --------------------------- #
    def allocate(self, seq_id: int, n_tokens: int, *,
                 reserve_tokens: Optional[int] = None) -> List[int]:
        """Claim pages for a prefill. Pages are sized for ``reserve_tokens``
        (e.g. the page-aligned padded prompt) while ``n_tokens`` records the
        real sequence length. Raises on exhaustion."""
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already allocated")
        need = self.pages_needed(max(reserve_tokens or 0, n_tokens))
        if need > self.n_free:
            raise PageAllocationError(
                f"need {need} pages for seq {seq_id}, only {self.n_free} free")
        pages = [self._free.pop() for _ in range(need)]
        self._seqs[seq_id] = _SeqAlloc(pages=pages, n_tokens=n_tokens)
        return list(pages)

    def append_token(self, seq_id: int) -> Optional[int]:
        """Extend a sequence by one token; returns the newly claimed page id
        when a page boundary is crossed, else None. Raises on exhaustion
        (the scheduler preempts and retries)."""
        s = self._seqs[seq_id]
        new_page = None
        if self.pages_needed(s.n_tokens + 1) > len(s.pages):
            if not self._free:
                raise PageAllocationError(
                    f"page pool exhausted extending seq {seq_id}")
            new_page = self._free.pop()
            s.pages.append(new_page)
        s.n_tokens += 1
        return new_page

    def free_seq(self, seq_id: int) -> int:
        """Release all pages of a retired/preempted sequence."""
        s = self._seqs.pop(seq_id)
        self._free.extend(s.pages)
        return len(s.pages)

    # --------------------------- table export -------------------------- #
    def table_row(self, seq_id: int, n_pages_per_seq: int) -> np.ndarray:
        """Padded int32 page-table row (null page 0 past the last page)."""
        pages = self._seqs[seq_id].pages
        row = np.zeros((n_pages_per_seq,), np.int32)
        row[:len(pages)] = pages
        return row

    # --------------------------- tier feedback ------------------------- #
    def kv_tier_split(self) -> Tuple[Tuple[str, float], ...]:
        """Occupied pages as a tier split, fast tier filled first.

        Matches the ``Placement.splits`` shape so the analytical model can
        price attention traffic with the runtime pool's actual placement."""
        used = self.n_used
        if not used:
            return ()
        if self.tier_budget is None:
            raise ValueError(
                "kv_tier_split() needs tier information: construct the "
                "manager with tier_budget=TierBudget.from_hierarchy(...)")
        out: List[Tuple[str, float]] = []
        rem = used
        for name, cap in self.tier_budget.tiers:
            take = min(rem, cap)
            if take > 0:
                out.append((name, take / used))
                rem -= take
            if rem == 0:
                break
        return tuple(out)

    def tier_occupancy_bytes(self, cfg: ArchConfig, dtype_bytes: int = 2
                             ) -> Dict[str, float]:
        pb = page_bytes(cfg, self.page_size, dtype_bytes)
        return {name: frac * self.n_used * pb
                for name, frac in self.kv_tier_split()}
