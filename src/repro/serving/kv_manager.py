"""Paged, tiered KV-cache manager with shared-prefix page reuse
(DESIGN.md SS10/SS11).

The runtime half of the paper's capacity-pressure story: the KV cache is a
pool of fixed-size pages shared by all in-flight sequences, indirected
through per-sequence page tables. A ``TierBudget`` derived from a
``repro.core.MemoryHierarchy`` caps the pool at what the hierarchy's KV
tiers can physically hold, and reports the pool's occupancy *as a tier
split* — the same ``((level, fraction), ...)`` shape the analytical
placement model consumes — so runtime admission pressure and analytical
spill predictions are computed from one source of truth.

Prefix sharing (SS11) attacks the capacity term directly: pages are
refcounted, full pages of completed prefixes are registered in a
hash-chained index (block content + every block before it), and a new
request whose prompt matches a chain *reuses the physical pages* instead
of recomputing and re-storing identical KV. Divergence mid-page is handled
copy-on-write: the manager hands the sequence a private copy of the
partially-matching page and records the (src, dst) device copy for the
engine to apply. Retired prefixes stay cached at refcount 0 (evictable,
LRU) until allocation pressure reclaims them.

Host-side bookkeeping is plain Python (free list + dicts); the page pool
arrays themselves live in the model cache (``models.init_paged_cache``).
Page 0 is reserved as the null page: padded page-table entries point at it,
inactive slots write into it, and nothing ever reads it unmasked.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig

# tiers a KV page may occupy, preferred (fastest) first; mirrors the
# placement policies in repro.core.placement
DEFAULT_KV_TIERS = ("chiplet", "ddr", "hbs")


def page_bytes(cfg: ArchConfig, page_size: int, dtype_bytes: int = 2) -> int:
    """Bytes one KV page holds across all layers (k + v)."""
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers * dtype_bytes
    return per_tok * page_size


@dataclass(frozen=True)
class TierBudget:
    """Per-tier page counts, preferred (fastest) tier first."""
    tiers: Tuple[Tuple[str, int], ...]     # ((level_name, n_pages), ...)

    @property
    def total_pages(self) -> int:
        return sum(n for _, n in self.tiers)

    @classmethod
    def from_hierarchy(cls, hier, cfg: ArchConfig, page_size: int,
                       dtype_bytes: int = 2,
                       kv_tiers: Sequence[str] = DEFAULT_KV_TIERS,
                       reserve_bytes: Dict[str, float] = None) -> "TierBudget":
        """Pages per tier from the hierarchy's KV-eligible capacities.

        ``reserve_bytes`` subtracts non-KV residency (weights, activations)
        per level before converting the remainder to pages — e.g. the output
        of ``workload.resident_bytes`` routed through a placement."""
        pb = page_bytes(cfg, page_size, dtype_bytes)
        reserve = reserve_bytes or {}
        tiers: List[Tuple[str, int]] = []
        for name in kv_tiers:
            try:
                lv = hier.level(name)
            except KeyError:
                continue
            cap = lv.capacity
            if cap is None:
                tiers.append((name, 1 << 30))
                continue
            avail = max(cap - reserve.get(name, 0.0), 0.0)
            n = int(avail // pb)
            if n > 0:
                tiers.append((name, n))
        if not tiers:
            raise ValueError(
                f"no KV-eligible tier in {kv_tiers} can hold even one "
                f"{pb}-byte page")
        return cls(tuple(tiers))


class PageAllocationError(RuntimeError):
    """Raised when the pool cannot satisfy an allocation (caller preempts)."""


@dataclass
class _SeqAlloc:
    pages: List[int] = field(default_factory=list)
    n_tokens: int = 0


@dataclass(frozen=True)
class PrefixAllocation:
    """Result of a prefix-aware allocation."""
    pages: Tuple[int, ...]       # the sequence's full page list
    n_cached: int                # leading tokens whose KV is already valid


def _chain_digest(parent: bytes, block: Sequence[int]) -> bytes:
    """Position-aware content hash: a block's key commits to every token
    before it, so identical blocks at different depths never collide."""
    h = hashlib.sha256(parent)
    h.update(np.asarray(block, np.int64).tobytes())
    return h.digest()


class PagedKVManager:
    """Refcounted free-list page allocator with per-sequence page tables
    and an optional shared-prefix page cache.

    Invariants (tested): every page is free, evictable (cached at
    refcount 0), or referenced by >=1 sequence; ``n_free + n_evictable +
    n_used == n_pages - 1`` (page 0 reserved); a page's refcount equals the
    number of sequences holding it; ``free_seq`` drops exactly one
    reference per page the sequence held.
    """

    def __init__(self, n_pages: int, page_size: int, *,
                 tier_budget: Optional[TierBudget] = None,
                 enable_prefix_cache: bool = False):
        if tier_budget is not None:
            n_pages = min(n_pages, tier_budget.total_pages + 1)
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.tier_budget = tier_budget
        self.enable_prefix_cache = enable_prefix_cache
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1
        self._seqs: Dict[int, _SeqAlloc] = {}
        self._ref: Dict[int, int] = {}                 # page -> refcount
        self._n_used = 0                               # O(1) distinct in-use
        # prefix cache: chain digest -> page; reverse map; per-parent
        # children (for partial-page matching); block token contents
        self._index: Dict[bytes, int] = {}
        self._page_key: Dict[int, bytes] = {}
        self._children: Dict[bytes, Dict[bytes, int]] = {}
        self._parent_key: Dict[bytes, bytes] = {}      # O(1) unregister
        self._block_tokens: Dict[bytes, Tuple[int, ...]] = {}
        self._evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        # device copies the engine must apply before the next KV write
        self._pending_copies: List[Tuple[int, int]] = []
        # observability (reset by the engine per serve)
        self.dedup_hits = 0        # pages reused instead of recomputed
        self.dedup_tokens = 0      # prompt tokens whose prefill was skipped
        self.cow_copies = 0
        self.evictions = 0

    # ------------------------------ queries ---------------------------- #
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_evictable(self) -> int:
        return len(self._evictable)

    @property
    def n_allocatable(self) -> int:
        """Pages an allocation may claim: free + evictable cached pages."""
        return len(self._free) + len(self._evictable)

    @property
    def n_used(self) -> int:
        """Distinct pages referenced by >=1 sequence. O(1) (maintained
        counter — this runs inside the per-step ``kv_tier_split`` path)."""
        return self._n_used

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int, headroom_pages: int = 0) -> bool:
        return (self.pages_needed(n_tokens) + headroom_pages
                <= self.n_allocatable)

    def fits_at_all(self, n_tokens: int) -> bool:
        """Could the request EVER run, with the whole pool to itself?"""
        return self.pages_needed(n_tokens) <= self.n_pages - 1

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].n_tokens

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def page_ref(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_cached(self, page: int) -> bool:
        return page in self._page_key

    # --------------------------- page lifecycle ------------------------ #
    def _take_page(self) -> int:
        """Claim a page: free list first, else evict the LRU cached page."""
        if self._free:
            return self._free.pop()
        if self._evictable:
            page, _ = self._evictable.popitem(last=False)
            self._unregister_page(page)
            self.evictions += 1
            return page
        raise PageAllocationError("page pool exhausted")

    def _incref(self, page: int) -> None:
        if self._ref.get(page, 0) == 0:
            self._evictable.pop(page, None)   # revived from the cache
            self._n_used += 1
        self._ref[page] = self._ref.get(page, 0) + 1

    def _decref(self, page: int) -> None:
        r = self._ref[page] - 1
        if r < 0:
            raise AssertionError(f"page {page} double-freed")
        if r == 0:
            del self._ref[page]
            self._n_used -= 1
            if page in self._page_key:        # stays cached, evictable
                self._evictable[page] = None
            else:
                self._free.append(page)
        else:
            self._ref[page] = r

    def _unregister_page(self, page: int) -> None:
        """Eviction runs on the per-token allocation path — O(1)."""
        key = self._page_key.pop(page, None)
        if key is None:
            return
        self._index.pop(key, None)
        self._block_tokens.pop(key, None)
        parent = self._parent_key.pop(key)
        kids = self._children.get(parent)
        if kids is not None:
            kids.pop(key, None)
            if not kids:
                del self._children[parent]

    # ---------------------------- allocation --------------------------- #
    def allocate(self, seq_id: int, n_tokens: int, *,
                 reserve_tokens: Optional[int] = None) -> List[int]:
        """Claim fresh pages for a prefill. Pages are sized for
        ``reserve_tokens`` (e.g. the page-aligned padded prompt) while
        ``n_tokens`` records the real sequence length. Raises on
        exhaustion."""
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already allocated")
        need = self.pages_needed(max(reserve_tokens or 0, n_tokens))
        if need > self.n_allocatable:
            raise PageAllocationError(
                f"need {need} pages for seq {seq_id}, "
                f"only {self.n_allocatable} allocatable")
        pages = []
        for _ in range(need):
            p = self._take_page()
            self._incref(p)
            pages.append(p)
        self._seqs[seq_id] = _SeqAlloc(pages=pages, n_tokens=n_tokens)
        return list(pages)

    def allocate_shared(self, seq_id: int, tokens: Sequence[int], *,
                        reserve_tokens: Optional[int] = None
                        ) -> PrefixAllocation:
        """Prefix-aware allocation: reuse cached pages for the longest
        indexed prefix of ``tokens`` (full pages shared by reference,
        a partially-matching page copy-on-write), fresh pages for the rest.

        ``n_cached`` is capped at ``len(tokens) - 1`` so at least the last
        token is always recomputed (its logits seed generation). Raises on
        exhaustion with nothing claimed."""
        if seq_id in self._seqs:
            raise ValueError(f"sequence {seq_id} already allocated")
        ps = self.page_size
        n_tokens = len(tokens)
        if not self.enable_prefix_cache:
            pages = self.allocate(seq_id, n_tokens,
                                  reserve_tokens=reserve_tokens)
            return PrefixAllocation(tuple(pages), 0)

        # walk the chain over full blocks (cap: keep >=1 token to compute)
        shared: List[int] = []
        parent = b""
        for b in range((n_tokens - 1) // ps):
            key = _chain_digest(parent, tokens[b * ps:(b + 1) * ps])
            page = self._index.get(key)
            if page is None:
                break
            shared.append(page)
            parent = key
        n_cached = len(shared) * ps

        # partial-page match: a cached child block sharing a strict prefix
        # of the request's next block -> copy-on-write a private page
        # (a full-block match is impossible here — the chain walk above
        # would have taken it)
        cow_src: Optional[int] = None
        partial = 0
        rest = tuple(tokens[n_cached:n_cached + ps])
        for key in self._children.get(parent, {}):
            blk = self._block_tokens.get(key, ())
            t = 0
            for a, c in zip(blk, rest):
                if a != c:
                    break
                t += 1
            t = min(t, n_tokens - 1 - n_cached)
            if t > partial:
                cow_src, partial = self._index[key], t
        if partial <= 0:
            cow_src = None

        need_total = self.pages_needed(max(reserve_tokens or 0, n_tokens))

        # atomic claim: check capacity up front (reviving an evictable
        # shared page shrinks the allocatable set without a _take_page)
        need_fresh = need_total - len(shared)   # incl. the COW copy, if any
        revived = sum(1 for p in shared if p in self._evictable)
        if need_fresh + revived > self.n_allocatable:
            raise PageAllocationError(
                f"need {need_fresh} pages for seq {seq_id}, only "
                f"{self.n_allocatable - revived} allocatable")
        for p in shared:
            self._incref(p)
        pages = list(shared)
        if cow_src is not None:
            dst = self._take_page()
            self._incref(dst)
            self._pending_copies.append((cow_src, dst))
            self.cow_copies += 1
            pages.append(dst)
            need_fresh -= 1
        for _ in range(need_fresh):
            p = self._take_page()
            self._incref(p)
            pages.append(p)
        self._seqs[seq_id] = _SeqAlloc(pages=pages, n_tokens=n_tokens)
        self.dedup_hits += len(shared)
        self.dedup_tokens += n_cached + partial
        return PrefixAllocation(tuple(pages), n_cached + partial)

    def ensure_writable(self, seq_id: int, pos: int
                        ) -> Optional[Tuple[int, int]]:
        """Make the page covering token ``pos`` privately writable.

        Shared pages (refcount > 1) are copied-on-write: a fresh page is
        claimed, the (src, dst) device copy is queued, and the sequence's
        table is rewritten. A cached-but-exclusive page is unregistered
        instead (writing would silently diverge it from its content hash).
        Returns the (src, dst) pair when a copy was made, else None."""
        s = self._seqs[seq_id]
        idx = pos // self.page_size
        page = s.pages[idx]
        if self._ref.get(page, 0) > 1:
            dst = self._take_page()
            self._incref(dst)
            self._decref(page)
            s.pages[idx] = dst
            self._pending_copies.append((page, dst))
            self.cow_copies += 1
            return (page, dst)
        if page in self._page_key:
            self._unregister_page(page)
        return None

    # ------------------------ lookahead reservation --------------------- #
    def reserve_ahead(self, seq_id: int, k: int) -> List[int]:
        """All-or-nothing reservation for the next ``k`` token writes
        (DESIGN.md SS12): after this returns, positions ``[n_tokens,
        n_tokens + k)`` are page-backed and privately writable, so a fused
        K-step decode can scatter KV without host intervention. Claims
        fresh pages past the sequence's current extent, copies-on-write any
        shared page inside the write window (the copies land in
        ``drain_copies``), and unregisters exclusively-owned cached pages
        there (their content is about to diverge from their hash).

        Does NOT advance ``n_tokens`` — the host commits the block's actual
        write count afterwards (``commit_tokens``); a preempted or retired
        sequence releases everything via ``free_seq``. Raises on exhaustion
        with nothing claimed (the scheduler preempts and retries). Returns
        the newly claimed page ids (fresh + COW copies)."""
        s = self._seqs[seq_id]
        if k <= 0:
            return []
        ps = self.page_size
        need_total = self.pages_needed(s.n_tokens + k)
        first = s.n_tokens // ps
        window_have = range(first, min(len(s.pages), need_total))
        cow_idx = [i for i in window_have
                   if self._ref.get(s.pages[i], 0) > 1]
        n_fresh = max(need_total - len(s.pages), 0)
        if n_fresh + len(cow_idx) > self.n_allocatable:
            raise PageAllocationError(
                f"lookahead({k}) for seq {seq_id} needs "
                f"{n_fresh + len(cow_idx)} pages, only "
                f"{self.n_allocatable} allocatable")
        claimed: List[int] = []
        for i in cow_idx:
            src = s.pages[i]
            dst = self._take_page()
            self._incref(dst)
            self._decref(src)
            s.pages[i] = dst
            self._pending_copies.append((src, dst))
            self.cow_copies += 1
            claimed.append(dst)
        for i in window_have:         # now-private pages must leave the index
            if s.pages[i] in self._page_key:
                self._unregister_page(s.pages[i])
        for _ in range(n_fresh):
            p = self._take_page()
            self._incref(p)
            s.pages.append(p)
            claimed.append(p)
        return claimed

    def commit_tokens(self, seq_id: int, n: int) -> None:
        """Advance the landed-KV length by ``n`` after a fused decode block
        wrote ``n`` tokens into previously reserved pages."""
        s = self._seqs[seq_id]
        if self.pages_needed(s.n_tokens + n) > len(s.pages):
            raise ValueError(
                f"commit of {n} tokens for seq {seq_id} exceeds its "
                f"reserved pages (reserve_ahead first)")
        s.n_tokens += n

    def release_reserved(self, seq_id: int) -> int:
        """Return reserved-but-unwritten pages (past the landed extent) to
        the pool; the inverse of ``reserve_ahead`` for a sequence that
        stays resident. Preemption/retirement need no explicit release —
        ``free_seq`` drops reserved pages with the rest."""
        s = self._seqs[seq_id]
        keep = self.pages_needed(s.n_tokens)
        n = 0
        while len(s.pages) > keep:
            self._decref(s.pages.pop())
            n += 1
        return n

    def append_token(self, seq_id: int) -> Optional[int]:
        """Extend a sequence by one token; returns the newly claimed page id
        when a page boundary is crossed, else None. Writes into a shared
        page trigger copy-on-write (the copy lands in ``drain_copies``).
        Raises on exhaustion (the scheduler preempts and retries)."""
        s = self._seqs[seq_id]
        new_page = None
        if self.pages_needed(s.n_tokens + 1) > len(s.pages):
            new_page = self._take_page()
            self._incref(new_page)
            s.pages.append(new_page)
        else:
            self.ensure_writable(seq_id, s.n_tokens)
        s.n_tokens += 1
        return new_page

    def free_seq(self, seq_id: int) -> int:
        """Drop a retired/preempted sequence's references. Cached pages
        whose refcount hits zero become evictable; the rest return to the
        free list. Pages are released deepest-first so LRU eviction
        reclaims the END of a cached chain before its head — a chain is
        only matchable through its prefix, so head pages are the valuable
        ones."""
        s = self._seqs.pop(seq_id)
        if self._pending_copies:
            # purge queued COW copies targeting this sequence's pages: the
            # dst was private to it, and once released it may be re-claimed
            # and re-targeted before the engine drains — duplicate dst
            # entries in one copy_pages batch scatter in undefined order
            released = set(s.pages)
            self._pending_copies = [(src, dst) for src, dst
                                    in self._pending_copies
                                    if dst not in released]
        for p in reversed(s.pages):
            self._decref(p)
        return len(s.pages)

    def drain_copies(self) -> List[Tuple[int, int]]:
        """(src, dst) page copies queued by COW since the last drain. The
        engine must apply them to the device pool before the next write."""
        out, self._pending_copies = self._pending_copies, []
        return out

    # --------------------------- prefix cache -------------------------- #
    def register_prefix(self, seq_id: int, tokens: Sequence[int],
                        n_valid: Optional[int] = None) -> int:
        """Index the sequence's full pages under their chained block hashes
        so later prompts can reuse them. ``n_valid`` caps how many leading
        tokens actually hold valid KV (defaults to the tracked length).
        Returns the number of newly indexed pages."""
        if not self.enable_prefix_cache:
            return 0
        s = self._seqs[seq_id]
        limit = min(len(tokens), s.n_tokens,
                    n_valid if n_valid is not None else s.n_tokens)
        ps = self.page_size
        parent = b""
        added = 0
        for b in range(limit // ps):
            block = tuple(tokens[b * ps:(b + 1) * ps])
            key = _chain_digest(parent, block)
            if key not in self._index:
                page = s.pages[b]
                if page in self._page_key:
                    # page already indexed under another chain (e.g. the
                    # request itself reused it) — leave that entry alone
                    parent = key
                    continue
                self._index[key] = page
                self._page_key[page] = key
                self._children.setdefault(parent, {})[key] = page
                self._parent_key[key] = parent
                self._block_tokens[key] = block
                added += 1
            parent = key
        return added

    def lookup_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens of ``tokens`` a prefix-aware allocation would reuse
        (full-page matches only; does not claim anything)."""
        if not self.enable_prefix_cache:
            return 0
        ps = self.page_size
        parent = b""
        n = 0
        for b in range(min(len(tokens) // ps, (len(tokens) - 1) // ps)):
            key = _chain_digest(parent, tokens[b * ps:(b + 1) * ps])
            if key not in self._index:
                break
            n += ps
            parent = key
        return n

    # --------------------------- table export -------------------------- #
    def table_row(self, seq_id: int, n_pages_per_seq: int) -> np.ndarray:
        """Padded int32 page-table row (null page 0 past the last page)."""
        pages = self._seqs[seq_id].pages
        row = np.zeros((n_pages_per_seq,), np.int32)
        row[:len(pages)] = pages
        return row

    # --------------------------- tier feedback ------------------------- #
    def kv_tier_split(self) -> Tuple[Tuple[str, float], ...]:
        """Occupied pages as a tier split, fast tier filled first.

        Matches the ``Placement.splits`` shape so the analytical model can
        price attention traffic with the runtime pool's actual placement.
        Shared pages count once — prefix dedup shrinks the split's mass."""
        used = self.n_used
        if not used:
            return ()
        if self.tier_budget is None:
            raise ValueError(
                "kv_tier_split() needs tier information: construct the "
                "manager with tier_budget=TierBudget.from_hierarchy(...)")
        out: List[Tuple[str, float]] = []
        rem = used
        for name, cap in self.tier_budget.tiers:
            take = min(rem, cap)
            if take > 0:
                out.append((name, take / used))
                rem -= take
            if rem == 0:
                break
        return tuple(out)

    def tier_occupancy_bytes(self, cfg: ArchConfig, dtype_bytes: int = 2
                             ) -> Dict[str, float]:
        pb = page_bytes(cfg, self.page_size, dtype_bytes)
        return {name: frac * self.n_used * pb
                for name, frac in self.kv_tier_split()}
