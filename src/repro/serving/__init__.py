from repro.serving.draft import ModelDraft, NGramDraft
from repro.serving.engine import ServeEngine, ServeStats
from repro.serving.kv_manager import (PageAllocationError, PagedKVManager,
                                      PrefixAllocation, ResidencyPlan,
                                      SimulatedTierDevice, TierBudget,
                                      page_bytes)
from repro.serving.metrics import latency_summary_ms, pct_ms, percentile
from repro.serving.scheduler import (AdaptiveSpecK, ContinuousScheduler,
                                     Request)
from repro.serving.streams import VirtualStream
from repro.serving.trace import PHASES, TraceRecorder, validate_chrome_trace

__all__ = ["ModelDraft", "NGramDraft", "ServeEngine", "ServeStats",
           "PageAllocationError", "PagedKVManager", "PrefixAllocation",
           "ResidencyPlan", "SimulatedTierDevice", "TierBudget",
           "page_bytes", "AdaptiveSpecK",
           "ContinuousScheduler", "Request", "PHASES", "TraceRecorder",
           "VirtualStream", "validate_chrome_trace", "latency_summary_ms",
           "pct_ms", "percentile"]
