from repro.serving.draft import ModelDraft, NGramDraft
from repro.serving.engine import ServeEngine, ServeStats
from repro.serving.kv_manager import (PageAllocationError, PagedKVManager,
                                      PrefixAllocation, SimulatedTierDevice,
                                      TierBudget, page_bytes)
from repro.serving.scheduler import (AdaptiveSpecK, ContinuousScheduler,
                                     Request)

__all__ = ["ModelDraft", "NGramDraft", "ServeEngine", "ServeStats",
           "PageAllocationError", "PagedKVManager", "PrefixAllocation",
           "SimulatedTierDevice", "TierBudget", "page_bytes", "AdaptiveSpecK",
           "ContinuousScheduler", "Request"]
