from repro.serving.engine import ServeEngine, ServeStats
from repro.serving.kv_manager import (PageAllocationError, PagedKVManager,
                                      PrefixAllocation, SimulatedTierDevice,
                                      TierBudget, page_bytes)
from repro.serving.scheduler import ContinuousScheduler, Request

__all__ = ["ServeEngine", "ServeStats", "PageAllocationError",
           "PagedKVManager", "PrefixAllocation", "SimulatedTierDevice",
           "TierBudget", "page_bytes", "ContinuousScheduler", "Request"]
