"""Structured tracing + latency attribution for the serve engine
(DESIGN.md SS15).

One event vocabulary threaded through every serving layer: the engine,
scheduler, KV manager / ``SimulatedTierDevice`` and the drafters emit
spans and instant events onto the SS13 virtual clock, and this recorder
turns them into three exports:

* **Chrome trace-event JSON** (``to_chrome`` / ``save``) — one track per
  request plus engine and DMA-channel tracks, loadable in Perfetto /
  ``chrome://tracing``.
* **Per-request latency breakdown** (``breakdown`` / ``breakdowns``) —
  each request's end-to-end latency partitioned into
  ``queue / prefill / recompute / decode / stall / draft`` seconds that
  sum to it *exactly* (conservation by construction: the recorder tiles
  each request's lifetime with contiguous segments; unattributed time —
  waiting while other requests hold the engine, host bookkeeping — is
  queue time).
* **SLO goodput report** (``slo_report``) — which requests met their
  TTFT/ITL targets, and for the violators, which phase to blame. This is
  the readout ROADMAP item 1's SLO-aware scheduler consumes.

``reconcile`` audits ``ServeStats`` against the trace after every serve:
total stall, per-request stall attribution, the TTFT/ITL sample sets and
the emitted-token count must all match the events within float
tolerance, so the aggregate counters can no longer silently drift from
what actually happened.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving import metrics

# ---- phase vocabulary (per-request latency attribution) ---- #
QUEUE = "queue"          # waiting: for admission, or for the engine while
                         # other requests hold it (incl. host bookkeeping)
PREFILL = "prefill"      # this request's own prefill-chunk compute
RECOMPUTE = "recompute"  # re-prefill of KV lost to a preemption
DECODE = "decode"        # fused decode blocks / spec verify passes
STALL = "stall"          # fetch-wait on THIS request's offload pages
DRAFT = "draft"          # speculative draft proposal overhead
PHASES = (QUEUE, PREFILL, RECOMPUTE, DECODE, STALL, DRAFT)

# ---- Chrome trace track model ---- #
PID_REQUESTS = 1         # one thread (track) per request id
PID_DEVICE = 2           # engine + stream + DMA-channel tracks
TID_ENGINE = 0
TID_DMA_IN = 1           # fetch: offload -> fast
TID_DMA_OUT = 2          # spill/write-back: fast -> offload
TID_PREFILL = 3          # prefill stream (overlapped engine, SS16)
TID_DECODE = 4           # decode stream
TID_CHIP_IN = 5          # chiplet link: promotion (base -> chiplet, SS17)
TID_CHIP_OUT = 6         # chiplet link: demotion (chiplet -> base)
_DEVICE_TIDS = {"engine": TID_ENGINE, "in": TID_DMA_IN, "out": TID_DMA_OUT,
                "prefill": TID_PREFILL, "decode": TID_DECODE,
                "chiplet:in": TID_CHIP_IN, "chiplet:out": TID_CHIP_OUT}


@dataclass
class _ReqTrace:
    rid: int
    t_submit: float
    cursor: float                      # end of the last tiled segment
    segments: List[Tuple[str, float, float]] = field(default_factory=list)
    token_t: List[float] = field(default_factory=list)
    prefill_hw: int = 0                # token extent ever computed (for
                                       # labelling re-prefill as recompute)
    n_preemptions: int = 0
    done: bool = False


class TraceRecorder:
    """Collects virtual-clock spans/instants and exports trace,
    breakdown, and SLO reports. All times are seconds on the engine's
    virtual clock (wall + absorbed migration stall)."""

    def __init__(self) -> None:
        self._req: Dict[int, _ReqTrace] = {}
        self._events: List[dict] = []      # chrome events, ts/dur in raw s
        self.stall_total = 0.0             # sum of absorbed batch stalls
        # DMA bytes by "src->dst" label, accumulated from device spans —
        # reconciled against the KV manager's channel_bytes counters
        self.dma_bytes: Dict[str, float] = {}
        self._t_base: Optional[float] = None
        self.t_final: Optional[float] = None

    # ------------------------- raw event plumbing ---------------------- #
    def _base(self, t: float) -> None:
        if self._t_base is None or t < self._t_base:
            self._t_base = t

    def _span_event(self, pid: int, tid: int, name: str, t0: float,
                    t1: float, args: Optional[dict] = None) -> None:
        self._base(t0)
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "ts_s": t0, "dur_s": t1 - t0}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def _instant_event(self, pid: int, tid: int, name: str, t: float,
                       args: Optional[dict] = None) -> None:
        self._base(t)
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name,
              "ts_s": t, "s": "t"}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, t: float, *, rid: Optional[int] = None,
                track: str = "engine", args: Optional[dict] = None) -> None:
        """Generic instant event — on a request track when ``rid`` is
        given, else on the named device track (engine/in/out)."""
        if rid is not None:
            self._instant_event(PID_REQUESTS, rid, name, t, args)
        else:
            self._instant_event(PID_DEVICE, _DEVICE_TIDS[track], name, t,
                                args)

    def engine_span(self, name: str, t0: float, t1: float,
                    args: Optional[dict] = None,
                    track: str = "engine") -> None:
        """Engine-side span. ``track`` routes it: the overlapped engine
        puts prefill chunks on the ``prefill`` stream track and decode /
        verify blocks on ``decode``, so concurrent spans land on distinct
        tids instead of overlapping illegibly on one engine row."""
        self._span_event(PID_DEVICE, _DEVICE_TIDS[track], name, t0,
                         max(t1, t0), args)

    def device_span(self, channel: str, t0: float, t1: float,
                    n_bytes: float, *, link: str = "hbs",
                    label: Optional[str] = None,
                    slice_idx: Optional[int] = None) -> None:
        """One batched DMA transfer (or one layer slice of a chained
        descriptor, ``slice_idx`` set) — emitted by
        ``SimulatedTierDevice.transfer`` / ``transfer_sliced``. ``link``
        routes chiplet-link migrations to their own tracks; ``label`` is
        the "src->dst" tier pair whose bytes are accumulated for the
        per-channel reconcile."""
        track = channel if link != "chiplet" else f"chiplet:{channel}"
        name = "fetch" if channel == "in" else "spill"
        if link == "chiplet":
            name = "promote" if channel == "in" else "demote"
        args = {"bytes": n_bytes}
        if label is not None:
            args["link"] = label
            self.dma_bytes[label] = self.dma_bytes.get(label, 0.0) + n_bytes
        if slice_idx is not None:
            args["slice"] = slice_idx
        self._span_event(PID_DEVICE, _DEVICE_TIDS[track], name, t0,
                         max(t1, t0), args)

    def prefetch(self, page: int, hit: bool, t: float) -> None:
        """Prefetch-hit/miss resolution, from the KV manager's fetch-wait
        barrier."""
        self._instant_event(PID_DEVICE, TID_DMA_IN,
                            "prefetch_hit" if hit else "prefetch_miss", t,
                            {"page": page})

    def absorbed_stall(self, t0: float, dur: float,
                       track: str = "engine") -> None:
        """A fetch-wait barrier the batch absorbed (the max over its
        requests' own waits). Sum over these == ``ServeStats.stall_s``.
        ``track`` places the span on the stream that absorbed it."""
        if dur <= 0:
            return
        self.stall_total += dur
        self._span_event(PID_DEVICE, _DEVICE_TIDS[track], "stall", t0,
                         t0 + dur)

    # --------------------- per-request lifecycle ----------------------- #
    def submit(self, rid: int, t: float) -> None:
        self._base(t)
        self._req[rid] = _ReqTrace(rid=rid, t_submit=t, cursor=t)

    def _fill(self, r: _ReqTrace, t: float) -> None:
        """Tile the gap up to ``t`` as queue time (waiting for service)."""
        if t > r.cursor:
            r.segments.append((QUEUE, r.cursor, t))
            self._span_event(PID_REQUESTS, r.rid, QUEUE, r.cursor, t)
            r.cursor = t

    def admit(self, rid: int, t: float, *, cached_tokens: int = 0,
              slot: Optional[int] = None) -> None:
        r = self._req[rid]
        self._fill(r, t)                  # submit -> admit wait, explicit
        args = {"cached_tokens": cached_tokens}
        if slot is not None:
            args["slot"] = slot
        self._instant_event(PID_REQUESTS, rid, "admit", t, args)

    def span(self, rid: int, phase: str, t0: float, t1: float, *,
             args: Optional[dict] = None) -> None:
        """Attribute ``[t0, t1]`` of this request's lifetime to ``phase``.
        Overlap with already-tiled time is clamped away (e.g. a decode
        span launched at a block start whose stall span already covered
        the barrier), and any gap before it becomes queue time — so
        segments always tile ``[t_submit, cursor]`` exactly."""
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}")
        r = self._req[rid]
        t0 = max(t0, r.cursor)
        t1 = max(t1, t0)
        self._fill(r, t0)
        if t1 > t0:
            r.segments.append((phase, t0, t1))
            self._span_event(PID_REQUESTS, rid, phase, t0, t1, args)
            r.cursor = t1

    def prefill_span(self, rid: int, t0: float, t1: float, start_tok: int,
                     end_tok: int) -> None:
        """A prefill chunk computing token positions ``[start_tok,
        end_tok)``. The portion under the request's computed-extent
        high-water mark was computed before (lost to preemption) and is
        labelled ``recompute``; the rest is first-time ``prefill``. The
        split is proportional in time within the chunk."""
        r = self._req[rid]
        n = max(end_tok - start_tok, 0)
        re_n = min(max(min(r.prefill_hw, end_tok) - start_tok, 0), n)
        t0 = max(t0, r.cursor)
        t1 = max(t1, t0)
        args = {"tokens": [start_tok, end_tok]}
        if n > 0 and re_n > 0:
            tm = t0 + (t1 - t0) * (re_n / n)
            self.span(rid, RECOMPUTE, t0, tm, args=args)
            self.span(rid, PREFILL, tm, t1, args=args)
        else:
            self.span(rid, PREFILL, t0, t1, args=args)
        r.prefill_hw = max(r.prefill_hw, end_tok)

    def token(self, rid: int, t: float, tok: int) -> None:
        r = self._req[rid]
        name = "first_token" if not r.token_t else "token"
        r.token_t.append(t)
        self._instant_event(PID_REQUESTS, rid, name, t, {"tok": tok})

    def preempt(self, rid: int, t: float, *, n_valid: int = 0) -> None:
        """LIFO recompute preemption: the request's pages are freed and it
        re-queues. ``n_valid`` (its landed KV extent) raises the computed
        high-water mark so the re-prefill is labelled recompute."""
        r = self._req[rid]
        r.n_preemptions += 1
        r.prefill_hw = max(r.prefill_hw, n_valid)
        self._instant_event(PID_REQUESTS, rid, "preempt", t,
                            {"n_valid": n_valid})

    def retire(self, rid: int, t: float) -> None:
        r = self._req[rid]
        self._fill(r, t)
        r.done = True
        self._instant_event(PID_REQUESTS, rid, "retire", t)

    def finalize(self, t: float) -> None:
        """Close the trace: any request still open (engine aborted
        mid-serve) is tiled out to ``t`` as queue time."""
        self.t_final = t
        for r in self._req.values():
            if not r.done:
                self._fill(r, t)

    # ------------------------- breakdown export ------------------------ #
    def breakdown(self, rid: int) -> Dict[str, object]:
        """Per-request phase partition. ``sum(<phase>_s) == e2e_s``
        exactly (segments tile the lifetime)."""
        r = self._req[rid]
        out: Dict[str, object] = {f"{p}_s": 0.0 for p in PHASES}
        for phase, t0, t1 in r.segments:
            out[f"{phase}_s"] += t1 - t0
        out["e2e_s"] = r.cursor - r.t_submit
        out["n_tokens"] = len(r.token_t)
        out["n_preemptions"] = r.n_preemptions
        out["ttft_s"] = (r.token_t[0] - r.t_submit if r.token_t else 0.0)
        out["itl_s"] = [b - a for a, b in zip(r.token_t, r.token_t[1:])]
        return out

    def breakdowns(self) -> Dict[int, Dict[str, object]]:
        return {rid: self.breakdown(rid) for rid in sorted(self._req)}

    def aggregate_breakdown_ms(self, ndigits: int = 3) -> Dict[str, float]:
        """Phase seconds summed across requests, in ms — the compact
        block the benchmark JSON sections embed."""
        total = {f"{p}_s": 0.0 for p in PHASES}
        e2e = 0.0
        for rid in self._req:
            bd = self.breakdown(rid)
            for p in PHASES:
                total[f"{p}_s"] += bd[f"{p}_s"]
            e2e += bd["e2e_s"]
        out = {f"{p}_ms": round(total[f"{p}_s"] * 1e3, ndigits)
               for p in PHASES}
        out["e2e_ms"] = round(e2e * 1e3, ndigits)
        return out

    # --------------------------- SLO goodput --------------------------- #
    def _window_phase(self, rid: int, t0: float, t1: float
                      ) -> Dict[str, float]:
        """Phase mass inside a time window (for blame attribution)."""
        out = {p: 0.0 for p in PHASES}
        for phase, a, b in self._req[rid].segments:
            ov = min(b, t1) - max(a, t0)
            if ov > 0:
                out[phase] += ov
        return out

    def slo_report(self, ttft_target_s: Optional[float] = None,
                   itl_target_s: Optional[float] = None,
                   ndigits: int = 3) -> Dict[str, object]:
        """Goodput vs the TTFT/ITL targets, with per-phase blame for each
        violator: the dominant phase of the violated window
        ([submit, first token] for TTFT; [first token, retire] for
        ITL)."""
        reqs, viol = [], []
        ttfts: List[float] = []
        itls: List[float] = []
        for rid in sorted(self._req):
            r = self._req[rid]
            bd = self.breakdown(rid)
            ttft = bd["ttft_s"]
            itl = bd["itl_s"]
            ttfts.append(ttft)
            itls.extend(itl)
            itl_p95 = metrics.percentile(itl, 95)
            ok_ttft = (ttft_target_s is None or not r.token_t
                       or ttft <= ttft_target_s)
            ok_itl = (itl_target_s is None or not itl
                      or itl_p95 <= itl_target_s)
            row = {"rid": rid,
                   "ttft_ms": round(ttft * 1e3, ndigits),
                   "itl_p95_ms": round(itl_p95 * 1e3, ndigits),
                   "meets_ttft": ok_ttft, "meets_itl": ok_itl}
            reqs.append(row)
            if not (ok_ttft and ok_itl):
                if not ok_ttft and r.token_t:
                    win = self._window_phase(rid, r.t_submit, r.token_t[0])
                elif r.token_t:
                    win = self._window_phase(rid, r.token_t[0], r.cursor)
                else:
                    win = self._window_phase(rid, r.t_submit, r.cursor)
                blame = max(win, key=lambda p: win[p]) if any(
                    win.values()) else DECODE
                viol.append({**row, "blame": blame,
                             "blame_window_ms": {
                                 p: round(v * 1e3, ndigits)
                                 for p, v in win.items() if v > 0},
                             "breakdown_ms": {
                                 f"{p}_ms": round(bd[f"{p}_s"] * 1e3,
                                                  ndigits)
                                 for p in PHASES}})
        n = len(reqs)
        met = sum(1 for r in reqs if r["meets_ttft"] and r["meets_itl"])
        return {
            "targets": {
                "ttft_ms": (None if ttft_target_s is None
                            else round(ttft_target_s * 1e3, ndigits)),
                "itl_ms": (None if itl_target_s is None
                           else round(itl_target_s * 1e3, ndigits))},
            "n_requests": n,
            "n_met_slo": met,
            "goodput_frac": round(met / n, 4) if n else 1.0,
            "ttft": metrics.latency_summary_ms(ttfts, ndigits=ndigits),
            "itl": metrics.latency_summary_ms(itls, ndigits=ndigits),
            "violators": viol,
        }

    # -------------------------- reconciliation ------------------------- #
    def reconcile(self, *, stall_s: float, ttft: Sequence[float],
                  itl: Sequence[float], new_tokens: int,
                  stall_by_rid: Optional[Dict[int, float]] = None,
                  channel_bytes: Optional[Dict[str, float]] = None,
                  tol: float = 1e-6, strict: bool = True
                  ) -> Dict[str, object]:
        """Audit ``ServeStats`` aggregates against the trace events.

        Conservation invariants checked (the SS15 contract):
        * each request's phase partition sums to its end-to-end latency
          (exact tiling, checked to ``tol``);
        * the trace's absorbed-stall spans sum to ``stall_s``;
        * each request's stall segments sum to its ``stall_by_rid`` entry;
        * the trace's token instants reproduce the TTFT and ITL sample
          sets and the emitted-token count;
        * the per-"src->dst" DMA span bytes match the manager's
          ``channel_bytes`` counters (SS17 per-channel accounting), when
          given.

        Returns a report dict; with ``strict`` raises ``AssertionError``
        listing every failed check (counters may not silently drift)."""
        fails: List[str] = []

        def close(a: float, b: float) -> bool:
            return abs(a - b) <= tol

        if channel_bytes is not None:
            for key in sorted(set(self.dma_bytes) | set(channel_bytes)):
                got = self.dma_bytes.get(key, 0.0)
                want = channel_bytes.get(key, 0.0)
                if abs(got - want) > max(tol, 1e-9 * max(got, want)):
                    fails.append(f"channel {key}: trace {got:.3f}B != "
                                 f"stats {want:.3f}B")

        for rid in self._req:
            bd = self.breakdown(rid)
            parts = sum(bd[f"{p}_s"] for p in PHASES)
            if not close(parts, bd["e2e_s"]):
                fails.append(f"req {rid}: phase sum {parts:.9f} != "
                             f"e2e {bd['e2e_s']:.9f}")

        if not close(self.stall_total, stall_s):
            fails.append(f"stall: trace {self.stall_total:.9f} != "
                         f"stats {stall_s:.9f}")

        if stall_by_rid is not None:
            for rid in set(self._req) | set(stall_by_rid):
                want = stall_by_rid.get(rid, 0.0)
                got = (self.breakdown(rid)["stall_s"]
                       if rid in self._req else 0.0)
                if not close(got, want):
                    fails.append(f"req {rid} stall: trace {got:.9f} != "
                                 f"stats {want:.9f}")

        t_ttft = sorted(self.breakdown(rid)["ttft_s"]
                        for rid in self._req if self._req[rid].token_t)
        s_ttft = sorted(ttft)
        if len(t_ttft) != len(s_ttft) or any(
                not close(a, b) for a, b in zip(t_ttft, s_ttft)):
            fails.append(f"ttft samples differ: trace {len(t_ttft)} vs "
                         f"stats {len(s_ttft)}")

        t_itl = sorted(x for rid in self._req
                       for x in self.breakdown(rid)["itl_s"])
        s_itl = sorted(itl)
        if len(t_itl) != len(s_itl) or any(
                not close(a, b) for a, b in zip(t_itl, s_itl)):
            fails.append(f"itl samples differ: trace {len(t_itl)} vs "
                         f"stats {len(s_itl)}")

        n_tok = sum(len(r.token_t) for r in self._req.values())
        if n_tok != new_tokens:
            fails.append(f"tokens: trace {n_tok} != stats {new_tokens}")

        report = {"ok": not fails, "failures": fails,
                  "n_requests": len(self._req), "n_tokens": n_tok,
                  "stall_s": self.stall_total}
        if strict and fails:
            raise AssertionError(
                "trace/stats drift detected:\n  " + "\n  ".join(fails))
        return report

    # ------------------------- Chrome trace export --------------------- #
    def to_chrome(self) -> Dict[str, object]:
        """Chrome trace-event JSON (Perfetto-loadable): ``ph: "X"``
        complete spans and ``ph: "i"`` instants with µs timestamps
        rebased to the first event, plus process/thread naming
        metadata."""
        base = self._t_base or 0.0
        events: List[dict] = [
            {"ph": "M", "pid": PID_REQUESTS, "tid": 0,
             "name": "process_name", "args": {"name": "requests"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": 0,
             "name": "process_name", "args": {"name": "device"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_ENGINE,
             "name": "thread_name", "args": {"name": "engine"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_DMA_IN,
             "name": "thread_name", "args": {"name": "dma:in (fetch)"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_DMA_OUT,
             "name": "thread_name", "args": {"name": "dma:out (spill)"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_PREFILL,
             "name": "thread_name", "args": {"name": "stream:prefill"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_DECODE,
             "name": "thread_name", "args": {"name": "stream:decode"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_CHIP_IN,
             "name": "thread_name",
             "args": {"name": "chiplet:in (promote)"}},
            {"ph": "M", "pid": PID_DEVICE, "tid": TID_CHIP_OUT,
             "name": "thread_name",
             "args": {"name": "chiplet:out (demote)"}},
        ]
        for rid in sorted(self._req):
            events.append({"ph": "M", "pid": PID_REQUESTS, "tid": rid,
                           "name": "thread_name",
                           "args": {"name": f"req {rid}"}})
        for ev in self._events:
            out = {"ph": ev["ph"], "pid": ev["pid"], "tid": ev["tid"],
                   "name": ev["name"],
                   "ts": round((ev["ts_s"] - base) * 1e6, 3)}
            if ev["ph"] == "X":
                out["dur"] = round(ev["dur_s"] * 1e6, 3)
            if ev["ph"] == "i":
                out["s"] = ev.get("s", "t")
            if "args" in ev:
                out["args"] = ev["args"]
            events.append(out)
        return {"displayTimeUnit": "ms", "traceEvents": events,
                "metadata": {"clock": "virtual (wall + absorbed stall)",
                             "breakdowns": {
                                 str(rid): bd for rid, bd in
                                 self.breakdowns().items()}}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)


def validate_chrome_trace(doc: object) -> Dict[str, int]:
    """Structural validation of a Chrome trace-event document (what the
    CI smoke step and the golden-trace test assert). Raises ``ValueError``
    on the first violation; returns event counts by phase type."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    counts = {"X": 0, "i": 0, "M": 0}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in counts:
            raise ValueError(f"event {i}: unsupported ph {ph!r}")
        for key in ("name", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} ({ph}): missing {key!r}")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if ph == "M" and "args" not in ev:
            raise ValueError(f"event {i}: metadata event missing args")
        counts[ph] += 1
    if counts["M"] == 0:
        raise ValueError("no track-naming metadata events")
    return counts
