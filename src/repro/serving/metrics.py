"""Shared latency-statistics helpers (DESIGN.md SS15).

One percentile implementation for every consumer — ``ServeStats`` in the
engine, the benchmark JSON writers, and the trace layer's SLO report —
so the p50/p95 a benchmark records is bit-identical to the one the
engine prints. Before this module the logic lived twice (``ServeStats
._pct`` and inline rounding in ``benchmarks/serve_bench.py``) and could
drift independently.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def percentile(xs: Sequence[float], q: float) -> float:
    """``np.percentile`` with the empty-list convention the serving
    metrics use: no samples -> 0.0 (a run that emitted nothing has no
    latency, not a NaN)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = list(xs)
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def pct_ms(xs: Sequence[float], q: float, ndigits: int = 3) -> float:
    """Percentile of second-valued samples, reported in milliseconds
    rounded for JSON emission (the benchmark writers' convention)."""
    return round(percentile(xs, q) * 1e3, ndigits)


def latency_summary_ms(xs: Sequence[float], *,
                       ndigits: int = 3) -> Dict[str, float]:
    """The standard latency block the benchmark JSON sections share:
    p50/p95/mean/max over second-valued samples, in milliseconds."""
    xs = list(xs)
    mean = float(np.mean(xs)) if xs else 0.0
    mx = float(np.max(xs)) if xs else 0.0
    return {
        "p50_ms": pct_ms(xs, 50, ndigits),
        "p95_ms": pct_ms(xs, 95, ndigits),
        "mean_ms": round(mean * 1e3, ndigits),
        "max_ms": round(mx * 1e3, ndigits),
        "n": len(xs),
    }
