"""Path-based sharding rules: DP / TP / EP / FSDP(ZeRO-3) over the
production mesh axes ("pod", "data", "model").

Conventions
-----------
* batch dims shard over ("pod","data") (all data-parallel axes).
* weight matrices: the "feature-out" dim shards over "model" (TP); with
  ``param_mode='fsdp'`` the other large dim additionally shards over
  ("pod","data") — GSPMD inserts the per-layer all-gathers (ZeRO-3),
  which is what makes the 236B/480B configs fit 16 GB HBM chips.
* MoE expert dim shards over "model" (EP).
* KV caches shard heads over "model" when divisible, else the LENGTH dim
  (sequence sharding — GSPMD turns the decode softmax into a collective).
* Small vectors (norms, biases, router) replicate.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if axes else None


def _dp_size(mesh: Mesh) -> int:
    return mesh_axis_size(mesh, "pod") * mesh_axis_size(mesh, "data")


# stacked-layer prefixes get a leading None (scan) dim
_STACK_RE = re.compile(
    r"(stack|head_layers\[\d+\]|mamba|site_proj|enc_stack|dec_stack)")


def _path_str(path) -> str:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(f"[{p.idx}]")
        else:
            out.append(str(p))
    return "/".join(out)


def _weight_rule(path: str, shape, mesh: Mesh, mode: str,
                 cfg: ArchConfig) -> Tuple:
    """Spec for the trailing (non-stack) dims of one parameter."""
    model_ok = lambda n: n % mesh_axis_size(mesh, "model") == 0
    dp = _dp_size(mesh)
    ba = batch_axes(mesh)

    def fsdp_dim(spec_list, skip):
        """shard the largest remaining None dim over the DP axes."""
        if mode != "fsdp" or ba is None:
            return spec_list
        best, best_n = None, 0
        for i, s in enumerate(spec_list):
            if s is None and i != skip and shape[i] % dp == 0 and shape[i] > best_n:
                best, best_n = i, shape[i]
        if best is not None and best_n >= 1024:
            spec_list[best] = ba
        return spec_list

    nd = len(shape)
    # ---- embeddings ----
    if path.endswith("embed/emb"):
        return tuple(fsdp_dim(["model" if model_ok(shape[0]) else None, None],
                              0))
    if "lm_head" in path and nd == 2:
        return tuple(fsdp_dim([None, "model" if model_ok(shape[1]) else None],
                              1))
    if "pos_dec" in path:
        return (None,) * nd
    # ---- MoE expert stacks: (E, in, out) ----
    if re.search(r"moe/w_(up|down)", path) or (
            "w_up" in path or "w_down" in path) and nd == 3:
        e_sh = "model" if model_ok(shape[0]) else None
        return tuple(fsdp_dim([e_sh, None, None], 0))
    # ---- MLA per-head stacks: (H, r, d) ----
    if re.search(r"(k_up|v_up)$", path) and nd == 3:
        return tuple(fsdp_dim(
            ["model" if model_ok(shape[0]) else None, None, None], 0))
    # ---- generic 2D dense weights ----
    if nd == 2 and path.endswith("/w"):
        if re.search(r"(wq|wk|wv|q_up|q_down|kv_down|up|in_proj|fc1|router)",
                     path):
            col = "model" if model_ok(shape[1]) else None
            return tuple(fsdp_dim([None, col], 1))
        if re.search(r"(wo|o_proj|down|out_proj|fc2|site_proj)", path):
            row = "model" if model_ok(shape[0]) else None
            return tuple(fsdp_dim([row, None], 0))
        col = "model" if model_ok(shape[1]) else None
        return tuple(fsdp_dim([None, col], 1))
    # ---- biases of column-parallel layers ----
    if nd == 1 and path.endswith("/b"):
        return ("model",) if model_ok(shape[0]) and shape[0] >= 1024 else (None,)
    if "conv_w" in path and nd == 2:
        return (None, "model" if model_ok(shape[1]) else None)
    if "conv_b" in path and nd == 1:
        return ("model",) if model_ok(shape[0]) else (None,)
    return (None,) * nd


def param_pspecs(cfg: ArchConfig, params_shapes, mesh: Mesh,
                 mode: str = "fsdp"):
    """PartitionSpec tree mirroring the params tree.

    ``params_shapes``: pytree of ShapeDtypeStruct (jax.eval_shape output)."""
    def rule(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        m = _STACK_RE.search(ps)
        lead = 0
        if m and m.group(1) != "site_proj" and "head_layers" not in m.group(1):
            lead = 1
        elif m and m.group(1) == "site_proj":
            lead = 1
        body = _weight_rule(ps, shape[lead:], mesh, mode, cfg)
        return P(*((None,) * lead + tuple(body)))
    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def opt_state_pspec(pspec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: moments/master copies inherit the param spec, further
    sharding the largest replicated dim over the DP axes when divisible."""
    dp = _dp_size(mesh)
    ba = batch_axes(mesh)
    if ba is None:
        return pspec
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    flat = [s for s in spec]
    if any(s is not None and ("data" in (s if isinstance(s, tuple) else (s,)))
           for s in flat if s):
        return pspec  # already DP-sharded (fsdp param)
    best, best_n = None, 0
    for i, s in enumerate(spec):
        if s is None and shape[i] % dp == 0 and shape[i] > best_n:
            best, best_n = i, shape[i]
    if best is not None and best_n >= 256:
        spec[best] = ba
    return P(*spec)


def effective_batch_axes(mesh: Mesh, global_batch: int):
    """Batch sharding axes, or None when the batch doesn't divide DP."""
    ba = batch_axes(mesh)
    if ba is None or global_batch % _dp_size(mesh) != 0:
        return None
    return ba


def data_pspecs(cfg: ArchConfig, mesh: Mesh, kind: str,
                global_batch: int = 0) -> Dict[str, P]:
    """Input shardings for a shape cell."""
    ba = (effective_batch_axes(mesh, global_batch) if global_batch
          else batch_axes(mesh))
    if kind in ("train", "prefill"):
        d = {"tokens": P(ba, None)}
        if kind == "train":
            d["labels"] = P(ba, None)
        if cfg.family in ("vlm", "encdec"):
            d["prefix_emb"] = P(ba, None, None)
        return d
    return {"token": P(ba), "pos": P()}


def _len_or_head(mesh, n_heads: int, length: int):
    ms = mesh_axis_size(mesh, "model")
    if n_heads % ms == 0 and n_heads >= ms:
        return "heads"
    if length % ms == 0:
        return "length"
    return "none"


def paged_cache_pspecs(cache, mesh: Mesh):
    """Shardings for the serve engine's paged KV pool (DESIGN.md SS16).

    The pool k/v arrays are (n_layers, n_pages, page_size, Hkv, head_dim):
    the KV-head dim shards over "model" when divisible, everything else —
    including the pages axis, which the replicated page table indexes —
    replicates. The int8 per-(layer, kv-head) scales stay REPLICATED on
    purpose: calibration happens outside the shard_map body so every shard
    quantizes with bitwise-identical scales, and the shard body slices its
    own head block on entry."""
    ms = mesh_axis_size(mesh, "model")

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 5 and shape[3] % ms == 0 and shape[3] >= ms:
            return P(None, None, None, "model", None)
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map_with_path(rule, cache)


def cache_pspecs(cfg: ArchConfig, cache_shapes, mesh: Mesh,
                 global_batch: int = 0):
    """KV-cache / SSM-state shardings: batch over DP; heads over "model"
    when divisible, else sequence-shard the cache length (GSPMD then
    lowers the decode softmax to a cross-shard collective)."""
    ba = (effective_batch_axes(mesh, global_batch) if global_batch
          else batch_axes(mesh))
    ms = mesh_axis_size(mesh, "model")

    def mdl(n):
        return "model" if (n % ms == 0 and n >= ms) else None

    def rule(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        # --- SSM recurrent states (check conv BEFORE the ssm catch-all:
        # the pytree path is .../ssm_states/{conv,ssm}) ------------------
        if "conv" in ps:
            # (L,B,K,C) or zamba (sites,per,B,K,C)
            lead = nd - 3
            return P(*([None] * lead), ba, None, mdl(shape[-1]))
        if "ssm" in ps:
            # (L,B,nh,hd,N) or zamba (sites,per,B,nh,hd,N)
            lead = nd - 4
            return P(*([None] * lead), ba, mdl(shape[lead + 1]), None, None)
        # --- MLA latent caches ----------------------------------------
        if ps.endswith("/c") or "k_rope" in ps:
            if nd == 4:  # (L,B,Lmax,width): sequence-shard the cache
                return P(None, ba, mdl(shape[2]), None)
            return P(ba, mdl(shape[1]), None)      # head-layer (B,Lmax,w)
        # --- attention KV caches --------------------------------------
        if nd == 5:      # (L,B,Lmax,H,hd) / zamba (sites,B,Lmax,H,hd)
            if mdl(shape[3]):
                return P(None, ba, None, "model", None)
            return P(None, ba, mdl(shape[2]), None, None)
        if nd == 4:      # unstacked head-layer cache (B,Lmax,Hkv,hd)
            if mdl(shape[2]):
                return P(ba, None, "model", None)
            return P(ba, mdl(shape[1]), None, None)
        return P(*([None] * nd))
    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
