from repro.sharding.rules import (batch_axes, cache_pspecs, data_pspecs,
                                  opt_state_pspec, param_pspecs)

__all__ = ["batch_axes", "cache_pspecs", "data_pspecs", "opt_state_pspec",
           "param_pspecs"]
