"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE, so a
``lax.scan`` over 60 layers reports 1/60th of the real FLOPs. This module
parses post-SPMD HLO text, finds ``while`` trip counts (scan upper bounds
are integer constants in the condition computation), and accumulates

    flops             (dot ops: 2 * prod(out) * prod(contracting))
    bytes             (operands + outputs at fusion/instruction boundaries)
    collective_bytes  (all-gather / all-reduce / reduce-scatter /
                       all-to-all / collective-permute result bytes)

All values are PER-DEVICE (post-partitioning shapes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Sum bytes over every array shape appearing in a type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_type(expr: str) -> str:
    """The result type at the start of an instruction RHS."""
    depth, out = 0, []
    for ch in expr:
        if ch == "(" and depth == 0 and out and out[-1] != " ":
            break  # reached op args
        out.append(ch)
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if depth == 0 and ch == ")":
            break
        if ch == " " and depth == 0:
            break
    return "".join(out)


@dataclass
class Instr:
    name: str
    op: str
    result_type: str
    operand_types: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)

    def operand_bytes(self, ins: Instr) -> float:
        total = 0.0
        for o in ins.operand_types:
            nm = o.lstrip("%")
            total += _shape_bytes(self.types.get(nm, o))
        return total

    def operand_shape(self, ins: Instr, idx: int) -> str:
        if idx >= len(ins.operand_types):
            return ""
        o = ins.operand_types[idx]
        return self.types.get(o.lstrip("%"), o)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*?\))|(?:[\w\[\],:{}\s]*?))\s*([\w\-]+)\(")


def _split_args(args: str) -> List[str]:
    """Split an operand list at TOP-LEVEL commas only: operand types carry
    commas inside brackets/braces (``f32[128,256]{1,0} %x``)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("{" in line):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if stripped == "}" or cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type: balanced-paren scan (tuple types contain
        # /*index=N*/ comments and nested parens that defeat regexes)
        rhs = rhs.strip()
        if rhs.startswith("("):
            depth, i = 0, 0
            while i < len(rhs):
                if rhs[i] == "(":
                    depth += 1
                elif rhs[i] == ")":
                    depth -= 1
                    if depth == 0:
                        i += 1
                        break
                i += 1
            result_type = rhs[:i]
            rest = rhs[i:].lstrip()
        else:
            sp = rhs.find(" ")
            if sp < 0:
                continue
            result_type = rhs[:sp]
            rest = rhs[sp + 1:].lstrip()
        om = re.match(r"([\w\-]+)\(", rest)
        if not om:
            continue
        op = om.group(1)
        args_start = len(op) + 1
        depth, i = 1, args_start
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        args = rest[args_start:i - 1]
        attrs = rest[i:]
        ins = Instr(name, op, result_type, _split_args(args), attrs)
        cur.instrs.append(ins)
        cur.types[name] = result_type
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the condition computation (scan bound)."""
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.op + "(" +
                             ",".join(ins.operand_types) + ")" + ins.attrs):
            best = max(best, int(m.group(1)))
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)",
                          f"constant({ins.attrs})")
        for m in re.finditer(r"s(?:32|64)\[\]\s*constant\((\d+)\)",
                             ins.result_type + " " + ins.attrs):
            best = max(best, int(m.group(1)))
    return best


_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _dot_flops(ins: Instr, comp: "Computation") -> float:
    out = 1.0
    m = _SHAPE_RE.search(ins.result_type)
    if m and m.group(2):
        for d in m.group(2).split(","):
            out *= int(d)
    contract = 1.0
    dm = _DOT_DIMS_RE.search(ins.attrs)
    lhs_type = comp.operand_shape(ins, 0)
    if dm and lhs_type:
        lhs = _SHAPE_RE.search(lhs_type)
        if lhs and lhs.group(2):
            dims = [int(d) for d in lhs.group(2).split(",")]
            for ci in filter(None, dm.group(1).split(",")):
                i = int(ci)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out * contract


def _fusion_bytes(fused: Computation, caller: Computation, ins: Instr,
                  out_b: float) -> float:
    """HBM traffic of one fusion = what its boundary actually moves.

    A loop fusion often takes a whole scan-stacked array as an operand and
    dynamic-slices ONE layer inside — the read is slice-sized, not
    stack-sized. Similarly a dynamic-update-slice root writes (and keeps
    in place) only the update region."""
    # map parameter index -> bytes actually read
    param_read: Dict[int, float] = {}
    param_of: Dict[str, int] = {}
    for i, fins in enumerate(fused.instrs):
        if fins.op == "parameter":
            idx = int(fins.operand_types[0]) if fins.operand_types and \
                fins.operand_types[0].isdigit() else len(param_of)
            param_of[fins.name] = idx
            param_read[idx] = _shape_bytes(fins.result_type)
    for fins in fused.instrs:
        if fins.op in ("dynamic-slice", "gather"):
            src = fins.operand_types[0].lstrip("%") if fins.operand_types \
                else ""
            if src in param_of:
                param_read[param_of[src]] = min(
                    param_read[param_of[src]],
                    2 * _shape_bytes(fins.result_type))
    root = fused.instrs[-1] if fused.instrs else None
    write_b = out_b
    if root is not None and root.op == "dynamic-update-slice":
        upd = (_shape_bytes(fused.operand_shape(root, 1))
               if len(root.operand_types) > 1 else out_b)
        write_b = upd
        # the aliased big operand is not re-read either
        tgt = root.operand_types[0].lstrip("%") if root.operand_types else ""
        if tgt in param_of:
            param_read[param_of[tgt]] = upd
    return sum(param_read.values()) + write_b


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, float] = field(default_factory=dict)


def analyze(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    memo: Dict[str, HloCosts] = {}

    def visit(name: str) -> HloCosts:
        if name in memo:
            return memo[name]
        memo[name] = HloCosts()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = HloCosts(collective_counts={})
        for ins in comp.instrs:
            out_b = _shape_bytes(ins.result_type)
            in_b = comp.operand_bytes(ins)
            if ins.op == "dot":
                c.flops += _dot_flops(ins, comp)
                c.bytes += in_b + out_b
            elif ins.op in ("dynamic-slice",):
                # reads only the slice; the big operand is not streamed
                c.bytes += 2 * out_b
            elif ins.op in ("dynamic-update-slice", "scatter"):
                # XLA aliases the target buffer in place inside loops:
                # traffic = the update region (r+w), not the whole buffer
                upd = (_shape_bytes(comp.operand_shape(ins, 1))
                       if len(ins.operand_types) > 1 else out_b)
                c.bytes += 2 * upd
            elif ins.op == "gather":
                c.bytes += 2 * out_b
            elif ins.op in ("fusion", "custom-call", "convolution"):
                fm = (re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                      if ins.op == "fusion" else None)
                if fm and fm.group(1) in comps:
                    c.bytes += _fusion_bytes(comps[fm.group(1)], comp, ins,
                                             out_b)
                else:
                    c.bytes += in_b + out_b
                # approximate fused flops: elementwise ~= output elements
                c.flops += out_b
                if fm:
                    c.flops += visit_fused_dots(fm.group(1))
            elif ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps[cm.group(1)]) if (
                        cm and cm.group(1) in comps) else 1
                if bm and bm.group(1) in comps:
                    sub = visit(bm.group(1))
                    c.flops += sub.flops * trip
                    c.bytes += sub.bytes * trip
                    c.collective_bytes += sub.collective_bytes * trip
                    for k, v in sub.collective_counts.items():
                        c.collective_counts[k] = (c.collective_counts.get(k, 0)
                                                  + v * trip)
            elif ins.op in ("call", "conditional", "async-start"):
                for cm in re.finditer(
                        r"(?:to_apply|called_computations?|branch_computations)"
                        r"=\{?%?([\w.\-]+)", ins.attrs):
                    sub = visit(cm.group(1))
                    c.flops += sub.flops
                    c.bytes += sub.bytes
                    c.collective_bytes += sub.collective_bytes
            elif any(ins.op.startswith(col) for col in _COLLECTIVES):
                c.collective_bytes += out_b
                c.bytes += in_b + out_b
                kind = next(col for col in _COLLECTIVES
                            if ins.op.startswith(col))
                c.collective_counts[kind] = (
                    c.collective_counts.get(kind, 0) + out_b)
            elif ins.op in ("parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast"):
                continue
            else:
                c.bytes += in_b + out_b
        memo[name] = c
        return c

    def visit_fused_dots(name: str) -> float:
        comp = comps.get(name)
        if comp is None:
            return 0.0
        return sum(_dot_flops(ins, comp) for ins in comp.instrs
                   if ins.op == "dot")

    return visit(entry)
