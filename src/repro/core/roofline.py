"""Hierarchical, latency-aware roofline engine (paper Sec. II).

    time(kernel) = max( FLOPs / compute_throughput,
                        max over memory levels l of
                            traffic_l / bw_l  +  n_chunks_l * latency_l )

Traffic per level comes from the analytic tiling search (``tiling.py``):
an operand resident at level P crosses every boundary from P inward; at each
boundary its re-read factor is set by the traffic-minimising tiling that fits
the boundary's staging capacity.  Transfers are issued at the granularity of
the consuming (L2-resident) tile — or the operand's natural unit (e.g. one
head's K matrix) if smaller — and each issue pays the level's latency
(non-overlapped; the paper's NAND-class HBS has no deep request queue).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig
from repro.core.memspec import MemoryHierarchy
from repro.core.placement import Placement, capacity_aware
from repro.core.tiling import gemm_tiling
from repro.core.workload import (Kernel, Phase, decode_phase, prefill_phase,
                                 resident_bytes)


@dataclass
class KernelTime:
    kernel: Kernel
    compute_time: float
    level_time: Dict[str, float]
    level_traffic: Dict[str, float]
    level_chunks: Dict[str, float]

    @property
    def time(self) -> float:
        mem = max(self.level_time.values(), default=0.0)
        return max(self.compute_time, mem)

    @property
    def bottleneck(self) -> str:
        mem_lv, mem_t = "", 0.0
        for lv, t in self.level_time.items():
            if t > mem_t:
                mem_lv, mem_t = lv, t
        return "compute" if self.compute_time >= mem_t else mem_lv


def _consume_chunk_bytes(hier: MemoryHierarchy) -> float:
    """Granularity of streamed transfers = last on-die staging buffer (L2)."""
    # chain[1] is L2 in the NPU presets; fall back to innermost.
    lv = hier.chain[1] if len(hier.chain) > 1 else hier.chain[0]
    return lv.capacity or 8e6


def kernel_time(k: Kernel, hier: MemoryHierarchy, place: Placement
                ) -> KernelTime:
    eff = hier.compute.gemm_efficiency if k.kind == "gemm" else 1.0
    compute_t = k.total_flops() / (hier.compute.flops * eff)
    level_time: Dict[str, float] = {}
    level_traffic: Dict[str, float] = {}
    level_chunks: Dict[str, float] = {}
    chunk_cap = _consume_chunk_bytes(hier)

    for op in k.operands:
        for (loc, frac) in place.locations(op.tclass):
            if frac <= 0.0:
                continue
            path = hier.path_from(loc)
            for lv in path:
                # re-read factor at this boundary from the tiling search
                if k.kind == "gemm":
                    staging = hier.staging_capacity(lv.name)
                    t = gemm_tiling(k.M, k.N, k.K, k.dtype_bytes, staging)
                    reread = (t.traffic[op.role]
                              / (_role_bytes(k, op.role) or 1.0))
                    traffic = op.bytes * frac * max(reread, 1.0)
                    tile = t.tile_bytes[op.role] * k.batch
                else:
                    traffic = op.bytes * frac
                    tile = traffic
                gran = op.granularity or traffic
                chunk = max(min(chunk_cap, gran, tile, traffic), 1.0)
                n_chunks = math.ceil(traffic / chunk) if traffic else 0.0
                # structural repetition (collapsed identical layers)
                traffic *= k.count
                n_chunks *= k.count
                level_traffic[lv.name] = level_traffic.get(lv.name, 0.0) + traffic
                level_chunks[lv.name] = level_chunks.get(lv.name, 0.0) + n_chunks
    for lv_name, traffic in level_traffic.items():
        lv = hier.level(lv_name)
        level_time[lv_name] = (traffic / lv.bandwidth
                               + level_chunks[lv_name] * lv.latency)
    return KernelTime(k, compute_t, level_time, level_traffic, level_chunks)


def _role_bytes(k: Kernel, role: str) -> float:
    """Per-GEMM-instance logical bytes (the tiling search is per instance)."""
    if role == "A":
        return float(k.M * k.K * k.dtype_bytes)
    if role == "B":
        return float(k.K * k.N * k.dtype_bytes)
    return float(k.M * k.N * k.dtype_bytes)


# ---------------------------------------------------------------------- #

@dataclass
class PhaseReport:
    phase: str
    total: float
    by_group: Dict[str, float]
    by_level: Dict[str, float]
    compute_time: float
    bottleneck: str
    kernel_times: List[KernelTime] = field(repr=False, default_factory=list)

    def group_share(self, *groups: str, gemm_only: bool = True) -> float:
        """Share of (GEMM) kernel time spent in the given groups (Fig. 2b)."""
        sel, tot = 0.0, 0.0
        for kt in self.kernel_times:
            if gemm_only and kt.kernel.kind != "gemm":
                continue
            tot += kt.time
            if kt.kernel.group in groups:
                sel += kt.time
        return sel / tot if tot else 0.0


def phase_time(ph: Phase, hier: MemoryHierarchy, place: Placement
               ) -> PhaseReport:
    kts = [kernel_time(k, hier, place) for k in ph.kernels]
    by_group: Dict[str, float] = {}
    by_level: Dict[str, float] = {}
    comp = 0.0
    for kt in kts:
        by_group[kt.kernel.group] = by_group.get(kt.kernel.group, 0.0) + kt.time
        comp += kt.compute_time
        for lv, t in kt.level_time.items():
            by_level[lv] = by_level.get(lv, 0.0) + t
    total = sum(kt.time for kt in kts)
    # dominant bottleneck = level (or compute) accounting for most kernel time
    tally: Dict[str, float] = {}
    for kt in kts:
        tally[kt.bottleneck] = tally.get(kt.bottleneck, 0.0) + kt.time
    bott = max(tally, key=tally.get) if tally else "compute"
    return PhaseReport(ph.name, total, by_group, by_level, comp, bott, kts)


# ---------------------------------------------------------------------- #

@dataclass
class InferenceReport:
    arch: str
    prefill_len: int
    decode_len: int
    batch: int
    prefill: PhaseReport
    decode_samples: List[Tuple[int, PhaseReport]]
    prefill_time: float
    decode_time: float
    placement: str

    @property
    def total_time(self) -> float:
        return self.prefill_time + self.decode_time

    @property
    def tps(self) -> float:
        """Tokens/s over the full request (the paper's interactivity metric)."""
        return self.batch * self.decode_len / self.total_time

    @property
    def tps_decode_only(self) -> float:
        return self.batch * self.decode_len / self.decode_time

    @property
    def bottleneck(self) -> str:
        mid = self.decode_samples[len(self.decode_samples) // 2][1]
        return mid.bottleneck

    def decode_group_share(self, *groups: str) -> Tuple[float, float]:
        shares = [r.group_share(*groups) for _, r in self.decode_samples]
        return min(shares), max(shares)


def run_inference(cfg: ArchConfig, hier: MemoryHierarchy, place: Placement,
                  prefill_len: int, decode_len: int, batch: int = 1,
                  dtype_bytes: int = 2, n_samples: int = 9,
                  capacity_check: bool = True) -> InferenceReport:
    """End-to-end TPS: prefill once + integrate decode over growing context."""
    if capacity_check:
        fp = resident_bytes(cfg, prefill_len + decode_len, batch, dtype_bytes)
        place = capacity_aware(place, hier, fp)
    pf = prefill_phase(cfg, prefill_len, batch, dtype_bytes)
    pf_rep = phase_time(pf, hier, place)
    # decode time: per-step cost is piecewise-linear in ctx -> sample + trapezoid
    lo, hi = prefill_len, prefill_len + decode_len
    n = max(2, min(n_samples, decode_len))
    xs = sorted({int(round(lo + (hi - lo) * i / (n - 1))) for i in range(n)})
    samples = [(x, phase_time(decode_phase(cfg, x, batch, dtype_bytes),
                              hier, place)) for x in xs]
    dec_t = 0.0
    for (x0, r0), (x1, r1) in zip(samples, samples[1:]):
        dec_t += 0.5 * (r0.total + r1.total) * (x1 - x0)
    if len(samples) == 1:
        dec_t = samples[0][1].total * decode_len
    return InferenceReport(cfg.name, prefill_len, decode_len, batch,
                           pf_rep, samples, pf_rep.total, dec_t, place.name)
