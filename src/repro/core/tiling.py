"""Analytic tiling search (paper Sec. II).

"GEMM/GEMV kernels are parallelized via tiling ... with tile sizes determined
by cache and memory capacities.  The memory access pattern ... is predictable
analytically.  Kernel latency is estimated by searching over candidate tiling
strategies at each memory hierarchy [level]."

For a blocked GEMM  C[M,N] += A[M,K] @ B[K,N]  staged through a buffer of
capacity ``C_bytes`` the boundary traffic under an output-stationary loop nest
with tiles (mt, nt, kt) is

    bytes(A) = M*K * ceil(N/nt)          (A re-streamed once per N-tile)
    bytes(B) = K*N * ceil(M/mt)          (B re-streamed once per M-tile)
    bytes(C) = M*N * (2*ceil(K/kt) - 1)  (partial-sum spills if kt < K)

subject to (mt*kt + kt*nt + mt*nt) * dtype <= C_bytes.  We search power-of-two
tile candidates (plus the exact dims) and return the traffic-minimising tiling.
GEMV (M==1) degenerates to compulsory traffic — the memory-wall regime the
paper targets.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Tuple


def _candidates(dim: int) -> Tuple[int, ...]:
    cands = {dim}
    p = 1
    while p < dim:
        cands.add(p)
        p *= 2
    return tuple(sorted(cands))


@dataclass(frozen=True)
class Tiling:
    mt: int
    nt: int
    kt: int
    traffic: Dict[str, float]      # role -> bytes crossing the boundary
    tile_bytes: Dict[str, float]   # role -> staged tile size (chunk unit)

    @property
    def total(self) -> float:
        return sum(self.traffic.values())


@functools.lru_cache(maxsize=200_000)
def gemm_tiling(M: int, N: int, K: int, dtype_bytes: int,
                capacity_bytes: float) -> Tiling:
    """Traffic-minimising tiling of one GEMM through one buffer level."""
    best = None
    cap_elems = max(capacity_bytes / dtype_bytes, 3.0)
    for mt in _candidates(M):
        if mt * 1 * 2 > cap_elems:   # even a k=1 sliver must fit
            break
        for nt in _candidates(N):
            if mt * nt > cap_elems:
                break
            # largest feasible kt given (mt, nt)
            kt_max = int((cap_elems - mt * nt) / max(mt + nt, 1))
            if kt_max < 1:
                continue
            kt = K if kt_max >= K else max(1, kt_max)
            a = M * K * math.ceil(N / nt)
            b = K * N * math.ceil(M / mt)
            c = M * N * (2 * math.ceil(K / kt) - 1)
            tot = (a + b + c) * dtype_bytes
            if best is None or tot < best[0]:
                best = (tot, mt, nt, kt, a, b, c)
    if best is None:  # degenerate capacity: stream element-wise
        a = M * K * N
        b = K * N * M
        c = 2 * M * N * K
        best = (float("inf"), 1, 1, 1, a, b, c)
    _, mt, nt, kt, a, b, c = best
    d = dtype_bytes
    return Tiling(
        mt=mt, nt=nt, kt=kt,
        traffic={"A": a * d, "B": b * d, "C": c * d},
        tile_bytes={"A": mt * kt * d, "B": kt * nt * d, "C": mt * nt * d},
    )


def elementwise_traffic(n_elems: int, dtype_bytes: int,
                        reads: int = 1, writes: int = 1) -> float:
    return float(n_elems) * dtype_bytes * (reads + writes)
