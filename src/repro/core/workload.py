"""LLM inference task-graph derivation (paper Sec. II).

Builds the per-phase kernel list — the six GEMM families
{X.W_qkv, Q.K^T, Softmax(R).V, Z.W_o, O.W_mlp1, O_mlp1.W_mlp2} plus
element-wise ops — for every architecture family in the pool (dense GQA,
MoE, MLA, SSM/SSD, hybrid, enc-dec, VLM), with per-operand tensor classes so
placement policies (paper Sec. III) can route each class to a memory tier.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.configs.base import ArchConfig


# --------------------------- tensor classes --------------------------- #
class TC:
    """The paper's placement knobs: weights vs Q/K/V vs activations."""
    W_ATTN = "w_attn"      # attention projection weights
    W_MLP = "w_mlp"        # MLP weights
    W_MOE = "w_moe"        # expert weights (streamed top-k)
    W_EMB = "w_emb"        # embedding / LM-head table
    W_SSM = "w_ssm"        # SSM block weights
    QKV = "qkv"            # current-token Q/K/V + attention intermediates
    KV = "kv"              # KV cache (grows with context)
    ACT = "act"            # other intermediate activations
    STATE = "state"        # SSM recurrent state (constant size)

    WEIGHTS = (W_ATTN, W_MLP, W_MOE, W_EMB, W_SSM)
    ALL = WEIGHTS + (QKV, KV, ACT, STATE)


@dataclass(frozen=True)
class Operand:
    role: str              # 'A' | 'B' | 'C'
    tclass: str
    bytes: float           # logical tensor bytes (single pass)
    granularity: float = 0.0  # natural transfer chunk; 0 -> tensor bytes


@dataclass(frozen=True)
class Kernel:
    name: str
    group: str             # qkv_gen | attn | proj | mlp | moe | embed | ssm | elem
    kind: str              # gemm | elemwise
    M: int
    N: int
    K: int
    dtype_bytes: int
    operands: tuple
    batch: int = 1         # independent GEMM instances (e.g. B * kv_heads)
    flops: float = 0.0     # 0 -> derived 2*batch*M*N*K
    count: int = 1         # structural repetition (layers collapsed)

    def total_flops(self) -> float:
        f = self.flops if self.flops else 2.0 * self.batch * self.M * self.N * self.K
        return f * self.count

    @property
    def is_attention(self) -> bool:
        return self.group == "attn"


def _gemm(name, group, M, N, K, b, *, A=TC.ACT, B=TC.W_MLP, C=TC.ACT,
          batch=1, count=1, a_bytes=None, b_bytes=None, c_bytes=None,
          b_gran=0.0, flops=0.0) -> Kernel:
    ab = a_bytes if a_bytes is not None else batch * M * K * b
    bb = b_bytes if b_bytes is not None else batch * K * N * b
    cb = c_bytes if c_bytes is not None else batch * M * N * b
    ops = (Operand("A", A, ab), Operand("B", B, bb, granularity=b_gran),
           Operand("C", C, cb))
    return Kernel(name, group, "gemm", M, N, K, b, ops, batch=batch,
                  flops=flops, count=count)


def _elem(name, n_elems, b, *, tclass=TC.ACT, reads=1, writes=1,
          flops_per=4.0, count=1) -> Kernel:
    ops = (Operand("A", tclass, n_elems * b * reads),
           Operand("C", tclass, n_elems * b * writes))
    return Kernel(name, "elem", "elemwise", 1, 1, 1, b, ops,
                  flops=flops_per * n_elems, count=count)


# ===================================================================== #
# per-layer kernel builders                                             #
# ===================================================================== #

def _attention_kernels(cfg: ArchConfig, *, new_tokens: int, ctx: int,
                       batch: int, b: int, count: int, tag: str,
                       kv_len: Optional[int] = None,
                       kv_class: str = TC.KV, causal: bool = True,
                       d_in: Optional[int] = None) -> List[Kernel]:
    """Dense/GQA attention: QKV gen, scores, AV, output projection."""
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = d_in if d_in is not None else cfg.d_model
    S = new_tokens
    L = kv_len if kv_len is not None else ctx
    group_sz = max(H // max(Hkv, 1), 1)
    ks: List[Kernel] = []
    n_qkv = (H + 2 * Hkv) * hd
    ks.append(_gemm(f"{tag}qkv_gen", "qkv_gen", batch * S, n_qkv, d, b,
                    A=TC.ACT, B=TC.W_ATTN, C=TC.QKV, count=count))
    # causal prefill touches ~L/2 of the keys on average
    cf = 0.5 if (causal and S > 1 and L == S) else 1.0
    kv_gran = L * hd * b          # one head's K (or V) matrix
    # scores: per kv-head, Q block (group*S x hd) x K^T (hd x L)
    ks.append(_gemm(f"{tag}attn_qk", "attn", group_sz * S, L, hd, b,
                    batch=batch * max(Hkv, 1), A=TC.QKV, B=kv_class, C=TC.QKV,
                    b_gran=kv_gran, count=count,
                    flops=2.0 * batch * H * S * L * hd * cf,
                    c_bytes=batch * H * S * L * b * cf))
    ks.append(_elem(f"{tag}softmax", batch * H * S * L * cf, b, tclass=TC.QKV,
                    flops_per=5.0, count=count))
    ks.append(_gemm(f"{tag}attn_av", "attn", group_sz * S, hd, L, b,
                    batch=batch * max(Hkv, 1), A=TC.QKV, B=kv_class, C=TC.QKV,
                    b_gran=kv_gran, count=count,
                    flops=2.0 * batch * H * S * L * hd * cf,
                    a_bytes=batch * H * S * L * b * cf))
    ks.append(_gemm(f"{tag}o_proj", "proj", batch * S, d, H * hd, b,
                    A=TC.QKV, B=TC.W_ATTN, C=TC.ACT, count=count))
    return ks


def _mla_kernels(cfg: ArchConfig, *, new_tokens: int, ctx: int, batch: int,
                 b: int, count: int) -> List[Kernel]:
    """DeepSeek-V2 MLA with the absorbed decode path.

    The latent cache (kv_lora + rope_dim wide) is SHARED across heads, so the
    score/AV GEMMs put all H heads on the M axis — the tiling search then
    captures cross-head latent reuse (unlike per-head GQA batching)."""
    m = cfg.mla
    assert m is not None
    H, d = cfg.n_heads, cfg.d_model
    S, L = new_tokens, ctx
    r, rq, dr = m.kv_lora_rank, m.q_lora_rank, m.rope_head_dim
    dn, dv = m.qk_nope_head_dim, m.v_head_dim
    w = r + dr
    ks: List[Kernel] = []
    ks.append(_gemm("mla_q_down", "qkv_gen", batch * S, rq, d, b,
                    A=TC.ACT, B=TC.W_ATTN, C=TC.QKV, count=count))
    ks.append(_gemm("mla_q_up", "qkv_gen", batch * S, H * (dn + dr), rq, b,
                    A=TC.QKV, B=TC.W_ATTN, C=TC.QKV, count=count))
    ks.append(_gemm("mla_kv_down", "qkv_gen", batch * S, w, d, b,
                    A=TC.ACT, B=TC.W_ATTN, C=TC.KV, count=count))
    # absorb: q_nope @ W_uk  ->  query in latent space
    ks.append(_gemm("mla_q_absorb", "qkv_gen", batch * S * H, r, dn, b,
                    A=TC.QKV, B=TC.W_ATTN, C=TC.QKV, count=count,
                    b_bytes=dn * r * H * b))
    cf = 0.5 if (S > 1 and L == S) else 1.0
    gran = L * w * b
    ks.append(_gemm("mla_score", "attn", H * S, L, w, b, batch=batch,
                    A=TC.QKV, B=TC.KV, C=TC.QKV, b_gran=gran, count=count,
                    flops=2.0 * batch * H * S * L * w * cf,
                    c_bytes=batch * H * S * L * b * cf))
    ks.append(_elem("mla_softmax", batch * H * S * L * cf, b, tclass=TC.QKV,
                    flops_per=5.0, count=count))
    ks.append(_gemm("mla_av", "attn", H * S, r, L, b, batch=batch,
                    A=TC.QKV, B=TC.KV, C=TC.QKV, b_gran=gran, count=count,
                    flops=2.0 * batch * H * S * L * r * cf,
                    a_bytes=batch * H * S * L * b * cf))
    ks.append(_gemm("mla_v_up", "proj", batch * S * H, dv, r, b,
                    A=TC.QKV, B=TC.W_ATTN, C=TC.QKV, count=count,
                    b_bytes=r * dv * H * b))
    ks.append(_gemm("mla_o_proj", "proj", batch * S, d, H * dv, b,
                    A=TC.QKV, B=TC.W_ATTN, C=TC.ACT, count=count))
    return ks


def _ffn_kernels(cfg: ArchConfig, d_ff: int, *, tokens: int, b: int,
                 count: int, wclass: str = TC.W_MLP, tag: str = "",
                 weight_mult: float = 1.0, flop_tokens: Optional[int] = None
                 ) -> List[Kernel]:
    """MLP kernels. ``weight_mult`` scales weight traffic (distinct experts);
    ``flop_tokens`` scales FLOPs (tokens actually processed)."""
    d = cfg.d_model
    ft = flop_tokens if flop_tokens is not None else tokens
    ks = []
    n_up = 2 * d_ff if cfg.gated_mlp else d_ff
    ks.append(_gemm(f"{tag}mlp1", "mlp", tokens, n_up, d, b,
                    A=TC.ACT, B=wclass, C=TC.ACT, count=count,
                    b_bytes=d * n_up * b * weight_mult,
                    flops=2.0 * ft * n_up * d))
    if cfg.gated_mlp:
        ks.append(_elem(f"{tag}swiglu", tokens * d_ff, b, flops_per=6.0,
                        count=count))
    ks.append(_gemm(f"{tag}mlp2", "mlp", tokens, d, d_ff, b,
                    A=TC.ACT, B=wclass, C=TC.ACT, count=count,
                    b_bytes=d_ff * d * b * weight_mult,
                    flops=2.0 * ft * d * d_ff))
    return ks


def _moe_kernels(cfg: ArchConfig, *, tokens: int, b: int, count: int
                 ) -> List[Kernel]:
    m = cfg.moe
    assert m is not None
    d = cfg.d_model
    ks = [_gemm("router", "moe", tokens, m.n_experts, d, b,
                A=TC.ACT, B=TC.W_MOE, C=TC.ACT, count=count)]
    # expected number of DISTINCT experts whose weights must be streamed
    p_untouched = (1.0 - m.top_k / m.n_experts) ** tokens
    distinct = m.n_experts * (1.0 - p_untouched)
    ks += _ffn_kernels(cfg, m.d_ff_expert, tokens=tokens, b=b, count=count,
                       wclass=TC.W_MOE, tag="moe_", weight_mult=distinct,
                       flop_tokens=tokens * m.top_k)
    if m.n_shared:
        ks += _ffn_kernels(cfg, m.d_ff_expert * m.n_shared, tokens=tokens,
                           b=b, count=count, wclass=TC.W_MOE, tag="moe_shared_")
    if m.dense_residual:
        ks += _ffn_kernels(cfg, m.d_ff_dense or cfg.d_ff, tokens=tokens, b=b,
                           count=count, tag="residual_")
    return ks


def _ssm_kernels(cfg: ArchConfig, *, new_tokens: int, batch: int, b: int,
                 count: int) -> List[Kernel]:
    """Mamba-2 SSD block. Decode: O(1) state update; prefill: chunked scan."""
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    di, nh, ng = s.d_inner(d), s.n_heads(d), s.n_groups
    N = s.state_dim
    T = batch * new_tokens
    n_in = 2 * di + 2 * ng * N + nh
    ks = [_gemm("ssm_in_proj", "ssm", T, n_in, d, b,
                A=TC.ACT, B=TC.W_SSM, C=TC.ACT, count=count)]
    ks.append(_elem("ssm_conv", T * (di + 2 * ng * N), b, flops_per=2 * s.conv_width,
                    count=count))
    # state update + output: per token, per head: dh x N outer products
    state_elems = batch * nh * s.head_dim * N
    ks.append(Kernel("ssm_scan", "ssm", "elemwise", 1, 1, 1, b,
                     (Operand("A", TC.STATE, state_elems * b * new_tokens),
                      Operand("C", TC.STATE, state_elems * b * new_tokens)),
                     flops=6.0 * T * nh * s.head_dim * N, count=count))
    ks.append(_gemm("ssm_out_proj", "ssm", T, d, di, b,
                    A=TC.ACT, B=TC.W_SSM, C=TC.ACT, count=count))
    return ks


# ===================================================================== #
# phase builders                                                        #
# ===================================================================== #

def _layer_plan(cfg: ArchConfig):
    """Collapse identical layers: yields (spec_kind, kwargs, count)."""
    if cfg.family == "ssm":
        return [("ssm", {}, cfg.n_layers)]
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1) if cfg.attn_every else 0
        return [("ssm", {}, cfg.n_layers), ("attn_shared", {}, n_attn)]
    plans = []
    if cfg.local_global_ratio and cfg.sliding_window:
        n_global = sum(1 for i in range(cfg.n_layers)
                       if cfg.attention_kind(i) == "global")
        plans.append(("attn", {"local": False}, n_global))
        plans.append(("attn", {"local": True}, cfg.n_layers - n_global))
    else:
        plans.append(("attn", {"local": bool(cfg.sliding_window)},
                      cfg.n_layers))
    if cfg.moe is not None:
        n_moe = cfg.n_layers - cfg.moe.first_dense
        if cfg.moe.first_dense:
            plans.append(("ffn_dense", {}, cfg.moe.first_dense))
        plans.append(("moe", {}, n_moe))
    else:
        plans.append(("ffn_dense", {}, cfg.n_layers))
    return plans


def _block_kernels(cfg: ArchConfig, kind: str, kw: dict, count: int, *,
                   new_tokens: int, ctx: int, batch: int, b: int
                   ) -> List[Kernel]:
    if kind == "ssm":
        return _ssm_kernels(cfg, new_tokens=new_tokens, batch=batch, b=b,
                            count=count)
    if kind == "attn_shared":
        ks = _attention_kernels(cfg, new_tokens=new_tokens, ctx=ctx,
                                batch=batch, b=b, count=count, tag="shared_")
        # zamba2: per-site projection back into the backbone width
        ks.append(_gemm("shared_site_proj", "proj", batch * new_tokens,
                        cfg.d_model, cfg.d_model, b, A=TC.ACT, B=TC.W_ATTN,
                        C=TC.ACT, count=count))
        return ks
    if kind == "attn":
        if cfg.mla is not None:
            ks = _mla_kernels(cfg, new_tokens=new_tokens, ctx=ctx,
                              batch=batch, b=b, count=count)
        else:
            kv_len = ctx
            if kw.get("local") and cfg.sliding_window:
                kv_len = min(ctx, cfg.sliding_window)
            ks = _attention_kernels(cfg, new_tokens=new_tokens, ctx=ctx,
                                    batch=batch, b=b, count=count, tag="",
                                    kv_len=kv_len)
        ks.append(_elem("norm_attn", batch * new_tokens * cfg.d_model, b,
                        flops_per=6.0, count=count))
        return ks
    if kind == "moe":
        return _moe_kernels(cfg, tokens=batch * new_tokens, b=b, count=count)
    if kind == "ffn_dense":
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                else cfg.d_ff)
        ks = _ffn_kernels(cfg, d_ff, tokens=batch * new_tokens, b=b,
                          count=count)
        ks.append(_elem("norm_mlp", batch * new_tokens * cfg.d_model, b,
                        flops_per=6.0, count=count))
        return ks
    raise ValueError(kind)


def _encoder_kernels(cfg: ArchConfig, batch: int, b: int) -> List[Kernel]:
    """Enc-dec (whisper): encoder runs once per request at prefill."""
    S = cfg.source_len
    ks = _attention_kernels(cfg, new_tokens=S, ctx=S, batch=batch, b=b,
                            count=cfg.enc_layers, tag="enc_", causal=False)
    ks += _ffn_kernels(cfg, cfg.d_ff, tokens=batch * S, b=b,
                       count=cfg.enc_layers, tag="enc_")
    return ks


def _cross_attention_kernels(cfg: ArchConfig, *, new_tokens: int, batch: int,
                             b: int) -> List[Kernel]:
    return _attention_kernels(cfg, new_tokens=new_tokens, ctx=cfg.source_len,
                              batch=batch, b=b, count=cfg.n_layers,
                              tag="cross_", kv_len=cfg.source_len,
                              causal=False)


def lm_head_kernel(cfg: ArchConfig, tokens: int, b: int) -> Kernel:
    return _gemm("lm_head", "embed", tokens, cfg.vocab, cfg.d_model, b,
                 A=TC.ACT, B=TC.W_EMB, C=TC.ACT)


@dataclass
class Phase:
    name: str                 # 'prefill' | 'decode@<ctx>'
    kernels: List[Kernel]
    new_tokens: int
    ctx: int


def prefill_phase(cfg: ArchConfig, seq_len: int, batch: int = 1,
                  dtype_bytes: int = 2) -> Phase:
    b = dtype_bytes
    ks: List[Kernel] = []
    if cfg.enc_layers:
        ks += _encoder_kernels(cfg, batch, b)
    for kind, kw, count in _layer_plan(cfg):
        ks += _block_kernels(cfg, kind, kw, count, new_tokens=seq_len,
                             ctx=seq_len, batch=batch, b=b)
    if cfg.enc_layers:
        ks += _cross_attention_kernels(cfg, new_tokens=seq_len, batch=batch,
                                       b=b)
    ks.append(lm_head_kernel(cfg, batch, b))  # only last position sampled
    return Phase("prefill", ks, new_tokens=seq_len, ctx=seq_len)


def decode_phase(cfg: ArchConfig, ctx: int, batch: int = 1,
                 dtype_bytes: int = 2) -> Phase:
    """One decode step with a KV cache of length ``ctx``."""
    b = dtype_bytes
    ks: List[Kernel] = []
    for kind, kw, count in _layer_plan(cfg):
        ks += _block_kernels(cfg, kind, kw, count, new_tokens=1, ctx=ctx,
                             batch=batch, b=b)
    if cfg.enc_layers:
        ks += _cross_attention_kernels(cfg, new_tokens=1, batch=batch, b=b)
    ks.append(lm_head_kernel(cfg, batch, b))
    return Phase(f"decode@{ctx}", ks, new_tokens=1, ctx=ctx)


# --------------------------- footprints ------------------------------ #

def resident_bytes(cfg: ArchConfig, ctx: int, batch: int,
                   dtype_bytes: int = 2) -> dict:
    """Static residency per tensor class (for capacity-aware placement)."""
    weights = {TC.W_ATTN: 0.0, TC.W_MLP: 0.0, TC.W_MOE: 0.0,
               TC.W_SSM: 0.0, TC.W_EMB: 0.0}
    d = cfg.d_model
    for i in range(cfg.n_layers):
        if cfg.family in ("ssm", "hybrid"):
            weights[TC.W_SSM] += cfg._ssm_params()
        else:
            weights[TC.W_ATTN] += cfg._attn_params()
            if cfg.moe is not None and i >= cfg.moe.first_dense:
                m = cfg.moe
                weights[TC.W_MOE] += ((m.n_experts + m.n_shared)
                                      * cfg._ffn_params(m.d_ff_expert)
                                      + m.n_experts * d)
                if m.dense_residual:
                    weights[TC.W_MLP] += cfg._ffn_params(m.d_ff_dense or cfg.d_ff)
            else:
                dff = (cfg.moe.d_ff_dense if (cfg.moe and i < cfg.moe.first_dense
                                              and cfg.moe.d_ff_dense)
                       else cfg.d_ff)
                weights[TC.W_MLP] += cfg._ffn_params(dff)
    if cfg.family == "hybrid" and cfg.attn_every:
        weights[TC.W_ATTN] += cfg._attn_params() + d * d * (
            1 + cfg.n_layers // cfg.attn_every)
    if cfg.enc_layers:
        weights[TC.W_ATTN] += (cfg.enc_layers + cfg.n_layers) * cfg._attn_params()
        weights[TC.W_MLP] += cfg.enc_layers * cfg._ffn_params(cfg.d_ff)
    weights[TC.W_EMB] += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    fp = {k: v * dtype_bytes for k, v in weights.items()}
    fp[TC.KV] = float(cfg.kv_bytes_per_token(dtype_bytes)) * ctx * batch
    if cfg.enc_layers:
        fp[TC.KV] += (2 * cfg.n_layers * cfg.source_len * cfg.n_kv_heads
                      * cfg.head_dim * dtype_bytes * batch)
    if cfg.ssm is not None:
        s = cfg.ssm
        fp[TC.STATE] = (cfg.n_layers * s.n_heads(d) * s.head_dim * s.state_dim
                        * dtype_bytes * batch)
    else:
        fp[TC.STATE] = 0.0
    fp[TC.QKV] = 4.0 * d * batch * dtype_bytes * cfg.n_layers  # transient
    fp[TC.ACT] = 8.0 * d * batch * dtype_bytes * cfg.n_layers
    return fp
