"""Memory-system specification for the hierarchical roofline model (paper Sec. II).

A ``MemoryHierarchy`` is an ordered chain of ``MemoryLevel``s from the level
closest to compute (NPU scratchpad) outward (DDR, HBS).  *Side tiers* (the
paper's hybrid-bonded SRAM chiplet) attach at a chain position: tensors placed
there stream straight to the inner levels without crossing the outer chain.

Units: bytes, bytes/s, seconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

GB = 1e9
MB = 1e6
KB = 1e3
US = 1e-6
NS = 1e-9


@dataclass(frozen=True)
class MemoryLevel:
    name: str
    capacity: Optional[float]      # bytes; None = effectively unbounded
    bandwidth: float               # bytes/s sustained
    latency: float = 0.0           # seconds per chunk issue (non-overlapped)

    def replace(self, **kw) -> "MemoryLevel":
        return replace(self, **kw)


@dataclass(frozen=True)
class ComputeSpec:
    name: str
    flops: float                   # peak FLOP/s at the working precision
    # efficiency multiplier applied to peak for GEMM-shaped work (MXU/PE
    # utilisation); the paper uses plain peak => 1.0 for NPU presets.
    gemm_efficiency: float = 1.0


@dataclass(frozen=True)
class MemoryHierarchy:
    """Chain ordered innermost-first + optional side tiers.

    ``side_tiers`` maps tier name -> (MemoryLevel, attach_pos); a tensor
    placed on a side tier crosses that tier's boundary and then every chain
    boundary *below* attach_pos (paper: chiplet sits "at the same footing as
    L2", attach_pos = index of L2).
    """
    compute: ComputeSpec
    chain: Tuple[MemoryLevel, ...]                 # innermost first
    side_tiers: Dict[str, Tuple[MemoryLevel, int]] = field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def level(self, name: str) -> MemoryLevel:
        for lv in self.chain:
            if lv.name == name:
                return lv
        if name in self.side_tiers:
            return self.side_tiers[name][0]
        raise KeyError(f"no memory level {name!r}")

    def chain_pos(self, name: str) -> int:
        for i, lv in enumerate(self.chain):
            if lv.name == name:
                return i
        if name in self.side_tiers:
            return self.side_tiers[name][1]
        raise KeyError(f"no memory level {name!r}")

    def path_from(self, name: str) -> Tuple[MemoryLevel, ...]:
        """Levels whose *outbound* boundary the tensor's bytes cross.

        For a tensor resident at `name`, bytes are read out of `name`, then
        out of every chain level strictly inside it, down to (excluding) the
        innermost level (whose inner boundary is the register file, treated
        as free).
        """
        if name in self.side_tiers:
            tier, pos = self.side_tiers[name]
            # attach_pos = chain index the tier sits BESIDE: data from the
            # tier crosses the same inner boundaries as data resident there.
            return (tier,) + tuple(self.chain[1:pos])[::-1]
        pos = self.chain_pos(name)
        return tuple(self.chain[1:pos + 1])[::-1] if pos > 0 else ()

    def outermost(self) -> MemoryLevel:
        return self.chain[-1]

    def with_level(self, name: str, **kw) -> "MemoryHierarchy":
        new_chain = tuple(lv.replace(**kw) if lv.name == name else lv
                          for lv in self.chain)
        new_side = {k: (lv.replace(**kw) if k == name else lv, pos)
                    for k, (lv, pos) in self.side_tiers.items()}
        return replace(self, chain=new_chain, side_tiers=new_side)

    def with_side_tier(self, name: str, level: MemoryLevel,
                       attach_pos: int) -> "MemoryHierarchy":
        side = dict(self.side_tiers)
        side[name] = (level, attach_pos)
        return replace(self, side_tiers=side)

    # staging capacity just inside a given level: bounds transfer chunk size
    def staging_capacity(self, name: str) -> float:
        pos = self.chain_pos(name)
        if pos <= 0:
            return self.chain[0].capacity or 0.0
        inner = self.chain[pos - 1]
        return inner.capacity if inner.capacity else 64 * MB


# ===================================================================== #
# Presets (paper Sec. III experiment design)                            #
# ===================================================================== #

def npu_compute(tflops: float = 35.0) -> ComputeSpec:
    """The paper's single-NPU instance: 35 TFLOP/s across all PEs."""
    return ComputeSpec("npu-35T", flops=tflops * 1e12)


def scratchpad(mb: float = 2.0) -> MemoryLevel:
    return MemoryLevel("spm", capacity=mb * MB, bandwidth=8e12, latency=20 * NS)


def l2(mb: float = 8.0) -> MemoryLevel:
    return MemoryLevel("l2", capacity=mb * MB, bandwidth=2e12, latency=50 * NS)


def lpddr6(bw_gbps: float = 173.0, latency_ns: float = 100.0,
           capacity_gb: float = 16.0, name: str = "ddr") -> MemoryLevel:
    """LPDDR6 (173 GB/s) or 3x-stacked (520 GB/s) per the paper."""
    return MemoryLevel(name, capacity=capacity_gb * GB, bandwidth=bw_gbps * GB,
                       latency=latency_ns * NS)


def hbs(bw_gbps: float, latency_us: float, capacity_gb: float = 1024.0
        ) -> MemoryLevel:
    """High Bandwidth Storage: NAND with many small planes, 16 IO/plane,
    1-4 Gb/s per IO => DDR-class bandwidth at microsecond latency."""
    return MemoryLevel("hbs", capacity=capacity_gb * GB, bandwidth=bw_gbps * GB,
                       latency=latency_us * US)


def ssd_pcie(gen: int = 5) -> MemoryLevel:
    """Baseline offload tier the paper compares against: PCIe Gen5/Gen6 SSD."""
    bw = {5: 16.0, 6: 32.0}[gen]
    return MemoryLevel("ssd", capacity=2048 * GB, bandwidth=bw * GB,
                       latency=80 * US)


def sram_chiplet(bw_gbps: float, capacity_mb: float = 128.0,
                 latency_ns: float = 50.0) -> MemoryLevel:
    """Hybrid-bonded SRAM global-buffer chiplet (paper Sec. III, Fig. 4).

    >68 MB so it holds Q + KV of small models; custom interface to the NPU
    logic die, bandwidth swept 173 GB/s - 1 TB/s in the paper."""
    return MemoryLevel("chiplet", capacity=capacity_mb * MB,
                       bandwidth=bw_gbps * GB, latency=latency_ns * NS)


def npu_hierarchy(ddr: MemoryLevel, hbs_level: Optional[MemoryLevel] = None,
                  chiplet: Optional[MemoryLevel] = None,
                  tflops: float = 35.0, spm_mb: float = 2.0,
                  l2_mb: float = 8.0) -> MemoryHierarchy:
    """Paper base hierarchy: spm - L2 - DDR [- HBS] [+ chiplet beside L2]."""
    chain = [scratchpad(spm_mb), l2(l2_mb), ddr]
    if hbs_level is not None:
        chain.append(hbs_level)
    h = MemoryHierarchy(compute=npu_compute(tflops), chain=tuple(chain))
    if chiplet is not None:
        h = h.with_side_tier("chiplet", chiplet, attach_pos=1)  # beside L2
    return h


# --------------------------- TPU v5e target --------------------------- #
# Deliverable (g): the same engine retargeted at the production pod.
V5E_PEAK_BF16 = 197e12          # FLOP/s per chip
V5E_HBM_BW = 819e9              # bytes/s per chip
V5E_ICI_BW = 50e9               # bytes/s per link
V5E_HBM_GB = 16.0
V5E_VMEM_MB = 128.0


def tpu_v5e_hierarchy() -> MemoryHierarchy:
    chain = (
        MemoryLevel("vmem", capacity=V5E_VMEM_MB * MB, bandwidth=40e12,
                    latency=0.0),
        MemoryLevel("hbm", capacity=V5E_HBM_GB * GB, bandwidth=V5E_HBM_BW,
                    latency=1 * US),
        MemoryLevel("ici", capacity=None, bandwidth=V5E_ICI_BW,
                    latency=1 * US),
    )
    return MemoryHierarchy(
        compute=ComputeSpec("tpu-v5e", flops=V5E_PEAK_BF16), chain=chain)
