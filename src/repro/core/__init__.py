"""The paper's primary contribution: a hierarchical, latency-aware roofline
performance model for gen-AI inference over emerging memory technologies
(HBS, bonded SRAM chiplet), plus its TPU-pod retargeting used by the
dry-run roofline deliverable."""
from repro.core import (concurrency, memspec, placement, roofline, stco,
                        tiling, tpu_roofline, workload)
from repro.core.concurrency import (ChipletGridPoint, ConcurrencyPoint,
                                    HBSGridPoint, chiplet_interactivity_sweep,
                                    chiplet_kv_hit_frac,
                                    compounded_offload_envelope,
                                    concurrency_sweep, concurrent_inference,
                                    expected_tokens_per_pass,
                                    hbs_interactivity_sweep, kv_dedup_factor,
                                    max_concurrency_without_spill,
                                    min_hbs_bandwidth_for_itl,
                                    placement_with_kv_split, speculative_tps)
from repro.core.memspec import (ComputeSpec, MemoryHierarchy, MemoryLevel,
                                hbs, lpddr6, npu_hierarchy, sram_chiplet,
                                ssd_pcie, tpu_v5e_hierarchy)
from repro.core.placement import (Placement, all_hbs, capacity_aware,
                                  chiplet_mlp_weights, chiplet_qkv, ddr_only,
                                  make_placement, qkv_in_ddr)
from repro.core.roofline import (InferenceReport, KernelTime, PhaseReport,
                                 kernel_time, phase_time, run_inference)
from repro.core.workload import (TC, Kernel, Phase, decode_phase,
                                 prefill_phase, resident_bytes)

__all__ = [
    "concurrency", "memspec", "placement", "roofline", "stco", "tiling",
    "tpu_roofline", "workload",
    "ChipletGridPoint", "ConcurrencyPoint", "HBSGridPoint",
    "chiplet_interactivity_sweep", "chiplet_kv_hit_frac",
    "compounded_offload_envelope", "concurrency_sweep",
    "concurrent_inference", "expected_tokens_per_pass",
    "hbs_interactivity_sweep", "kv_dedup_factor",
    "max_concurrency_without_spill", "min_hbs_bandwidth_for_itl",
    "placement_with_kv_split", "speculative_tps",
    "ComputeSpec", "MemoryHierarchy", "MemoryLevel", "hbs", "lpddr6",
    "npu_hierarchy", "sram_chiplet", "ssd_pcie", "tpu_v5e_hierarchy",
    "Placement", "all_hbs", "capacity_aware", "chiplet_mlp_weights",
    "chiplet_qkv", "ddr_only", "make_placement", "qkv_in_ddr",
    "InferenceReport", "KernelTime", "PhaseReport", "kernel_time",
    "phase_time", "run_inference",
    "TC", "Kernel", "Phase", "decode_phase", "prefill_phase",
    "resident_bytes",
]
