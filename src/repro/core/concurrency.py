"""Concurrency-aware analytical serving model (paper capacity pressure).

The paper observes that "even a low degree of concurrent inference serving
… can further add to memory capacity pressure": every in-flight request
adds a full KV cache, so the aggregate ``TC.KV`` footprint grows linearly
with concurrency while the hierarchy's fast tiers do not. This module asks
the roofline engine the resulting question for ANY hierarchy preset: how
does TPS scale with the number of concurrent requests once the KV class
starts spilling to slower tiers?

It is the analytical twin of the runtime's paged KV pool: a
``PagedKVManager.kv_tier_split()`` can be passed in verbatim (``kv_split``)
to price attention traffic with the tier occupancy the runtime actually
produced, instead of the greedy capacity_aware split.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.memspec import MemoryHierarchy
from repro.core.placement import Placement, capacity_aware
from repro.core.roofline import InferenceReport, run_inference
from repro.core.workload import TC, resident_bytes


@dataclass(frozen=True)
class ConcurrencyPoint:
    """One point of the TPS-vs-concurrency curve."""
    n_concurrent: int
    report: InferenceReport
    kv_bytes: float                              # aggregate KV footprint
    kv_locations: Tuple[Tuple[str, float], ...]  # where the KV ended up
    kv_preferred: str                            # the policy's KV tier

    @property
    def aggregate_tps(self) -> float:
        return self.report.tps

    @property
    def per_request_tps(self) -> float:
        return self.report.tps / self.n_concurrent

    @property
    def kv_spill_frac(self) -> float:
        """Fraction of the KV class NOT on its preferred tier."""
        on_pref = sum(frac for level, frac in self.kv_locations
                      if level == self.kv_preferred)
        return 1.0 - on_pref

    @property
    def bottleneck(self) -> str:
        return self.report.bottleneck


def placement_with_kv_split(place: Placement,
                            kv_split: Sequence[Tuple[str, float]]
                            ) -> Placement:
    """Pin the KV class to an explicit tier split (e.g. the runtime paged
    pool's ``kv_tier_split()``) instead of the policy's preferred tier."""
    splits = dict(place.splits)
    splits[TC.KV] = tuple(kv_split)
    return Placement(place.name + "+kvrt", dict(place.mapping), splits)


def concurrent_inference(cfg: ArchConfig, hier: MemoryHierarchy,
                         place: Placement, *, n_concurrent: int,
                         prefill_len: int, decode_len: int,
                         dtype_bytes: int = 2,
                         kv_split: Optional[Sequence[Tuple[str, float]]] = None
                         ) -> ConcurrencyPoint:
    """Serve ``n_concurrent`` simultaneous requests analytically.

    The aggregate KV footprint (``TC.KV`` scaled by batch) runs through
    ``capacity_aware`` spilling, so past the fast tier's capacity the
    marginal request pays slow-tier attention traffic — the capacity-
    pressure curve the runtime engine measures.

    A pinned ``kv_split`` bypasses the greedy KV split entirely: the KV
    class is removed from the capacity pass (its tier occupancy is instead
    pre-charged against each tier's capacity) and the runtime-observed
    split is applied on top."""
    ctx = prefill_len + decode_len
    fp = resident_bytes(cfg, ctx, n_concurrent, dtype_bytes)
    if kv_split is not None:
        # charge the pinned KV residency against the tiers it occupies so
        # co-resident classes see the reduced capacity, then keep the KV
        # class out of capacity_aware (which would re-split and overwrite)
        kv_bytes = fp[TC.KV]
        charged = hier
        for level, frac in kv_split:
            cap = hier.level(level).capacity
            if cap is not None:
                charged = charged.with_level(
                    level, capacity=max(cap - frac * kv_bytes, 0.0))
        fp_rest = {c: v for c, v in fp.items() if c != TC.KV}
        placed = capacity_aware(place, charged, fp_rest)
        placed = placement_with_kv_split(placed, kv_split)
    else:
        placed = capacity_aware(place, hier, fp)
    rep = run_inference(cfg, hier, placed, prefill_len, decode_len,
                        batch=n_concurrent, dtype_bytes=dtype_bytes,
                        capacity_check=False)
    return ConcurrencyPoint(n_concurrent, rep, fp[TC.KV],
                            placed.locations(TC.KV), place.mapping[TC.KV])


def concurrency_sweep(cfg: ArchConfig, hier: MemoryHierarchy,
                      place: Placement, *,
                      concurrency: Iterable[int] = (1, 2, 4, 8, 16),
                      prefill_len: int = 2048, decode_len: int = 256,
                      dtype_bytes: int = 2) -> List[ConcurrencyPoint]:
    """TPS-vs-concurrency curve (the paper's experiment, any hierarchy)."""
    return [concurrent_inference(cfg, hier, place, n_concurrent=n,
                                 prefill_len=prefill_len,
                                 decode_len=decode_len,
                                 dtype_bytes=dtype_bytes)
            for n in concurrency]


def max_concurrency_without_spill(cfg: ArchConfig, hier: MemoryHierarchy,
                                  place: Placement, *, prefill_len: int,
                                  decode_len: int, dtype_bytes: int = 2,
                                  limit: int = 4096) -> int:
    """Largest concurrency whose aggregate KV still fits its preferred tier
    (the runtime admission controller's analytical counterpart)."""
    kv_level = place.mapping[TC.KV]
    cap = hier.level(kv_level).capacity
    if cap is None:
        return limit
    ctx = prefill_len + decode_len
    per_req = float(cfg.kv_bytes_per_token(dtype_bytes)) * ctx
    if per_req <= 0:
        return limit
    # the preferred tier also houses whatever other classes map to it
    fp1 = resident_bytes(cfg, ctx, 1, dtype_bytes)
    other = sum(v for c, v in fp1.items()
                if c != TC.KV and place.mapping.get(c) == kv_level)
    avail = max(cap - other, 0.0)
    return max(min(int(avail // per_req), limit), 0)
