"""Concurrency-aware analytical serving model (paper capacity pressure).

The paper observes that "even a low degree of concurrent inference serving
… can further add to memory capacity pressure": every in-flight request
adds a full KV cache, so the aggregate ``TC.KV`` footprint grows linearly
with concurrency while the hierarchy's fast tiers do not. This module asks
the roofline engine the resulting question for ANY hierarchy preset: how
does TPS scale with the number of concurrent requests once the KV class
starts spilling to slower tiers?

It is the analytical twin of the runtime's paged KV pool: a
``PagedKVManager.kv_tier_split()`` can be passed in verbatim (``kv_split``)
to price attention traffic with the tier occupancy the runtime actually
produced, instead of the greedy capacity_aware split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.memspec import MemoryHierarchy
from repro.core.placement import Placement, capacity_aware
from repro.core.roofline import InferenceReport, run_inference
from repro.core.workload import TC, resident_bytes


@dataclass(frozen=True)
class ConcurrencyPoint:
    """One point of the TPS-vs-concurrency curve."""
    n_concurrent: int
    report: InferenceReport
    kv_bytes: float                              # aggregate KV footprint
    kv_locations: Tuple[Tuple[str, float], ...]  # where the KV ended up
    kv_preferred: str                            # the policy's KV tier

    @property
    def aggregate_tps(self) -> float:
        return self.report.tps

    @property
    def per_request_tps(self) -> float:
        return self.report.tps / self.n_concurrent

    @property
    def kv_spill_frac(self) -> float:
        """Fraction of the KV class NOT on its preferred tier."""
        on_pref = sum(frac for level, frac in self.kv_locations
                      if level == self.kv_preferred)
        return 1.0 - on_pref

    @property
    def bottleneck(self) -> str:
        return self.report.bottleneck


def kv_dedup_factor(n_concurrent: int, prefill_len: int, decode_len: int, *,
                    shared_prefix_len: int = 0,
                    share_group: int = 1) -> float:
    """Unique / naive aggregate KV under prefix sharing.

    The runtime's shared-prefix page reuse (serving.kv_manager) stores the
    KV of a document prefix ONCE per document instead of once per request.
    With ``n_concurrent`` requests in groups of ``share_group`` over the
    same document (``shared_prefix_len`` tokens of the prompt), the unique
    footprint is ``n*(ctx - p) + ceil(n/g)*p`` tokens against the naive
    ``n*ctx`` — the factor the analytical sweep scales ``TC.KV`` by."""
    ctx = prefill_len + decode_len
    g = max(share_group, 1)
    p = min(max(shared_prefix_len, 0), prefill_len)
    if g <= 1 or p <= 0 or n_concurrent <= 0:
        return 1.0
    n = n_concurrent
    n_docs = -(-n // g)
    return (n * (ctx - p) + n_docs * p) / (n * ctx)


def placement_with_kv_split(place: Placement,
                            kv_split: Sequence[Tuple[str, float]]
                            ) -> Placement:
    """Pin the KV class to an explicit tier split (e.g. the runtime paged
    pool's ``kv_tier_split()``) instead of the policy's preferred tier."""
    splits = dict(place.splits)
    splits[TC.KV] = tuple(kv_split)
    return Placement(place.name + "+kvrt", dict(place.mapping), splits)


def concurrent_inference(cfg: ArchConfig, hier: MemoryHierarchy,
                         place: Placement, *, n_concurrent: int,
                         prefill_len: int, decode_len: int,
                         dtype_bytes: int = 2,
                         kv_split: Optional[Sequence[Tuple[str, float]]] = None,
                         shared_prefix_len: int = 0,
                         share_group: int = 1,
                         kv_shards: int = 1,
                         kv_dtype_bytes: Optional[int] = None
                         ) -> ConcurrencyPoint:
    """Serve ``n_concurrent`` simultaneous requests analytically.

    The aggregate KV footprint (``TC.KV`` scaled by batch) runs through
    ``capacity_aware`` spilling, so past the fast tier's capacity the
    marginal request pays slow-tier attention traffic — the capacity-
    pressure curve the runtime engine measures.

    ``shared_prefix_len``/``share_group`` model the runtime's prefix-page
    dedup: the aggregate KV is scaled by ``kv_dedup_factor`` before the
    capacity pass, so shared-document workloads spill later and fit more
    concurrency (the headroom the paged pool actually realizes).

    ``kv_shards`` is the per-device analytic view of head-sharded serving
    (DESIGN.md SS16): an N-way mesh leaves each device Hkv/N heads of
    every request's KV, so the per-chip ``TC.KV`` footprint — what this
    hierarchy's capacities constrain — divides by N while weights and
    activations replicate. The runtime twin is ``ServeEngine(shards=N)``,
    whose ``TierBudget`` divides page bytes the same way.

    A pinned ``kv_split`` bypasses the greedy KV split entirely: the KV
    class is removed from the capacity pass (its tier occupancy is instead
    pre-charged against each tier's capacity) and the runtime-observed
    split is applied on top.

    ``kv_dtype_bytes`` (runtime twin: ``ServeEngine(cache_dtype="int8")``)
    stores the KV class narrower than the compute dtype: the TC.KV
    footprint — what the capacity pass spills — scales by
    ``kv_dtype_bytes / dtype_bytes``, so a quantized cache fits more
    concurrency before tier spill. Traffic stays priced at the compute
    dtype here; the traffic-side scaling composes in
    ``min_hbs_bandwidth_for_itl(kv_traffic_scale=...)``."""
    if kv_shards < 1:
        raise ValueError(f"kv_shards ({kv_shards}) must be >= 1")
    ctx = prefill_len + decode_len
    fp = resident_bytes(cfg, ctx, n_concurrent, dtype_bytes)
    fp[TC.KV] = fp[TC.KV] * kv_dedup_factor(
        n_concurrent, prefill_len, decode_len,
        shared_prefix_len=shared_prefix_len,
        share_group=share_group) / kv_shards
    if kv_dtype_bytes is not None:
        if kv_dtype_bytes < 1:
            raise ValueError(f"kv_dtype_bytes ({kv_dtype_bytes}) must be >= 1")
        fp[TC.KV] = fp[TC.KV] * kv_dtype_bytes / dtype_bytes
    if kv_split is not None:
        # charge the pinned KV residency against the tiers it occupies so
        # co-resident classes see the reduced capacity, then keep the KV
        # class out of capacity_aware (which would re-split and overwrite)
        kv_bytes = fp[TC.KV]
        charged = hier
        for level, frac in kv_split:
            cap = hier.level(level).capacity
            if cap is not None:
                charged = charged.with_level(
                    level, capacity=max(cap - frac * kv_bytes, 0.0))
        fp_rest = {c: v for c, v in fp.items() if c != TC.KV}
        placed = capacity_aware(place, charged, fp_rest)
        placed = placement_with_kv_split(placed, kv_split)
    else:
        placed = capacity_aware(place, hier, fp)
    rep = run_inference(cfg, hier, placed, prefill_len, decode_len,
                        batch=n_concurrent, dtype_bytes=dtype_bytes,
                        capacity_check=False)
    return ConcurrencyPoint(n_concurrent, rep, fp[TC.KV],
                            placed.locations(TC.KV), place.mapping[TC.KV])


def concurrency_sweep(cfg: ArchConfig, hier: MemoryHierarchy,
                      place: Placement, *,
                      concurrency: Iterable[int] = (1, 2, 4, 8, 16),
                      prefill_len: int = 2048, decode_len: int = 256,
                      dtype_bytes: int = 2, shared_prefix_len: int = 0,
                      share_group: int = 1) -> List[ConcurrencyPoint]:
    """TPS-vs-concurrency curve (the paper's experiment, any hierarchy)."""
    return [concurrent_inference(cfg, hier, place, n_concurrent=n,
                                 prefill_len=prefill_len,
                                 decode_len=decode_len,
                                 dtype_bytes=dtype_bytes,
                                 shared_prefix_len=shared_prefix_len,
                                 share_group=share_group)
            for n in concurrency]


@dataclass(frozen=True)
class HBSGridPoint:
    """One cell of the HBS bandwidth x latency interactivity grid."""
    bw_gbps: float
    latency_us: float
    point: ConcurrencyPoint

    @property
    def tps(self) -> float:
        return self.point.aggregate_tps

    @property
    def itl_s(self) -> float:
        """Predicted per-request inter-token latency: all concurrent
        requests advance together, so decode wall time over the decode
        length is the seconds each request waits between its tokens."""
        rep = self.point.report
        return rep.decode_time / max(rep.decode_len, 1)

    @property
    def kv_spill_frac(self) -> float:
        return self.point.kv_spill_frac


def hbs_interactivity_sweep(cfg: ArchConfig, hier: MemoryHierarchy,
                            place: Placement, *,
                            bw_gbps: Iterable[float] = (2., 4., 8., 16., 32.),
                            latency_us: Iterable[float] = (5., 20., 80.),
                            n_concurrent: int = 1,
                            prefill_len: int = 8192, decode_len: int = 256,
                            dtype_bytes: int = 2,
                            kv_split: Optional[Sequence[Tuple[str, float]]]
                            = None) -> List[HBSGridPoint]:
    """The paper's HBS requirement table, analytically: TPS and predicted
    ITL over a bandwidth x latency grid for the ``"hbs"`` level of
    ``hier`` — the envelope HBS must hit for a long-context large-model
    workload to stay interactive once its KV spills past the fast tiers.

    The runtime twin is ``benchmarks/hbs_sweep.py``, which drives the
    serve engine's real page-residency offload over the same grid; a
    ``kv_split`` observed from ``PagedKVManager.kv_tier_split()`` (landed
    pages only — reserved lookahead pages carry no traffic) can be pinned
    here so both halves price the same placement."""
    hier.level("hbs")        # fail fast: with_level() would silently no-op
    out: List[HBSGridPoint] = []
    for bw in bw_gbps:
        for lat in latency_us:
            h = hier.with_level("hbs", bandwidth=bw * 1e9,
                                latency=lat * 1e-6)
            pt = concurrent_inference(cfg, h, place,
                                      n_concurrent=n_concurrent,
                                      prefill_len=prefill_len,
                                      decode_len=decode_len,
                                      dtype_bytes=dtype_bytes,
                                      kv_split=kv_split)
            out.append(HBSGridPoint(bw, lat, pt))
    return out


@dataclass(frozen=True)
class ChipletGridPoint:
    """One cell of the chiplet-capacity x HBS bandwidth/latency grid."""
    chiplet_mb: float
    hit_frac: float           # fraction of KV reads served by the chiplet
    base: HBSGridPoint

    @property
    def bw_gbps(self) -> float:
        return self.base.bw_gbps

    @property
    def latency_us(self) -> float:
        return self.base.latency_us

    @property
    def tps(self) -> float:
        return self.base.tps

    @property
    def itl_s(self) -> float:
        """HBS-bound approximation (DESIGN.md SS17): on a long-context
        decode the inter-token latency is dominated by streaming the KV
        off the offload link, so the fraction ``hit_frac`` of reads the
        bonded chiplet absorbs shrinks the ITL by ``1 - hit_frac``.
        Never worse than the chiplet-less base point by construction."""
        return self.base.itl_s * (1.0 - self.hit_frac)

    @property
    def kv_spill_frac(self) -> float:
        return self.base.kv_spill_frac


def chiplet_kv_hit_frac(cfg: ArchConfig, ctx: int, *, chiplet_mb: float,
                        dtype_bytes: int = 2) -> float:
    """Steady-state fraction of per-token KV reads served from a bonded
    chiplet buffer of ``chiplet_mb`` megabytes.

    Decode attention reads the whole landed context every token, so a
    capacity-``C`` buffer holding the hottest pages serves ``C / KV``
    of the read traffic once the EMA promoter has converged (the runtime
    twin is ``ServeStats.chiplet_hit_rate``). Clamped to [0, 1]; a buffer
    larger than the working set hits on every read."""
    if chiplet_mb <= 0:
        return 0.0
    kv = float(cfg.kv_bytes_per_token(dtype_bytes)) * max(ctx, 1)
    if kv <= 0:
        return 0.0
    return min(chiplet_mb * 1e6 / kv, 1.0)


def chiplet_interactivity_sweep(cfg: ArchConfig, hier: MemoryHierarchy,
                                place: Placement, *,
                                chiplet_mb: Iterable[float] = (32., 64., 128.),
                                bw_gbps: Iterable[float] = (2., 4., 8., 16.,
                                                            32.),
                                latency_us: Iterable[float] = (5., 20., 80.),
                                n_concurrent: int = 1,
                                prefill_len: int = 8192,
                                decode_len: int = 256,
                                dtype_bytes: int = 2,
                                kv_dtype_bytes: Optional[int] = None,
                                kv_split: Optional[Sequence[Tuple[str, float]]]
                                = None) -> List[ChipletGridPoint]:
    """The HBS interactivity grid with a chiplet global-buffer tier in
    front of it: every ``(chiplet capacity, HBS bandwidth, HBS latency)``
    cell reports the ITL after the chiplet's steady-state hit fraction
    absorbs its share of the KV streaming (DESIGN.md SS17).

    The base HBS grid is swept ONCE — the chiplet axis only rescales the
    readout — so the sweep costs the same roofline passes as
    ``hbs_interactivity_sweep``. The runtime twin is
    ``benchmarks/chiplet_sweep.py``, which drives the serve engine's EMA
    promoter over the same chiplet sizes. ``kv_dtype_bytes`` prices the
    hit fraction at the stored KV width: a narrower cache fits more
    context into the same chiplet, compounding the two levers."""
    grid = hbs_interactivity_sweep(cfg, hier, place, bw_gbps=bw_gbps,
                                   latency_us=latency_us,
                                   n_concurrent=n_concurrent,
                                   prefill_len=prefill_len,
                                   decode_len=decode_len,
                                   dtype_bytes=dtype_bytes,
                                   kv_split=kv_split)
    ctx = prefill_len + decode_len
    out: List[ChipletGridPoint] = []
    for mb in chiplet_mb:
        h = chiplet_kv_hit_frac(cfg, ctx, chiplet_mb=mb,
                                dtype_bytes=(kv_dtype_bytes
                                             if kv_dtype_bytes is not None
                                             else dtype_bytes))
        for g in grid:
            out.append(ChipletGridPoint(mb, h, g))
    return out


def expected_tokens_per_pass(alpha: float, k: int) -> float:
    """Expected tokens landed by ONE speculative verify pass with draft
    length ``k`` and per-position acceptance probability ``alpha``
    (DESIGN.md SS14).

    The accepted prefix is geometric — position j lands iff all of
    positions 0..j were accepted — and the correction/bonus token always
    lands, so E = sum_{j=0..k} alpha^j = (1 - alpha^(k+1)) / (1 - alpha),
    ranging from 1 (alpha=0: plain decode) to k+1 (alpha=1)."""
    if k < 0:
        raise ValueError("k must be >= 0")
    a = min(max(alpha, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculative_tps(base_tps: float, alpha: float, k: int, *,
                    overhead_frac: float = 0.0) -> float:
    """Analytic decode TPS with speculative decoding layered on a plain
    decode rate of ``base_tps``.

    A verify pass streams weights + KV once — the same traffic a single
    decode step pays, which is what ``base_tps`` prices — and lands
    ``expected_tokens_per_pass(alpha, k)`` tokens. ``overhead_frac`` is
    the extra per-pass cost relative to one plain step (draft compute +
    the verify window's K extra query rows; ~0 for n-gram drafts on a
    bandwidth-bound platform)."""
    e = expected_tokens_per_pass(alpha, k)
    return base_tps * e / (1.0 + max(overhead_frac, 0.0))


def min_hbs_bandwidth_for_itl(grid: Sequence[HBSGridPoint],
                              itl_target_s: float, *,
                              tokens_per_pass: float = 1.0,
                              overhead_frac: float = 0.0,
                              kv_traffic_scale: float = 1.0,
                              chiplet_hit_frac: float = 0.0
                              ) -> Dict[float, float]:
    """Per HBS latency, the smallest swept bandwidth whose predicted ITL
    meets the target (the paper's requirement readout); latencies whose
    entire bandwidth sweep misses the target map to ``inf``.

    ``tokens_per_pass`` (> 1 with speculative decoding; see
    ``expected_tokens_per_pass``) divides the effective ITL: each
    bandwidth-bound streaming pass emits that many tokens on average, so
    the SAME interactivity target is met at LOWER HBS bandwidth — the
    spec-compounded envelope. ``overhead_frac`` prices the per-pass draft
    + verify-window overhead.

    ``kv_traffic_scale`` (int8 KV: ``kv_dtype_bytes / dtype_bytes``) and
    ``chiplet_hit_frac`` (see ``chiplet_kv_hit_frac``) shrink the
    KV-streaming portion of the ITL under the HBS-bound approximation
    (DESIGN.md SS17): a narrower stored cache moves fewer bytes per read,
    and chiplet-resident hot pages never touch the HBS link at all, so
    ``itl_eff = itl * kv_traffic_scale * (1 - chiplet_hit_frac)``. Both
    factors are <= 1, so the returned envelope is never-worse than the
    plain one by construction. Defaults reproduce plain fp16 decode.
    Pass ``chiplet_hit_frac`` only with a plain ``HBSGridPoint`` grid —
    a ``ChipletGridPoint`` grid already folds its own hit fraction into
    ``itl_s``."""
    if tokens_per_pass <= 0:
        raise ValueError("tokens_per_pass must be > 0")
    if not 0.0 < kv_traffic_scale <= 1.0:
        raise ValueError(f"kv_traffic_scale ({kv_traffic_scale}) must be "
                         "in (0, 1]")
    if not 0.0 <= chiplet_hit_frac <= 1.0:
        raise ValueError(f"chiplet_hit_frac ({chiplet_hit_frac}) must be "
                         "in [0, 1]")
    scale = ((1.0 + max(overhead_frac, 0.0)) / tokens_per_pass
             * kv_traffic_scale * (1.0 - chiplet_hit_frac))
    best: Dict[float, float] = {}
    for g in grid:
        if g.itl_s * scale <= itl_target_s:
            cur = best.get(g.latency_us, float("inf"))
            best[g.latency_us] = min(cur, g.bw_gbps)
        else:
            best.setdefault(g.latency_us, float("inf"))
    return best


def compounded_offload_envelope(grid: Sequence[HBSGridPoint],
                                itl_target_s: float, *,
                                dtype_bytes: int = 2,
                                kv_dtype_bytes: int = 1,
                                chiplet_hit_frac: float = 0.0,
                                tokens_per_pass: float = 1.0,
                                overhead_frac: float = 0.0
                                ) -> Dict[float, float]:
    """The int8-KV x chiplet x speculative compounded HBS requirement:
    every lever the stack implements, priced against ONE swept grid.

    Quantized KV (``kv_dtype_bytes`` < ``dtype_bytes``) scales the bytes
    each streamed token moves; the chiplet's hit fraction removes its
    share of reads from the HBS link entirely; speculative decoding lands
    ``tokens_per_pass`` tokens per streaming pass. All three multiply
    into the effective ITL, so the minimum HBS bandwidth that keeps the
    platform interactive drops by the product — the paper's "technology
    solutions compound" readout. With all defaults at their identity
    values this is exactly ``min_hbs_bandwidth_for_itl(grid, target)``."""
    if kv_dtype_bytes < 1 or dtype_bytes < 1:
        raise ValueError("dtype widths must be >= 1 byte")
    if kv_dtype_bytes > dtype_bytes:
        raise ValueError(f"kv_dtype_bytes ({kv_dtype_bytes}) must not "
                         f"exceed dtype_bytes ({dtype_bytes})")
    return min_hbs_bandwidth_for_itl(
        grid, itl_target_s, tokens_per_pass=tokens_per_pass,
        overhead_frac=overhead_frac,
        kv_traffic_scale=kv_dtype_bytes / dtype_bytes,
        chiplet_hit_frac=chiplet_hit_frac)


def max_concurrency_without_spill(cfg: ArchConfig, hier: MemoryHierarchy,
                                  place: Placement, *, prefill_len: int,
                                  decode_len: int, dtype_bytes: int = 2,
                                  limit: int = 4096,
                                  shared_prefix_len: int = 0,
                                  share_group: int = 1) -> int:
    """Largest concurrency whose aggregate (dedup'd) KV still fits its
    preferred tier (the runtime admission controller's analytical
    counterpart). Prefix sharing shrinks the marginal request's KV, so the
    no-spill limit GROWS with the share factor — the extra concurrency the
    paged pool fits before tier spill."""
    kv_level = place.mapping[TC.KV]
    cap = hier.level(kv_level).capacity
    if cap is None:
        return limit
    ctx = prefill_len + decode_len
    per_tok = float(cfg.kv_bytes_per_token(dtype_bytes))
    if per_tok <= 0:
        return limit
    # the preferred tier also houses whatever other classes map to it
    fp1 = resident_bytes(cfg, ctx, 1, dtype_bytes)
    other = sum(v for c, v in fp1.items()
                if c != TC.KV and place.mapping.get(c) == kv_level)
    avail = max(cap - other, 0.0)
    g = max(share_group, 1)
    p = min(max(shared_prefix_len, 0), prefill_len) if g > 1 else 0
    best = 0
    for n in range(1, limit + 1):
        unique_tokens = n * (ctx - p) + (-(-n // g)) * p
        if unique_tokens * per_tok > avail:
            break
        best = n
    return best
