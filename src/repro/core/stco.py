"""System-Technology Co-Optimization driver (paper Sec. III methodology,
generalized): sweep (hierarchy x placement x model x context) -> TPS /
bottleneck / breakdown tables, plus requirement solvers ("what bandwidth /
latency does tier X need to reach T TPS?" — the paper's Fig. 1 question
asked programmatically).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.memspec import MemoryHierarchy, hbs, lpddr6, npu_hierarchy
from repro.core.placement import Placement
from repro.core.roofline import run_inference


@dataclass(frozen=True)
class SweepPoint:
    arch: str
    hierarchy: str
    placement: str
    prefill: int
    decode: int
    tps: float
    bottleneck: str
    attn_share: float


def sweep(cfgs: Sequence[ArchConfig],
          hierarchies: Dict[str, MemoryHierarchy],
          placements: Sequence[Placement],
          contexts: Sequence[Tuple[int, int]],
          *, batch: int = 1, dtype_bytes: int = 2,
          n_samples: int = 5) -> List[SweepPoint]:
    """Full-factorial STCO sweep; one engine configuration for all points."""
    def adapt(place: Placement, hier: MemoryHierarchy) -> Placement:
        """Remap tensor classes whose tier is absent to the outermost level
        (an all-in-HBS policy on an HBS-less hierarchy means all-in-DDR)."""
        names = {lv.name for lv in hier.chain} | set(hier.side_tiers)
        fallback = hier.outermost().name
        mapping = {c: (lv if lv in names else fallback)
                   for c, lv in place.mapping.items()}
        if mapping == place.mapping:
            return place
        return Placement(place.name, mapping, place.splits)

    out: List[SweepPoint] = []
    for cfg in cfgs:
        for hname, hier in hierarchies.items():
            for placement in placements:
                place = adapt(placement, hier)
                for pf, dec in contexts:
                    rep = run_inference(cfg, hier, place, pf, dec,
                                        batch=batch, dtype_bytes=dtype_bytes,
                                        n_samples=n_samples)
                    out.append(SweepPoint(
                        cfg.name, hname, place.name, pf, dec, rep.tps,
                        rep.bottleneck,
                        rep.decode_group_share("attn")[1]))
    return out


def required_bandwidth(cfg: ArchConfig, place: Placement, *,
                       target_tps: float, level: str = "hbs",
                       latency_us: float = 10.0, ddr_bw: float = 520.0,
                       prefill: int = 512, decode: int = 512,
                       lo: float = 8.0, hi: float = 4096.0,
                       tol: float = 0.02) -> Optional[float]:
    """Minimum ``level`` bandwidth (GB/s) reaching ``target_tps``
    (bisection over the monotone TPS(bw) curve — paper Fig. 1 inverted)."""
    def tps_at(bw: float) -> float:
        hier = npu_hierarchy(lpddr6(ddr_bw), hbs(bw, latency_us=latency_us))
        return run_inference(cfg, hier, place, prefill, decode,
                             n_samples=5).tps
    if tps_at(hi) < target_tps:
        return None
    while hi / lo > 1 + tol:
        mid = (lo * hi) ** 0.5
        if tps_at(mid) >= target_tps:
            hi = mid
        else:
            lo = mid
    return hi


def max_tolerable_latency(cfg: ArchConfig, place: Placement, *,
                          target_tps: float, bw_gbps: float = 512.0,
                          ddr_bw: float = 520.0, prefill: int = 512,
                          decode: int = 512, lo_us: float = 0.1,
                          hi_us: float = 1000.0) -> Optional[float]:
    """Largest HBS latency (us) still meeting the target (Fig. 1 y-axis
    question: which latency curves cross 10 TPS?)."""
    def tps_at(lat: float) -> float:
        hier = npu_hierarchy(lpddr6(ddr_bw), hbs(bw_gbps, latency_us=lat))
        return run_inference(cfg, hier, place, prefill, decode,
                             n_samples=5).tps
    if tps_at(lo_us) < target_tps:
        return None
    lo, hi = lo_us, hi_us
    while hi / lo > 1.05:
        mid = (lo * hi) ** 0.5
        if tps_at(mid) >= target_tps:
            lo = mid
        else:
            hi = mid
    return lo
