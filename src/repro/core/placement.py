"""Tensor-class -> memory-tier placement (the paper's central knob).

A ``Placement`` maps each tensor class to the memory level it RESIDES at.
``capacity_aware`` splits a class across its preferred tier and a fallback
when the preferred tier cannot hold the class footprint (e.g. a 128 MB SRAM
chiplet asked to hold 1.6 GB of MLP weights) — the paper's takeaway-IV
proposal evaluated under a real capacity constraint (beyond-paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.core.memspec import MemoryHierarchy
from repro.core.workload import TC


@dataclass(frozen=True)
class Placement:
    """class -> level name; ``splits``: class -> [(level, fraction), ...]."""
    name: str
    mapping: Dict[str, str]
    splits: Dict[str, Tuple[Tuple[str, float], ...]] = field(default_factory=dict)

    def locations(self, tclass: str) -> Tuple[Tuple[str, float], ...]:
        if tclass in self.splits:
            return self.splits[tclass]
        return ((self.mapping[tclass], 1.0),)


def uniform(name: str, level: str) -> Placement:
    return Placement(name, {c: level for c in TC.ALL})


def make_placement(name: str, default: str, **over: str) -> Placement:
    m = {c: default for c in TC.ALL}
    for k, v in over.items():
        m[getattr(TC, k.upper())] = v
    return Placement(name, m)


# --------------------- the paper's configurations --------------------- #

def all_hbs() -> Placement:
    """Experiments I & II: Q, K, V, weights and activations reside on HBS."""
    return make_placement("all-hbs", "hbs")


def qkv_in_ddr() -> Placement:
    """Experiment III: Q/K/V + intermediate activations restricted to DDR."""
    return make_placement("qkv-in-ddr", "hbs",
                          qkv="ddr", kv="ddr", act="ddr", state="ddr")


def ddr_only() -> Placement:
    """No-HBS baseline (model must fit DDR): everything in DDR."""
    return make_placement("ddr-only", "ddr")


def chiplet_qkv() -> Placement:
    """Fig. 4: Q + KV cache + attention intermediates on the bonded chiplet."""
    return make_placement("chiplet-qkv", "ddr",
                          qkv="chiplet", kv="chiplet", state="chiplet")


def chiplet_mlp_weights() -> Placement:
    """Takeaway IV proposal: chiplet holds MLP + projection weights."""
    return make_placement("chiplet-w-mlp", "ddr",
                          w_mlp="chiplet", w_attn="chiplet")


def capacity_aware(p: Placement, hier: MemoryHierarchy,
                   footprints: Dict[str, float]) -> Placement:
    """Split classes whose footprint exceeds the preferred tier's capacity.

    Greedy in descending footprint: what fits stays; the remainder of the
    class spills to the innermost chain level that can absorb it (DDR, else
    the outermost level)."""
    used: Dict[str, float] = {}
    splits: Dict[str, Tuple[Tuple[str, float], ...]] = {}
    fallback_order = [lv.name for lv in hier.chain[2:]] or [hier.outermost().name]
    for tclass in sorted(footprints, key=lambda c: -footprints[c]):
        level = p.mapping[tclass]
        need = footprints.get(tclass, 0.0)
        cap = hier.level(level).capacity
        if cap is None or need <= 0:
            continue
        avail = max(cap - used.get(level, 0.0), 0.0)
        if need <= avail:
            used[level] = used.get(level, 0.0) + need
            continue
        fit = avail / need
        used[level] = used.get(level, 0.0) + avail
        spill = next((n for n in fallback_order if n != level),
                     hier.outermost().name)
        splits[tclass] = ((level, fit), (spill, 1.0 - fit))
    if not splits:
        return p
    return Placement(p.name + "+cap", dict(p.mapping), {**p.splits, **splits})
