"""TPU-pod retargeting of the paper's roofline methodology (deliverable g).

Converts trip-count-aware HLO costs into the three roofline terms and the
derived metrics recorded per dry-run cell; also provides the analytical
"compulsory traffic" bound used in EXPERIMENTS.md §Roofline to size the
headroom between the XLA graph and a Pallas-kernel implementation.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.core.hlo_analysis import HloCosts
from repro.core.memspec import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_BF16


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        return self.compute_s / max(self.step_lower_bound_s, 1e-30)

    @property
    def model_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops_global, 1.0)


def terms_from_costs(costs: HloCosts, *, n_dev: int, model_flops: float,
                     peak: float = V5E_PEAK_BF16, hbm_bw: float = V5E_HBM_BW,
                     ici_bw: float = V5E_ICI_BW) -> RooflineTerms:
    return RooflineTerms(
        compute_s=costs.flops / peak,
        memory_s=costs.bytes / hbm_bw,
        collective_s=costs.collective_bytes / ici_bw,
        model_flops=model_flops,
        hlo_flops_global=costs.flops * n_dev,
    )


def model_flops_for(cfg: ArchConfig, kind: str, seq_len: int,
                    global_batch: int) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)."""
    n_act = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n_act * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n_act * seq_len * global_batch
    return 2.0 * n_act * global_batch


def decode_compulsory_bytes(cfg: ArchConfig, ctx: int, batch: int,
                            n_dev: int, dtype_bytes: int = 2) -> float:
    """Per-device compulsory HBM traffic for one decode step: every active
    weight byte once + the KV cache once (the paper's ops:bytes ~ O(1)
    memory-wall floor). A Pallas decode kernel reaches this bound by
    construction; the gap to the measured memory term is optimization
    headroom."""
    weights = cfg.n_active_params() * dtype_bytes
    kv = cfg.kv_bytes_per_token(dtype_bytes) * ctx * batch
    return (weights + kv) / n_dev


def decode_floor_seconds(cfg: ArchConfig, ctx: int, batch: int,
                         n_dev: int = 256, hbm_bw: float = V5E_HBM_BW) -> float:
    return decode_compulsory_bytes(cfg, ctx, batch, n_dev) / hbm_bw
