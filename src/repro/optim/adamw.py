"""AdamW + cosine schedule + global-norm clipping, pure JAX.

State: fp32 first/second moments + fp32 master params (bf16 model params are
round-trips of the master). ZeRO-1 sharding of the state is applied by the
launcher via ``repro.sharding.opt_state_pspec``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params) -> Dict:
    # true copy: donated params must not alias the master buffers
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
    }


def adamw_update(cfg: AdamWConfig, params, grads, state) -> Tuple:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    new_m, new_v, new_ma = [], [], []
    for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma):
        a, b, c = upd(g, m, v, ma)
        new_m.append(a)
        new_v.append(b)
        new_ma.append(c)
    flat_p = treedef.flatten_up_to(params)
    new_p = [ma.astype(p.dtype) for ma, p in zip(new_ma, flat_p)]
    mk = treedef.unflatten
    new_state = {"step": step, "m": mk(new_m), "v": mk(new_v),
                 "master": mk(new_ma)}
    return mk(new_p), new_state, {"lr": lr, "grad_norm": gnorm}
