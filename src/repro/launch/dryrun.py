import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input-shape x mesh) cell:
    jit(step).lower(**ShapeDtypeStructs).compile()
then record memory_analysis(), cost_analysis(), and the trip-count-aware
HLO costs (FLOPs / bytes / collective bytes) into artifacts/dryrun/*.json.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    ... [--multi-pod] [--variant tp|fsdp] [--force]
"""
import argparse
import gc
import json
import pathlib
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import (RuntimeOptions, SHAPES, cell_runnable, decode_step,
                          init_cache, init_params, input_specs, prefill,
                          train_loss)
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import (cache_pspecs, data_pspecs, opt_state_pspec,
                            param_pspecs)


def cm_constrain(x, mesh, ba):
    """Keep microbatch slices batch-sharded after the reshape."""
    spec = P(ba, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _sharded_specs(tree, pspecs, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        tree, pspecs)


def _ns(mesh, pspecs):
    """PartitionSpec tree -> NamedSharding tree (jit out_shardings)."""
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape: str, mesh, *, variant: str = "fsdp",
               opts: RuntimeOptions = None):
    """Returns (fn, example_args_with_shardings, out_shardings)."""
    import dataclasses

    from repro.sharding.rules import effective_batch_axes
    cfg = get_config(arch)
    sp = SHAPES[shape]
    opts = opts or RuntimeOptions()
    ba_eff = effective_batch_axes(mesh, sp.global_batch)
    ms = mesh.shape.get("model", 1)
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), opts))
    p_specs = param_pspecs(cfg, params_shapes, mesh, mode=variant)
    moe_kw = {}
    # shard-local EP dispatch (moe_impl="shard_map"): tokens stay on their
    # data shard, experts on model shards, combine = one small psum.
    # (GSPMD-constraint and gather-combine variants both measured WORSE —
    # see EXPERIMENTS.md SSPerf iterations 1-2 for arctic prefill_32k.)
    if (cfg.moe is not None and cfg.moe.n_experts % ms == 0
            and not os.environ.get("REPRO_NO_MOE_SHARD")):
        moe_kw = {"moe_impl": "shard_map", "moe_shard_map_mesh": mesh}
    z3_kw = {}
    if sp.kind in ("train", "prefill") and variant == "fsdp" and \
            not os.environ.get("REPRO_NO_ZERO3_GATHER"):
        def _nodata(spec):
            def clean(ax):
                if ax is None:
                    return None
                axes = ax if isinstance(ax, tuple) else (ax,)
                kept = tuple(a for a in axes if a not in ("data", "pod"))
                return (kept[0] if len(kept) == 1 else (kept or None))
            return P(*(clean(a) for a in spec))
        entries = []
        flat = jax.tree_util.tree_flatten_with_path(p_specs)[0]
        for path, spec in flat:
            ps = "/".join(str(q.key) if hasattr(q, "key") else str(q.idx)
                          for q in path)
            if "stack" not in ps or len(spec) < 2:
                continue
            body = P(*tuple(spec)[1:])          # drop the scan dim
            nd = _nodata(body)
            if nd != body:                       # only data-sharded weights
                entries.append((ps.split("stack/", 1)[-1],
                                NamedSharding(mesh, nd)))
        if entries:
            z3_kw = {"zero3_gather": tuple(entries)}
    seq_kw = {}
    if (sp.kind == "decode" and cfg.mla is None
            and cfg.family in ("dense", "moe", "vlm")
            and cfg.n_kv_heads % ms != 0
            and not os.environ.get("REPRO_NO_SEQ_SHARD")):
        seq_kw = {"seq_shard_attn": True, "seq_shard_mesh": mesh}
    # sequence parallelism (SSPerf iteration 5): shard the residual stream's
    # sequence dim over "model" for big-token kinds — row-parallel
    # all-reduces become reduce-scatter+all-gather at half the traffic,
    # and norms/elementwise run 1/model_size of the tokens
    # OPT-IN: refuted as a default — with GQA kv-heads < model-axis size
    # the attention replicates over "model" and the memory term explodes
    # (EXPERIMENTS.md SSPerf arctic iteration 5)
    seq_dim_shard = (sp.kind in ("train", "prefill")
                     and sp.seq_len % ms == 0
                     and bool(os.environ.get("REPRO_SEQPAR")))
    res_spec = (P(ba_eff, "model", None) if seq_dim_shard
                else P(ba_eff, None, None))
    opts = dataclasses.replace(
        opts, residual_sharding=NamedSharding(mesh, res_spec),
        **moe_kw, **seq_kw, **z3_kw)
    params_in = _sharded_specs(params_shapes, p_specs, mesh)
    d_specs = data_pspecs(cfg, mesh, sp.kind, sp.global_batch)
    inputs = input_specs(cfg, shape, opts)
    data_in = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, d_specs[k]))
        for k, v in inputs.items() if k in d_specs}

    if sp.kind == "train":
        ocfg = AdamWConfig()
        opt_shapes = jax.eval_shape(partial(adamw_init), params_shapes)
        opt_specs = {
            "step": P(),
            "m": jax.tree.map(lambda ps, s: opt_state_pspec(ps, s.shape, mesh),
                              p_specs, opt_shapes["m"]),
            "v": jax.tree.map(lambda ps, s: opt_state_pspec(ps, s.shape, mesh),
                              p_specs, opt_shapes["v"]),
            "master": jax.tree.map(
                lambda ps, s: opt_state_pspec(ps, s.shape, mesh),
                p_specs, opt_shapes["master"]),
        }
        opt_in = _sharded_specs(opt_shapes, opt_specs, mesh)

        # gradient accumulation: bounds activation memory (per-micro local
        # batch ~4 sequences) — and is how 1M-token global steps run at
        # 1000+-node scale anyway.
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                dp *= mesh.shape[a]
        local_b = max(sp.global_batch // dp, 1)
        n_micro = int(os.environ.get("REPRO_MICROBATCH", "0")) or max(
            1, local_b // 4)

        def train_step(params, opt_state, batch):
            def loss_fn(p, mb):
                return train_loss(cfg, p, mb, opts)

            def micro(carry, mb):
                g_acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: cm_constrain(x, mesh, ba_eff), mb)
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros((), jnp.float32)),
                                        mbs)
            g = jax.tree.map(lambda x: x / n_micro, g)
            new_p, new_s, om = adamw_update(ocfg, params, g, opt_state)
            return loss / n_micro, new_p, new_s

        fn = jax.jit(train_step,
                     out_shardings=(NamedSharding(mesh, P()),
                                    _ns(mesh, p_specs), _ns(mesh, opt_specs)))
        return fn, (params_in, opt_in, data_in)

    # decode cache length: +slack, rounded to a multiple of 256 so the
    # length dim divides any mesh axis (seq-sharded caches)
    max_len = (sp.seq_len if sp.kind != "decode"
               else ((sp.seq_len + 8 + 255) // 256) * 256)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, sp.global_batch, max_len, opts))
    c_specs = cache_pspecs(cfg, cache_shapes, mesh, sp.global_batch)
    cache_in = _sharded_specs(cache_shapes, c_specs, mesh)

    if sp.kind == "prefill":
        def prefill_step(params, tokens_batch, cache):
            return prefill(cfg, params, tokens_batch["tokens"], cache, opts,
                           prefix_emb=tokens_batch.get("prefix_emb"))
        ba = d_specs["tokens"][0]
        fn = jax.jit(prefill_step,
                     out_shardings=(NamedSharding(mesh, P(ba, None)),
                                    _ns(mesh, c_specs)))
        return fn, (params_in, data_in, cache_in)

    def serve_step(params, token, pos, cache):
        return decode_step(cfg, params, token, pos, cache, opts)
    ba = d_specs["token"][0]
    fn = jax.jit(serve_step, donate_argnums=(3,),
                 out_shardings=(NamedSharding(mesh, P(ba, None)),
                                _ns(mesh, c_specs)))
    return fn, (params_in, data_in["token"],
                jax.ShapeDtypeStruct((), jnp.int32), cache_in)


def roofline_terms(costs: hlo_analysis.HloCosts, cfg, shape: str,
                   n_dev: int = 256) -> dict:
    """Three-term roofline (terms per device; ratio vs GLOBAL HLO flops)."""
    from repro.core import tpu_roofline as tr
    sp = SHAPES[shape]
    t = tr.terms_from_costs(
        costs, n_dev=n_dev,
        model_flops=tr.model_flops_for(cfg, sp.kind, sp.seq_len,
                                       sp.global_batch))
    out = {
        "compute_s": t.compute_s, "memory_s": t.memory_s,
        "collective_s": t.collective_s,
        "bottleneck": t.bottleneck,
        "model_flops": t.model_flops,
        "hlo_flops_global": t.hlo_flops_global,
        "model_flops_ratio": t.model_flops_ratio,
        "step_time_lower_bound_s": t.step_lower_bound_s,
        "roofline_fraction": t.roofline_fraction,
    }
    if sp.kind == "decode":
        out["memory_floor_s"] = tr.decode_floor_seconds(
            cfg, sp.seq_len, sp.global_batch, n_dev)
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, variant: str,
             force: bool = False, opts: RuntimeOptions = None,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    stem = f"{arch}.{shape}.{mesh_name}.{variant}{('.' + tag) if tag else ''}"
    out_path = ART / f"{stem}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    skip = cell_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "variant": variant, "tag": tag}
    if skip:
        rec["skipped"] = skip
        ART.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = 512 if multi_pod else 256
        with mesh:
            fn, args = build_cell(arch, shape, mesh, variant=variant,
                                  opts=opts)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            text = compiled.as_text()
            costs = hlo_analysis.analyze(text)
        rec.update({
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "devices": n_dev,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0)),
            },
            "xla_cost_analysis": {k: ca.get(k) for k in
                                  ("flops", "bytes accessed")},
            "hlo_costs": {
                "flops": costs.flops,
                "bytes": costs.bytes,
                "collective_bytes": costs.collective_bytes,
                "collective_breakdown": costs.collective_counts,
            },
            "roofline": roofline_terms(costs, cfg, shape, n_dev),
        })
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    ART.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    # free compiler memory between heavy cells
    gc.collect()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn-impl", default="xla")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--block-q", type=int, default=512)
    ap.add_argument("--block-kv", type=int, default=1024)
    ap.add_argument("--flash-acc", default="float32")
    ap.add_argument("--cache-dtype", default="")
    args = ap.parse_args()

    opts = RuntimeOptions(attn_impl=args.attn_impl, remat=args.remat,
                          block_q=args.block_q, block_kv=args.block_kv,
                          flash_acc=args.flash_acc,
                          cache_dtype=args.cache_dtype)
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ([False, True] if args.both_meshes
              else [args.multi_pod])

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, multi_pod=mp,
                               variant=args.variant, force=args.force,
                               opts=opts, tag=args.tag)
                status = ("SKIP " + rec["skipped"][:40] if "skipped" in rec
                          else ("ERR " + rec["error"][:80] if "error" in rec
                                else f"ok {rec['compile_s']:.0f}s "
                                f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB"
                                f" bott={rec['roofline']['bottleneck']}"))
                print(f"[{arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'} x {args.variant}] "
                      f"{status} ({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
