"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 200 --seq-len 128 --batch 8 --ckpt-dir /tmp/ck

Full-size configs target the production mesh (see dryrun.py); ``--reduced``
shrinks to a same-family config that trains on this host.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model, n_layers=args.layers)
    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        n_micro=args.n_micro, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps))
    out = train(cfg, tcfg, RuntimeOptions(dtype=args.dtype))
    print(f"[train] done: steps={out['last_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
