"""Serving CLI: batched greedy decode with the tiered-KV policy.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32 --kv-policy int8

Continuous batching over the paged KV pool (ragged prompts, per-step
join/retire, page-pool preemption):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --scheduler continuous --concurrency 8 --page-size 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--kv-policy", default="native",
                    choices=["native", "int8"])
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--scheduler", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--concurrency", type=int, default=0,
                    help="number of in-flight ragged requests "
                         "(0: one equal-length wave of --batch prompts)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="continuous scheduler slot count")
    ap.add_argument("--shards", type=int, default=1,
                    help="head-shard the paged KV pool and attention "
                         "kernels over this many devices (DESIGN.md SS16; "
                         "requires --scheduler continuous and "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N on the CPU rig)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serialize the prefill and decode streams onto "
                         "one virtual queue (the pre-SS16 loop) instead "
                         "of overlapping them")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="tokens per prefill chunk (continuous scheduler; "
                         "default 2 pages, min 32)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prefill tokens per engine step")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix KV page reuse")
    ap.add_argument("--decode-lookahead", type=int, default=8,
                    help="fused decode block size K: sample greedily on "
                         "device and sync with the host once per K tokens "
                         "instead of once per token; KV pages for the K "
                         "writes are reserved ahead (all-or-nothing). K=1 "
                         "reproduces the per-token loop exactly; any K is "
                         "token-identical (default: 8)")
    ap.add_argument("--spec-mode", default="off",
                    choices=["off", "ngram", "model"],
                    help="speculative decoding on the fused paged path "
                         "(DESIGN.md SS14): 'ngram' drafts by prompt "
                         "lookup (model-free), 'model' drafts with a small "
                         "paged-KV model (--draft-config); requires "
                         "--scheduler continuous")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens verified per pass (the verify "
                         "window is K+1 wide; acceptance-adaptive per "
                         "request)")
    ap.add_argument("--draft-config",
                    help="arch name for the --spec-mode model draft "
                         "(reduced with --d-model/2 when --reduced)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0: greedy). Stochastic "
                         "sampling runs on device from per-request seeded "
                         "keys; with spec decoding, leftover/rejection "
                         "sampling keeps the output distribution exact")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k logit filter (0: off; needs --temperature)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus filter (1.0: off; needs --temperature)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for per-request sampling keys")
    ap.add_argument("--shared-doc", type=int, default=0,
                    help="prepend a shared document of this many tokens to "
                         "every request (exercises prefix dedup)")
    ap.add_argument("--kv-fast-mb", type=float, default=None,
                    help="cap the fast KV tier (DDR) at this many MB and "
                         "offload the overflow to simulated HBS "
                         "(DESIGN.md SS13); enables real page residency, "
                         "spill/prefetch, and stall accounting")
    ap.add_argument("--hbs-gb", type=float, default=64.0,
                    help="HBS offload tier capacity in GB")
    ap.add_argument("--hbs-gbps", type=float, default=None,
                    help="override HBS bandwidth (GB/s) for migration "
                         "timing (default: the hierarchy preset's)")
    ap.add_argument("--hbs-us", type=float, default=None,
                    help="override HBS issue latency (µs) for migration "
                         "timing")
    ap.add_argument("--chiplet-mb", type=float, default=None,
                    help="bond a promote-only SRAM chiplet buffer of this "
                         "many MB in front of the fast KV tier (DESIGN.md "
                         "SS17); hot pages promote in by EMA touch "
                         "frequency, cold residents demote out LRU "
                         "(needs --kv-fast-mb)")
    ap.add_argument("--chiplet-gbps", type=float, default=None,
                    help="override the chiplet link bandwidth (GB/s) for "
                         "promotion/demotion timing (default: the "
                         "sram_chiplet preset's)")
    ap.add_argument("--chiplet-us", type=float, default=None,
                    help="override the chiplet link issue latency (µs)")
    ap.add_argument("--layer-overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="slice each demand fetch per layer and pipeline "
                         "the slices against the kernel's layer loop "
                         "(DESIGN.md SS17); --no-layer-overlap restores "
                         "the whole-block fetch barrier baseline")
    ap.add_argument("--writeback-link", default="dedicated",
                    choices=["shared", "dedicated"],
                    help="'dedicated': dirty-page write-back rides its own "
                         "out channel; 'shared': spills and fetches "
                         "contend for one half-duplex offload link")
    ap.add_argument("--trace-out", default=None,
                    help="write the run's Chrome trace-event JSON here "
                         "(perfetto-loadable: one track per request plus "
                         "engine/DMA tracks on the virtual clock; "
                         "continuous scheduler only — DESIGN.md SS15)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT target: print the goodput report (requests "
                         "meeting SLO + per-phase blame for violators)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="per-request p95 inter-token-latency target for "
                         "the goodput report")
    args = ap.parse_args()
    wants_trace = (args.trace_out or args.slo_ttft_ms is not None
                   or args.slo_itl_ms is not None)
    if wants_trace and args.scheduler != "continuous":
        ap.error("--trace-out/--slo-* need --scheduler continuous (the "
                 "trace recorder instruments the continuous engine)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model)
    draft_cfg = None
    if args.draft_config:
        draft_cfg = get_config(args.draft_config)
        if args.reduced:
            draft_cfg = reduced(draft_cfg, d_model=max(args.d_model // 2, 16))
    max_len = args.prompt_len + args.new_tokens + args.shared_doc
    hier = None
    if args.chiplet_mb is not None and args.kv_fast_mb is None:
        ap.error("--chiplet-mb needs --kv-fast-mb (the chiplet promotes "
                 "out of the tiered KV pool)")
    if args.kv_fast_mb is not None:
        from repro.core import hbs, lpddr6, npu_hierarchy, sram_chiplet
        chiplet = None
        if args.chiplet_mb is not None:
            chiplet = sram_chiplet(args.chiplet_gbps or 512.0,
                                   capacity_mb=args.chiplet_mb)
        hier = npu_hierarchy(
            lpddr6(capacity_gb=args.kv_fast_mb / 1e3),
            hbs(args.hbs_gbps or 8.0, latency_us=args.hbs_us or 20.0,
                capacity_gb=args.hbs_gb),
            chiplet=chiplet)
    eng = ServeEngine(cfg, opts=RuntimeOptions(dtype=args.dtype),
                      kv_policy=args.kv_policy, max_len=max_len,
                      scheduler=args.scheduler, page_size=args.page_size,
                      max_batch=args.max_batch,
                      prefill_chunk=args.prefill_chunk,
                      prefill_budget=args.prefill_budget,
                      prefix_cache=not args.no_prefix_cache,
                      decode_lookahead=args.decode_lookahead,
                      hierarchy=hier, hbs_gbps=args.hbs_gbps,
                      hbs_latency_us=args.hbs_us,
                      spec_mode=args.spec_mode, spec_k=args.spec_k,
                      draft_cfg=draft_cfg, temperature=args.temperature,
                      top_k=args.top_k, top_p=args.top_p,
                      sample_seed=args.seed,
                      shards=args.shards, overlap=not args.no_overlap,
                      chiplet_gbps=args.chiplet_gbps,
                      chiplet_latency_us=args.chiplet_us,
                      layer_overlap=args.layer_overlap,
                      writeback_link=args.writeback_link)

    rng = np.random.default_rng(0)
    if args.concurrency:
        # ragged request stream: lengths in [prompt_len // 2, prompt_len]
        doc = rng.integers(1, cfg.vocab, size=args.shared_doc).tolist()
        lens = rng.integers(max(args.prompt_len // 2, 1),
                            args.prompt_len + 1, size=args.concurrency)
        reqs = [doc + rng.integers(1, cfg.vocab, size=n).tolist()
                for n in lens]
        outs = eng.serve(reqs, args.new_tokens)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(0),
                                     (args.batch, args.prompt_len), 1,
                                     cfg.vocab)
        if args.scheduler == "continuous":
            # route through the configured scheduler, not the static wave
            outs = eng.serve([row.tolist() for row in np.asarray(prompts)],
                             args.new_tokens)
        else:
            outs = eng.generate(jnp.asarray(prompts), args.new_tokens)
    s = eng.stats
    print(f"[serve] arch={cfg.name} sched={args.scheduler} "
          f"kv={args.kv_policy} reqs={s.requests} "
          f"prefill={s.prefill_s*1e3:.0f}ms decode={s.decode_s*1e3:.0f}ms "
          f"serve={s.serve_s*1e3:.0f}ms "
          f"steps={s.decode_steps} lookahead={args.decode_lookahead} "
          f"syncs={s.host_syncs} preempt={s.preemptions} TPS={s.tps:.1f} "
          f"shards={args.shards} overlap={not args.no_overlap}")
    if args.scheduler == "continuous":
        print(f"[serve] prefill_toks={s.prefill_tokens_computed} "
              f"cached={s.cached_prefix_tokens} deduped={s.pages_deduped} "
              f"cow={s.cow_copies} compiles={s.prefill_compiles} "
              f"ttft_p50/p95={s.ttft_p50*1e3:.1f}/{s.ttft_p95*1e3:.1f}ms "
              f"itl_p50/p95={s.itl_p50*1e3:.1f}/{s.itl_p95*1e3:.1f}ms")
        if hier is not None:
            # peak KV footprint priced at the ACTIVE cache width (an int8
            # pool is 1 B/elem, not bf16's 2 — DESIGN.md SS13)
            peak_mb = s.peak_pages_used * eng.page_nbytes / 1e6
            fast_mb = s.peak_fast_pages * eng.page_nbytes / 1e6
            print(f"[serve] offload: stall={s.stall_s*1e3:.1f}ms "
                  f"spilled={s.pages_spilled}p/{s.spill_bytes/1e6:.2f}MB "
                  f"fetched={s.pages_fetched}p/{s.fetch_bytes/1e6:.2f}MB "
                  f"prefetch_hit={s.prefetch_hit_rate:.0%} "
                  f"kv_width={eng.kv_dtype_bytes}B "
                  f"peak_kv={peak_mb:.2f}MB (fast {fast_mb:.2f}MB)")
            print(f"[serve] overlap: layer_overlap={args.layer_overlap} "
                  f"stall_saved={s.stall_saved_s*1e3:.1f}ms "
                  f"writeback={args.writeback_link} "
                  f"clean_demotions={s.clean_demotions}")
            if args.chiplet_mb is not None:
                chan = " ".join(f"{k}={v/1e6:.2f}MB" for k, v
                                in sorted(s.channel_bytes.items()))
                print(f"[serve] chiplet: {args.chiplet_mb:g}MB "
                      f"hit_rate={s.chiplet_hit_rate:.0%} "
                      f"promoted={s.chiplet_promotions}p "
                      f"demoted={s.chiplet_demotions}p "
                      f"channels[{chan}]")
            if s.stall_by_rid:
                worst = sorted(s.stall_by_rid.items(),
                               key=lambda kv_: -kv_[1])[:4]
                per = " ".join(f"r{r}={v*1e3:.1f}ms" for r, v in worst)
                print(f"[serve] stall by request (top): {per}")
        if args.spec_mode != "off":
            print(f"[serve] spec: mode={args.spec_mode} k={args.spec_k} "
                  f"blocks={s.spec_blocks} proposed={s.draft_proposed} "
                  f"accepted={s.draft_accepted} "
                  f"accept_rate={s.acceptance_rate:.0%}")
        # ---- structured trace exports (DESIGN.md SS15) ---- #
        if eng.trace is not None:
            agg = eng.trace.aggregate_breakdown_ms()
            print("[serve] time breakdown: " + " ".join(
                f"{p}={agg[f'{p}_ms']:.1f}ms"
                for p in ("queue", "prefill", "recompute", "decode",
                          "stall", "draft")))
            if args.slo_ttft_ms is not None or args.slo_itl_ms is not None:
                rep = eng.trace.slo_report(
                    None if args.slo_ttft_ms is None
                    else args.slo_ttft_ms * 1e-3,
                    None if args.slo_itl_ms is None
                    else args.slo_itl_ms * 1e-3)
                print(f"[serve] goodput: {rep['n_met_slo']}/"
                      f"{rep['n_requests']} met SLO "
                      f"(frac={rep['goodput_frac']:.2f})")
                for v in rep["violators"][:6]:
                    print(f"[serve]   violator r{v['rid']}: "
                          f"ttft={v['ttft_ms']:.1f}ms "
                          f"itl_p95={v['itl_p95_ms']:.1f}ms "
                          f"blame={v['blame']}")
            if args.trace_out:
                eng.trace.save(args.trace_out)
                print(f"[serve] wrote trace {args.trace_out} "
                      f"(reconciled={eng.trace_report['ok']})")
    print("[serve] first output:", outs[0][:16])


if __name__ == "__main__":
    main()
