"""Serving CLI: batched greedy decode with the tiered-KV policy.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 32 --kv-policy int8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--kv-policy", default="native",
                    choices=["native", "int8"])
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model)
    eng = ServeEngine(cfg, opts=RuntimeOptions(dtype=args.dtype),
                      kv_policy=args.kv_policy,
                      max_len=args.prompt_len + args.new_tokens)
    prompts = jax.random.randint(jax.random.PRNGKey(0),
                                 (args.batch, args.prompt_len), 1, cfg.vocab)
    outs = eng.generate(jnp.asarray(prompts), args.new_tokens)
    s = eng.stats
    print(f"[serve] arch={cfg.name} kv={args.kv_policy} batch={args.batch} "
          f"prefill={s.prefill_s*1e3:.0f}ms decode={s.decode_s*1e3:.0f}ms "
          f"TPS={s.tps:.1f}")
    print("[serve] first output:", outs[0][:16])


if __name__ == "__main__":
    main()
