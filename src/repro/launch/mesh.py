"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process CPU mesh for tests/examples (1 device)."""
    n = len(jax.devices())
    if n >= 8:
        return jax.make_mesh((n // 4, 4), ("data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))
