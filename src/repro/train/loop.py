"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/examples):
* checkpoint/restart: atomic sharded checkpoints every ``ckpt_every`` steps,
  resume from the newest on start — a SIGTERM'd/killed job loses at most
  ``ckpt_every`` steps;
* preemption handling: SIGTERM/SIGINT set a flag; the loop checkpoints and
  exits cleanly at the next step boundary;
* data determinism: batches are a pure function of (seed, step) — resume
  continues the exact token stream (no loader state in the checkpoint);
* gradient accumulation: ``n_micro`` microbatches bound activation memory;
* straggler visibility: per-step wall time + EMA watermark; steps slower
  than ``straggler_factor x`` the watermark are logged (on real multi-host
  deployments this feeds the controller's slow-host eviction);
* elastic restart: checkpoints are mesh-agnostic (see checkpoint/store.py),
  so a restore onto a different device count just applies new shardings.
"""
from __future__ import annotations

import json
import pathlib
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ArchConfig
from repro.data.pipeline import for_arch
from repro.models import RuntimeOptions, init_params, train_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    n_micro: int = 1
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    log_every: int = 10
    straggler_factor: float = 2.0
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y.astype(x.dtype), a, b)


def build_train_step(cfg: ArchConfig, opts: RuntimeOptions, tcfg: TrainConfig):
    ocfg = tcfg.optimizer

    def train_step(params, opt_state, batch):
        def loss_fn(p, mb):
            return train_loss(cfg, p, mb, opts)

        if tcfg.n_micro > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (_tree_add(g_acc, g), l_acc + loss), None
            mbs = jax.tree.map(
                lambda x: x.reshape(tcfg.n_micro, x.shape[0] // tcfg.n_micro,
                                    *x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda x: x / tcfg.n_micro, grads)
            loss = loss / tcfg.n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        new_p, new_s, om = adamw_update(ocfg, params, grads, opt_state)
        return loss, new_p, new_s, om

    return jax.jit(train_step, donate_argnums=(0, 1))


def train(cfg: ArchConfig, tcfg: TrainConfig,
          opts: RuntimeOptions = RuntimeOptions(dtype="float32"),
          log_fn: Optional[Callable[[str], None]] = print) -> Dict:
    """Run (or resume) a training job; returns final metrics."""
    ds = for_arch(cfg, tcfg.seq_len, tcfg.global_batch, tcfg.seed)
    step_fn = build_train_step(cfg, opts, tcfg)
    ckpt_dir = pathlib.Path(tcfg.ckpt_dir)

    params = init_params(cfg, jax.random.PRNGKey(tcfg.seed), opts)
    opt_state = adamw_init(params)
    start = 0
    if latest_step(ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            ckpt_dir, (params, opt_state))
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        if log_fn:
            log_fn(f"[train] resumed from step {start}")

    preempted = {"flag": False}
    prev_handlers = {}

    def on_signal(signum, frame):
        preempted["flag"] = True
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            prev_handlers[sig] = signal.signal(sig, on_signal)
        except ValueError:
            pass  # non-main thread (tests)

    metrics_path = ckpt_dir / "metrics.jsonl"
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    ema_step_time = None
    losses = []
    step = start
    try:
        for step in range(start, tcfg.steps):
            t0 = time.perf_counter()
            batch = ds.batch_at(step)
            loss, params, opt_state, om = step_fn(params, opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            ema_step_time = dt if ema_step_time is None else (
                0.9 * ema_step_time + 0.1 * dt)
            straggler = dt > tcfg.straggler_factor * ema_step_time
            losses.append(loss)
            if log_fn and (step % tcfg.log_every == 0 or straggler):
                log_fn(f"[train] step={step} loss={loss:.4f} "
                       f"dt={dt*1e3:.0f}ms lr={float(om['lr']):.2e}"
                       f"{' STRAGGLER' if straggler else ''}")
            with metrics_path.open("a") as f:
                f.write(json.dumps({"step": step, "loss": loss,
                                    "dt_ms": dt * 1e3,
                                    "straggler": straggler}) + "\n")
            done = step + 1
            if done % tcfg.ckpt_every == 0 or done == tcfg.steps:
                save_checkpoint(ckpt_dir, done, (params, opt_state),
                                keep=tcfg.keep)
            if preempted["flag"]:
                save_checkpoint(ckpt_dir, done, (params, opt_state),
                                keep=tcfg.keep)
                if log_fn:
                    log_fn(f"[train] preempted at step {done}; "
                           "checkpoint written, exiting cleanly")
                break
    finally:
        for sig, h in prev_handlers.items():
            signal.signal(sig, h)
    return {"last_step": step + 1, "losses": losses,
            "final_loss": losses[-1] if losses else float("nan"),
            "preempted": preempted["flag"]}
