"""repro-lint: AST/CFG static analysis for the serving stack's invariants.

The serving simulator's credibility rests on hand-maintained disciplines
— reservation pairing, virtual-clock purity, per-channel byte accounting
— that ``trace.reconcile()`` can only audit on paths a test happens to
execute. This package proves them on EVERY path, before any test runs
(DESIGN.md SS18):

* :mod:`repro.analysis.cfg` — intra-procedural control-flow graphs over
  Python AST, with exception edges, loop back edges, and path walks.
* :mod:`repro.analysis.core` — project loading, call-name resolution,
  ``# repro: allow(<rule>): why`` suppression pragmas, finding
  fingerprints and the committed-baseline workflow.
* :mod:`repro.analysis.checkers` — the five repo-specific checkers:
  resource pairing, host-sync/wall-clock discipline, traced-code purity,
  accounting completeness, and config/CLI drift.

Entry point: ``scripts/analyze.py`` (human + ``--json`` output, nonzero
exit on findings not covered by the baseline).
"""
from repro.analysis.core import (Finding, Project, load_project,
                                 run_checkers)

__all__ = ["Finding", "Project", "load_project", "run_checkers"]
