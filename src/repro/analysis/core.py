"""repro-lint framework: project model, pragmas, findings, baseline.

The unit of analysis is a *project* — every ``*.py`` under a source root
(normally ``src/``), indexed per module with its AST, its functions
(qualnames like ``ClassName.method``), and its suppression pragmas.

Suppression pragma (DESIGN.md SS18)::

    # repro: allow(<rule>): <justification>

placed on the offending line or the line directly above it. The
justification text is REQUIRED — a pragma without one is itself a
finding (rule ``pragma``), so every suppression in the tree carries a
written reason. Unknown rule names are also flagged.

Baseline: a committed JSON file mapping finding fingerprints to
justifications, for grandfathered findings that predate a checker.
Fingerprints hash (rule, path, qualname, message) — no line numbers, so
unrelated edits don't churn the baseline.
"""
from __future__ import annotations

import ast
import hashlib
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# every rule id a checker may emit or a pragma may name
KNOWN_RULES = (
    "resource-pairing",
    "host-sync",
    "wall-clock",
    "traced-purity",
    "accounting",
    "channel-vocab",
    "config-drift",
    "pragma",
)

_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\(([a-z-]+)\)\s*(?::\s*(.*?))?\s*$")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # project-relative, e.g. "repro/serving/engine.py"
    line: int
    qualname: str      # enclosing function, or "<module>"
    message: str

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256(
            f"{self.rule}|{self.path}|{self.qualname}|{self.message}"
            .encode()).hexdigest()
        return h[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.qualname}: {self.message}")

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "qualname": self.qualname, "message": self.message,
                "fingerprint": self.fingerprint}


@dataclass
class Pragma:
    line: int
    rule: str
    justification: str


@dataclass
class FunctionInfo:
    qualname: str
    node: ast.FunctionDef
    cls: Optional[ast.ClassDef] = None


@dataclass
class ModuleInfo:
    path: Path
    rel: str
    source: str
    tree: ast.Module
    pragmas: List[Pragma] = field(default_factory=list)
    functions: List[FunctionInfo] = field(default_factory=list)

    def allowed(self, rule: str, line: int) -> bool:
        """True when a pragma on ``line`` or the line above names
        ``rule`` (malformed pragmas never suppress)."""
        return any(p.rule == rule and p.justification
                   and p.line in (line, line - 1)
                   for p in self.pragmas)


@dataclass
class Project:
    root: Path                       # the source root (…/src)
    modules: List[ModuleInfo]
    by_rel: Dict[str, ModuleInfo] = field(default_factory=dict)

    def __post_init__(self):
        self.by_rel = {m.rel: m for m in self.modules}

    def module(self, rel: str) -> Optional[ModuleInfo]:
        return self.by_rel.get(rel)

    def in_dir(self, prefix: str) -> List[ModuleInfo]:
        return [m for m in self.modules if m.rel.startswith(prefix)]

    # ---------------- import resolution ------------------------------- #
    def resolve_import(self, mod: ModuleInfo, name: str, _depth: int = 0
                       ) -> Optional[Tuple[ModuleInfo, ast.AST]]:
        """Resolve ``name`` (used in ``mod``) to its defining module and
        def node, following ``from repro.x import name`` one
        ``__init__`` re-export hop deep."""
        if _depth > 3:
            return None
        # defined locally?
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and node.name == name:
                return mod, node
        for node in mod.tree.body:
            if isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    if (alias.asname or alias.name) != name:
                        continue
                    target = self._module_for(node.module, mod, node.level)
                    if target is None:
                        return None
                    return self.resolve_import(target, alias.name,
                                               _depth + 1)
        return None

    def _module_for(self, dotted: str, frm: ModuleInfo,
                    level: int) -> Optional[ModuleInfo]:
        if level:  # relative import: resolve against the importer's pkg
            base = Path(frm.rel).parent
            for _ in range(level - 1):
                base = base.parent
            parts = list(base.parts) + (dotted.split(".") if dotted else [])
        else:
            parts = dotted.split(".")
        rel = "/".join(parts)
        return self.by_rel.get(rel + ".py") or self.by_rel.get(
            rel + "/__init__.py")


def _parse_pragmas(source: str) -> List[Pragma]:
    out: List[Pragma] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if m:
                out.append(Pragma(tok.start[0], m.group(1),
                                  (m.group(2) or "").strip()))
    except tokenize.TokenError:
        pass
    return out


def _index_functions(tree: ast.Module) -> List[FunctionInfo]:
    out: List[FunctionInfo] = []

    def visit(node: ast.AST, prefix: str, cls: Optional[ast.ClassDef]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                out.append(FunctionInfo(q, child, cls))
                visit(child, q + ".", cls)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", child)

    visit(tree, "", None)
    return out


def load_module(path: Path, rel: str) -> ModuleInfo:
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    return ModuleInfo(path=path, rel=rel, source=source, tree=tree,
                      pragmas=_parse_pragmas(source),
                      functions=_index_functions(tree))


def load_project(src_root: Path,
                 rel_prefix: str = "repro/") -> Project:
    """Load every ``*.py`` under ``src_root`` whose project-relative path
    starts with ``rel_prefix`` (default: the repro package)."""
    src_root = Path(src_root)
    modules = []
    for path in sorted(src_root.rglob("*.py")):
        rel = path.relative_to(src_root).as_posix()
        if not rel.startswith(rel_prefix):
            continue
        modules.append(load_module(path, rel))
    return Project(root=src_root, modules=modules)


# ---------------------------------------------------------------------- #
# AST call helpers shared by the checkers
# ---------------------------------------------------------------------- #

def attr_chain(node: ast.AST) -> List[str]:
    """``self.kv.reserve_ahead`` -> ["self", "kv", "reserve_ahead"];
    returns [] for expressions that aren't plain name/attribute chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def call_name(call: ast.Call) -> str:
    """Terminal name of a call: ``self.kv.reserve_ahead(...)`` ->
    ``reserve_ahead``; ``foo(...)`` -> ``foo``; else ``""``."""
    chain = attr_chain(call.func)
    return chain[-1] if chain else ""


def call_recv(call: ast.Call) -> str:
    """Terminal receiver segment: ``self.kv.reserve_ahead`` -> ``kv``;
    bare ``foo(...)`` -> ``""``."""
    chain = attr_chain(call.func)
    return chain[-2] if len(chain) >= 2 else ""


def calls_in(node: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(node) if isinstance(n, ast.Call)]


def stmt_calls(stmt: ast.AST) -> List[ast.Call]:
    """Calls belonging to ONE CFG node. For compound statements only the
    header expression counts (``while <test>:``, ``for t in <iter>:``,
    ``with <items>:``, ``except <type>:``) — body statements are their
    own CFG nodes and must not be double-attributed to the head. Calls
    nested in an inner function/lambda are excluded everywhere."""
    if isinstance(stmt, (ast.If, ast.While)):
        roots: List[ast.AST] = [stmt.test]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        roots = [stmt.target, stmt.iter]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        roots = [i.context_expr for i in stmt.items]
    elif isinstance(stmt, ast.Try):
        roots = []
    elif isinstance(stmt, ast.ExceptHandler):
        roots = [stmt.type] if stmt.type is not None else []
    elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        roots = []
    else:
        roots = [stmt]
    out: List[ast.Call] = []
    stack: List[ast.AST] = list(roots)
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Call):
            out.append(n)
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)
    return out


# ---------------------------------------------------------------------- #
# Checker registry + baseline
# ---------------------------------------------------------------------- #

Checker = Callable[[Project], List[Finding]]


def pragma_findings(project: Project) -> List[Finding]:
    """Meta-checks on the pragmas themselves: a justification is
    required, and the rule name must exist."""
    out: List[Finding] = []
    for mod in project.modules:
        for p in mod.pragmas:
            if p.rule not in KNOWN_RULES:
                out.append(Finding(
                    "pragma", mod.rel, p.line, "<module>",
                    f"pragma names unknown rule '{p.rule}'"))
            elif not p.justification:
                out.append(Finding(
                    "pragma", mod.rel, p.line, "<module>",
                    f"allow({p.rule}) pragma has no justification text"))
    return out


def run_checkers(project: Project,
                 checkers: Optional[Sequence[Checker]] = None
                 ) -> List[Finding]:
    """Run checkers (default: all five + pragma meta-checks), dropping
    findings suppressed by a well-formed pragma."""
    if checkers is None:
        from repro.analysis.checkers import ALL_CHECKERS
        checkers = ALL_CHECKERS
    findings: List[Finding] = list(pragma_findings(project))
    for check in checkers:
        for f in check(project):
            mod = project.module(f.path)
            if mod is not None and mod.allowed(f.rule, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def load_baseline(path: Path) -> Dict[str, dict]:
    """Load the committed baseline; returns {fingerprint: entry}.
    Raises ValueError when an entry lacks a justification."""
    if not path.exists():
        return {}
    doc = json.loads(path.read_text())
    out: Dict[str, dict] = {}
    for entry in doc.get("findings", []):
        fp = entry.get("fingerprint", "")
        if not entry.get("justification", "").strip():
            raise ValueError(
                f"baseline entry {fp or entry} has no justification")
        out[fp] = entry
    return out


def apply_baseline(findings: Sequence[Finding], baseline: Dict[str, dict]
                   ) -> Tuple[List[Finding], List[dict]]:
    """Split findings into (new, stale-baseline-entries). A baseline
    entry is stale when no current finding matches its fingerprint."""
    live = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    stale = [e for fp, e in baseline.items() if fp not in live]
    return new, stale


def write_baseline(path: Path, findings: Sequence[Finding],
                   justification: str = "grandfathered at baseline"
                   ) -> None:
    doc = {"findings": [dict(f.to_dict(), justification=justification)
                        for f in findings]}
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
