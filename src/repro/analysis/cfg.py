"""Intra-procedural control-flow graphs over Python AST (DESIGN.md SS18).

One statement per node, plus three synthetic nodes: ENTRY, EXIT (normal
return / fall-off-the-end) and RAISE_EXIT (an exception escaping the
function). Edges carry a kind tag:

* ``normal``  — sequential flow
* ``true`` / ``false`` — branch edges out of ``if`` / loop heads
* ``back``    — loop back edge (body tail / ``continue`` -> head)
* ``exc``     — exception flow: every statement inside a ``try`` body
  gets an edge to each of that try's handler heads; an uncaught ``raise``
  edges to RAISE_EXIT

Modelling choices (deliberate over-approximations, kept simple because
the pairing checker only needs reachability, not exactness):

* Only explicit ``raise`` statements and try-body statements produce
  exception edges — an arbitrary call is NOT assumed to throw, otherwise
  "release on all paths including exception edges" would be
  unsatisfiable for any code that calls anything.
* ``finally`` blocks are threaded on the normal and handler exits and on
  ``return`` paths; the finally tail conservatively edges to both the
  continuation and EXIT.
* A ``while True:`` head has no false edge (the loop only exits via
  ``break``/``return``/``raise``), so code after an infinite loop is not
  treated as reachable from before it.
* ``assert`` and ``with`` are plain statements (an assert failure is a
  fatal invariant trip, not a resource-flow path we lint).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

NORMAL = "normal"
TRUE = "true"
FALSE = "false"
BACK = "back"
EXC = "exc"


@dataclass
class Node:
    idx: int
    stmt: Optional[ast.stmt]      # None for the synthetic nodes
    kind: str                     # "entry" | "exit" | "raise-exit" | "stmt"

    @property
    def line(self) -> int:
        return self.stmt.lineno if self.stmt is not None else 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        if self.stmt is None:
            return f"<{self.kind}>"
        return f"<n{self.idx} L{self.line} {type(self.stmt).__name__}>"


@dataclass
class CFG:
    name: str
    nodes: List[Node] = field(default_factory=list)
    succ: Dict[int, List[Tuple[int, str]]] = field(default_factory=dict)
    pred: Dict[int, List[Tuple[int, str]]] = field(default_factory=dict)
    entry: int = -1
    exit: int = -1
    raise_exit: int = -1

    # ------------------------------------------------------------------ #
    def add_node(self, stmt: Optional[ast.stmt], kind: str = "stmt") -> int:
        idx = len(self.nodes)
        self.nodes.append(Node(idx, stmt, kind))
        self.succ[idx] = []
        self.pred[idx] = []
        return idx

    def add_edge(self, u: int, v: int, kind: str = NORMAL) -> None:
        if (v, kind) not in self.succ[u]:
            self.succ[u].append((v, kind))
            self.pred[v].append((u, kind))

    @property
    def edges(self) -> List[Tuple[int, int, str]]:
        return [(u, v, k) for u, outs in self.succ.items()
                for v, k in outs]

    def stmt_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.stmt is not None]

    # ------------------------------------------------------------------ #
    def reachable(self, starts: Iterable[int],
                  blocked: Iterable[int] = ()) -> Set[int]:
        """Nodes reachable from ``starts`` without entering ``blocked``.

        Blocked nodes are neither visited nor expanded — this is the
        primitive behind the all-paths pairing check: a release-free path
        from an acquire to EXIT exists iff EXIT is reachable from the
        acquire's successors in the graph minus the release nodes.
        """
        blocked = set(blocked)
        seen: Set[int] = set()
        stack = [s for s in starts if s not in blocked]
        while stack:
            u = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            for v, _ in self.succ[u]:
                if v not in seen and v not in blocked:
                    stack.append(v)
        return seen

    def iter_paths(self, max_paths: int = 20000) -> Iterator[List[int]]:
        """Enumerate maximal paths from ENTRY, each edge taken at most
        once per path (so every loop is unrolled at most one full lap per
        path and the walk always terminates). A path ends at EXIT,
        RAISE_EXIT, or a node whose out-edges are all already used."""
        yielded = 0
        # stack entries: (path, used-edge set)
        stack: List[Tuple[List[int], frozenset]] = [
            ([self.entry], frozenset())]
        while stack and yielded < max_paths:
            path, used = stack.pop()
            u = path[-1]
            nxt = [(v, k) for v, k in self.succ[u]
                   if (u, v, k) not in used]
            if not nxt:
                yielded += 1
                yield path
                continue
            for v, k in reversed(nxt):
                stack.append((path + [v], used | {(u, v, k)}))


class _Builder:
    """Recursive-descent CFG construction.

    A *frontier* is the set of (node, edge-kind) pairs whose edges are
    still dangling and will attach to whatever node comes next.
    """

    def __init__(self, name: str):
        self.cfg = CFG(name)
        self.cfg.entry = self.cfg.add_node(None, "entry")
        self.cfg.exit = self.cfg.add_node(None, "exit")
        self.cfg.raise_exit = self.cfg.add_node(None, "raise-exit")
        # innermost-first stacks
        self._loops: List[Tuple[int, List[Tuple[int, str]]]] = []
        self._handlers: List[List[int]] = []   # handler heads per try
        self._finals: List[List[ast.stmt]] = []  # enclosing finally bodies

    # ------------------------------------------------------------------ #
    def build(self, body: Sequence[ast.stmt]) -> CFG:
        frontier = self._block(body, [(self.cfg.entry, NORMAL)])
        self._connect(frontier, self.cfg.exit)
        return self.cfg

    def _connect(self, frontier: Sequence[Tuple[int, str]],
                 target: int) -> None:
        for u, kind in frontier:
            self.cfg.add_edge(u, target, kind)

    def _new(self, stmt: ast.stmt,
             frontier: Sequence[Tuple[int, str]]) -> int:
        n = self.cfg.add_node(stmt)
        self._connect(frontier, n)
        # statements lexically inside a try body may raise into that
        # try's handlers
        if self._handlers:
            for h in self._handlers[-1]:
                self.cfg.add_edge(n, h, EXC)
        return n

    def _abrupt(self, n: int, target: int) -> None:
        """Route an abrupt edge (return / raise-to-exit / break /
        continue) from node ``n`` through any enclosing finally bodies,
        then to ``target``."""
        frontier: List[Tuple[int, str]] = [(n, NORMAL)]
        for fin_body in reversed(self._finals):
            if not fin_body:
                continue
            frontier = self._block(fin_body, frontier)
        self._connect(frontier, target)

    # ------------------------------------------------------------------ #
    def _block(self, stmts: Sequence[ast.stmt],
               frontier: List[Tuple[int, str]]) -> List[Tuple[int, str]]:
        for stmt in stmts:
            if not frontier:
                # unreachable code after return/raise/break — still build
                # nodes (a checker may want their calls) but leave them
                # disconnected from the flow
                pass
            frontier = self._stmt(stmt, frontier)
        return frontier

    def _stmt(self, stmt: ast.stmt,
              frontier: List[Tuple[int, str]]) -> List[Tuple[int, str]]:
        cfg = self.cfg
        if isinstance(stmt, ast.If):
            n = self._new(stmt, frontier)
            then_f = self._block(stmt.body, [(n, TRUE)])
            else_f = self._block(stmt.orelse, [(n, FALSE)]) \
                if stmt.orelse else [(n, FALSE)]
            return then_f + else_f

        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            head = self._new(stmt, frontier)
            breaks: List[Tuple[int, str]] = []
            self._loops.append((head, breaks))
            body_f = self._block(stmt.body, [(head, TRUE)])
            self._loops.pop()
            for u, kind in body_f:
                cfg.add_edge(u, head, BACK)
            infinite = (isinstance(stmt, ast.While)
                        and isinstance(stmt.test, ast.Constant)
                        and bool(stmt.test.value))
            out: List[Tuple[int, str]] = [] if infinite else [(head, FALSE)]
            if stmt.orelse:
                out = self._block(stmt.orelse, out)
            return out + breaks

        if isinstance(stmt, ast.Break):
            n = self._new(stmt, frontier)
            if self._loops:
                self._loops[-1][1].append((n, NORMAL))
            return []

        if isinstance(stmt, ast.Continue):
            n = self._new(stmt, frontier)
            if self._loops:
                cfg.add_edge(n, self._loops[-1][0], BACK)
            return []

        if isinstance(stmt, ast.Return):
            n = self._new(stmt, frontier)
            self._abrupt(n, cfg.exit)
            return []

        if isinstance(stmt, ast.Raise):
            n = self._new(stmt, frontier)
            if self._handlers:
                # _new already wired the exc edges to the innermost
                # handlers; a raise has no normal successor
                pass
            else:
                self._abrupt(n, cfg.raise_exit)
            return []

        if isinstance(stmt, ast.Try):
            return self._try(stmt, frontier)

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            n = self._new(stmt, frontier)
            return self._block(stmt.body, [(n, NORMAL)])

        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # a nested def is a single binding statement here; its body
            # gets its own CFG when a checker asks for it
            n = self._new(stmt, frontier)
            return [(n, NORMAL)]

        # plain statement (Assign, Expr, AugAssign, Assert, ...)
        n = self._new(stmt, frontier)
        return [(n, NORMAL)]

    def _try(self, stmt: ast.Try,
             frontier: List[Tuple[int, str]]) -> List[Tuple[int, str]]:
        cfg = self.cfg
        head = self._new(stmt, frontier)   # the `try:` itself
        handler_heads: List[int] = []
        for h in stmt.handlers:
            hn = cfg.add_node(h)  # type: ignore[arg-type]
            handler_heads.append(hn)

        has_finally = bool(stmt.finalbody)
        if has_finally:
            self._finals.append(stmt.finalbody)
        self._handlers.append(handler_heads)
        body_f = self._block(stmt.body, [(head, NORMAL)])
        self._handlers.pop()

        out: List[Tuple[int, str]] = []
        if stmt.orelse:
            body_f = self._block(stmt.orelse, body_f)
        out.extend(body_f)

        for h, hn in zip(stmt.handlers, handler_heads):
            hf = self._block(h.body, [(hn, NORMAL)])
            out.extend(hf)
            # a handler that doesn't match re-raises: edge to the next
            # enclosing handlers, else the raise exit
            if self._handlers:
                for outer in self._handlers[-1]:
                    cfg.add_edge(hn, outer, EXC)
            else:
                cfg.add_edge(hn, cfg.raise_exit, EXC)

        if has_finally:
            self._finals.pop()
            out = self._block(stmt.finalbody, out)
        return out


def build_cfg(fn: ast.FunctionDef) -> CFG:
    """Build the CFG of one function/method body."""
    return _Builder(fn.name).build(fn.body)


def build_module_cfg(tree: ast.Module, name: str = "<module>") -> CFG:
    """CFG over a module's top-level statements (used by fixtures)."""
    return _Builder(name).build(tree.body)
