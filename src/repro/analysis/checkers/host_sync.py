"""Checker 2 — host-sync discipline + wall-clock ban.

Rule ``host-sync``: inside ``serving/``, every device->host
synchronization site — ``.block_until_ready()``, ``jax.device_get``,
``.item()``, or ``np.asarray`` applied to a device value — must sit
within a few CFG statements of a ``host_syncs`` counter update, so
``ServeStats.host_syncs`` ("device->host round-trips taken") stays an
exact count, which the fused-decode sync-bound tests and the paper's
one-sync-per-block claim both lean on.

Device values are tracked by a per-function taint pass seeded at calls
to the class's jitted callables (``self._prefill = jax.jit(...)`` style
assignments collected per class): anything computed from a jitted
result is device-resident until ``np.asarray`` pulls it to the host.
``np.asarray`` over plain host data (hash digests, latency lists) is
NOT a sync and is never flagged.

Rule ``wall-clock``: virtual-time modules must not read the wall clock
— ``time.time``/``time.monotonic``/``datetime.now``-style calls are
banned everywhere under ``repro/`` except ``launch/dryrun.py`` (the
compile-latency harness, which measures real wall time on purpose).
``time.perf_counter`` stays legal: it is the measured-kernel-wall basis
the virtual clock is built FROM (DESIGN.md SS13).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.cfg import build_cfg
from repro.analysis.core import (Finding, FunctionInfo, ModuleInfo, Project,
                                 attr_chain, call_name, stmt_calls)

RULE = "host-sync"
WALL_RULE = "wall-clock"
SCOPE = "repro/serving/"
WALL_ALLOWLIST = ("repro/launch/dryrun.py",)

# how many CFG statements away an increment may sit from its sync site
ADJACENCY = 12

_WALL_BANNED: Tuple[Tuple[str, ...], ...] = (
    ("time", "time"), ("time", "monotonic"), ("time", "monotonic_ns"),
    ("time", "localtime"), ("time", "gmtime"), ("time", "ctime"),
    ("time", "strftime"), ("datetime", "now"), ("datetime", "utcnow"),
    ("datetime", "today"), ("date", "today"),
)


def _jitted_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attribute names assigned from ``jax.jit(...)`` in any method:
    ``self._prefill = jax.jit(partial(...))`` -> ``{"_prefill"}``."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call)
                and call_name(node.value) == "jit"):
            continue
        for tgt in node.targets:
            chain = attr_chain(tgt)
            if len(chain) == 2 and chain[0] == "self":
                out.add(chain[1])
    return out


def _is_np_asarray(call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    return (len(chain) == 2 and chain[0] in ("np", "numpy")
            and chain[1] in ("asarray", "array"))


class _Taint:
    """Flow-insensitive device-value taint within one function."""

    def __init__(self, jitted: Set[str]):
        self.jitted = jitted
        self.names: Set[str] = set()

    def device(self, expr: ast.expr) -> bool:
        if isinstance(expr, ast.Call):
            chain = attr_chain(expr.func)
            if (len(chain) == 2 and chain[0] == "self"
                    and chain[1] in self.jitted):
                return True
            if _is_np_asarray(expr):
                return False          # the pull itself lands on the host
            return any(self.device(a) for a in expr.args) or any(
                kw.value is not None and self.device(kw.value)
                for kw in expr.keywords)
        if isinstance(expr, ast.Name):
            return expr.id in self.names
        return any(self.device(c) for c in ast.iter_child_nodes(expr)
                   if isinstance(c, ast.expr))

    def run(self, fn: ast.FunctionDef) -> None:
        changed = True
        while changed:
            changed = False
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    val, tgts = node.value, node.targets
                elif isinstance(node, ast.AugAssign):
                    val, tgts = node.value, [node.target]
                else:
                    continue
                if not self.device(val):
                    continue
                for tgt in tgts:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name) \
                                and n.id not in self.names:
                            self.names.add(n.id)
                            changed = True


def _sync_calls(stmt: ast.stmt, taint: _Taint) -> List[Tuple[ast.Call, str]]:
    out = []
    for c in stmt_calls(stmt):
        name = call_name(c)
        chain = attr_chain(c.func)
        if name == "block_until_ready":
            out.append((c, "block_until_ready()"))
        elif chain[-2:] == ["jax", "device_get"] or chain == ["device_get"]:
            out.append((c, "jax.device_get"))
        elif name == "item" and len(chain) >= 2:
            out.append((c, ".item()"))
        elif _is_np_asarray(c) and c.args and taint.device(c.args[0]):
            out.append((c, "np.asarray(<device value>)"))
    return out


def _is_increment(stmt: ast.stmt) -> bool:
    tgts: List[ast.expr] = []
    if isinstance(stmt, ast.AugAssign):
        tgts = [stmt.target]
    elif isinstance(stmt, ast.Assign):
        tgts = list(stmt.targets)
    for tgt in tgts:
        chain = attr_chain(tgt)
        if chain and chain[-1] == "host_syncs":
            return True
    return False


def _check_function(mod: ModuleInfo, info: FunctionInfo,
                    jitted: Set[str]) -> List[Finding]:
    fn = info.node
    src = ast.dump(fn)  # cheap pre-filter
    if ("block_until_ready" not in src and "device_get" not in src
            and "asarray" not in src and "'item'" not in src):
        return []
    taint = _Taint(jitted)
    taint.run(fn)
    cfg = build_cfg(fn)
    sync_nodes: List[Tuple[int, ast.stmt, str]] = []
    incr_nodes: Set[int] = set()
    for node in cfg.stmt_nodes():
        if _is_increment(node.stmt):
            incr_nodes.add(node.idx)
        for _, what in _sync_calls(node.stmt, taint):
            sync_nodes.append((node.idx, node.stmt, what))

    out: List[Finding] = []
    for idx, stmt, what in sync_nodes:
        if idx in incr_nodes:
            continue
        # undirected BFS: an increment within ADJACENCY statements in
        # either flow direction counts as "adjacent"
        seen = {idx}
        frontier = {idx}
        found = False
        for _ in range(ADJACENCY):
            nxt = set()
            for u in frontier:
                for v, _k in cfg.succ[u]:
                    nxt.add(v)
                for v, _k in cfg.pred[u]:
                    nxt.add(v)
            nxt -= seen
            if nxt & incr_nodes:
                found = True
                break
            seen |= nxt
            frontier = nxt
            if not frontier:
                break
        if not found:
            out.append(Finding(
                RULE, mod.rel, stmt.lineno, info.qualname,
                f"device sync {what} has no host_syncs accounting within "
                f"{ADJACENCY} statements"))
    return out


def _wall_clock(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if mod.rel in WALL_ALLOWLIST:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = tuple(attr_chain(node.func))
            if any(chain[-len(b):] == b for b in _WALL_BANNED if chain):
                qual = "<module>"
                out.append(Finding(
                    WALL_RULE, mod.rel, node.lineno, qual,
                    f"wall-clock call {'.'.join(chain)}() in a "
                    f"virtual-time module (allowlist: "
                    f"{', '.join(WALL_ALLOWLIST)})"))
    return out


def check(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.in_dir(SCOPE):
        jit_by_class: Dict[Optional[ast.ClassDef], Set[str]] = {}
        for info in mod.functions:
            cls = info.cls
            if cls not in jit_by_class:
                jit_by_class[cls] = _jitted_attrs(cls) if cls else set()
            out.extend(_check_function(mod, info, jit_by_class[cls]))
    out.extend(_wall_clock(project))
    return out
