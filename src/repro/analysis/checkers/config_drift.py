"""Checker 5 — config/CLI drift (rule ``config-drift``).

``launch/serve.py`` is the paper-reproduction front door: a flag that
parses but is silently ignored produces a benchmark run that LOOKS
configured (the flag is in the command line the paper artifact records)
while measuring something else. Three static closures prevent that:

* every ``add_argument("--flag")`` must have its dest read somewhere in
  ``serve.py`` (``args.flag`` / an explicit ``dest=``);
* every keyword passed at a ``ServeEngine(...)`` construction site in
  ``serve.py`` must be a real ``ServeEngine.__init__`` parameter;
* every ``ServeEngine.__init__`` parameter must be consumed by the
  constructor body (an accepted-but-unused parameter is the same bug
  one layer down).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.core import (Finding, ModuleInfo, Project, attr_chain,
                                 call_name)

RULE = "config-drift"
SERVE_REL = "repro/launch/serve.py"
ENGINE_REL = "repro/serving/engine.py"


def _flags(serve: ModuleInfo) -> List[tuple]:
    out = []
    for node in ast.walk(serve.tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) == "add_argument"):
            continue
        flag = None
        for a in node.args:
            if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                    and a.value.startswith("--"):
                flag = a.value
                break
        if flag is None:
            continue
        dest = None
        for kw in node.keywords:
            if kw.arg == "dest" and isinstance(kw.value, ast.Constant):
                dest = kw.value.value
        if dest is None:
            dest = flag.lstrip("-").replace("-", "_")
        out.append((flag, dest, node.lineno))
    return out


def _args_reads(serve: ModuleInfo) -> Set[str]:
    reads: Set[str] = set()
    for node in ast.walk(serve.tree):
        if isinstance(node, ast.Attribute):
            chain = attr_chain(node)
            if len(chain) == 2 and chain[0] == "args":
                reads.add(chain[1])
    return reads


def _engine_init(engine: ModuleInfo) -> Optional[ast.FunctionDef]:
    for node in engine.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "ServeEngine":
            for item in node.body:
                if isinstance(item, ast.FunctionDef) \
                        and item.name == "__init__":
                    return item
    return None


def _init_params(init: ast.FunctionDef) -> Set[str]:
    names = {a.arg for a in init.args.args} \
        | {a.arg for a in init.args.kwonlyargs}
    names.discard("self")
    return names


def check(project: Project) -> List[Finding]:
    out: List[Finding] = []
    serve = project.module(SERVE_REL)
    engine = project.module(ENGINE_REL)

    init = _engine_init(engine) if engine is not None else None
    init_params = _init_params(init) if init is not None else set()

    if serve is not None:
        reads = _args_reads(serve)
        for flag, dest, line in _flags(serve):
            if dest not in reads:
                out.append(Finding(
                    RULE, SERVE_REL, line, "<module>",
                    f"flag '{flag}' is parsed but args.{dest} is never "
                    f"read — the CLI silently ignores it"))
        # ServeEngine(...) call sites must use real constructor params
        if init_params:
            for node in ast.walk(serve.tree):
                if isinstance(node, ast.Call) \
                        and call_name(node) == "ServeEngine":
                    for kw in node.keywords:
                        if kw.arg is not None \
                                and kw.arg not in init_params:
                            out.append(Finding(
                                RULE, SERVE_REL, node.lineno, "<module>",
                                f"ServeEngine(...) passes unknown "
                                f"keyword '{kw.arg}'"))

    if init is not None:
        # every accepted parameter must be consumed in the body
        body_names: Set[str] = set()
        for stmt in init.body:
            for n in ast.walk(stmt):
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                    body_names.add(n.id)
        for p in sorted(_init_params(init)):
            if p not in body_names:
                out.append(Finding(
                    RULE, ENGINE_REL, init.lineno, "ServeEngine.__init__",
                    f"constructor parameter '{p}' is accepted but never "
                    f"consumed"))
    return out
