"""Checker 3 — traced-code purity (rule ``traced-purity``).

Functions handed to ``jax.jit`` / ``lax.scan`` / ``shard_map`` /
``pl.pallas_call`` execute under tracing: Python-level randomness,
wall-clock reads, printing, I/O, or host branching on traced values
either breaks (concretization errors) or — worse — silently bakes a
trace-time constant into the compiled program. This checker resolves
each staged callable (through ``functools.partial``, ``jax.checkpoint``,
``jax.vmap`` wrappers, local defs, lambdas, and one ``from repro.x
import y`` re-export hop) and walks its body, plus repo-local callees a
few levels deep, for:

* banned host calls — ``random.*``, ``np.random.*``, ``time.*``,
  ``datetime.*``, ``print``, ``open``, ``input``, ``.item()``,
  ``.block_until_ready()``, and ``np.asarray``/``np.array`` over traced
  values (``jnp`` stays legal, as does ``jax.random``);
* host branching — an ``if``/``while`` condition reading a *traced*
  parameter directly. Static arguments (bound by ``partial`` or named in
  ``static_argnames``/``static_argnums``), ``is None`` tests, and
  shape/dtype/len/isinstance inspection are all legal host control flow.

Pallas ``index_map`` lambdas (in ``BlockSpec`` /
``PrefetchScalarGridSpec``) must be side-effect-free: arithmetic,
subscripts and ``pl.*`` helpers only.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, ModuleInfo, Project, attr_chain,
                                 call_name)

RULE = "traced-purity"
SCOPE = "repro/"

_WRAPPERS = {"checkpoint", "remat", "vmap", "custom_vjp", "named_call"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_PURE_INDEX_ROOTS = {"pl", "pltpu", "min", "max", "abs", "divmod", "int",
                     "sum", "len"}
_TRANSITIVE_DEPTH = 5


def _const_str_items(node: ast.expr) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return out
    return []


@dataclass
class _Resolved:
    mod: ModuleInfo
    fn: ast.AST                      # FunctionDef or Lambda
    qualname: str
    bound_pos: int = 0               # leading params bound by partial
    bound_kw: Set[str] = field(default_factory=set)
    static_names: Set[str] = field(default_factory=set)
    static_nums: Set[int] = field(default_factory=set)

    def traced_params(self) -> Set[str]:
        args = self.fn.args
        names = [a.arg for a in args.args]
        traced: Set[str] = set()
        for i, name in enumerate(names):
            if i < self.bound_pos:
                continue
            if name in self.bound_kw or name in self.static_names:
                continue
            if i in self.static_nums:
                continue
            if name in ("self", "cls"):
                continue
            traced.add(name)
        # kwonly params are static when bound/named, else traced
        for a in args.kwonlyargs:
            if a.arg not in self.bound_kw \
                    and a.arg not in self.static_names:
                traced.add(a.arg)
        return traced


class _Resolver:
    def __init__(self, project: Project):
        self.project = project

    def local_def(self, mod: ModuleInfo, name: str,
                  near: Optional[str]) -> Optional[Tuple[ModuleInfo,
                                                         ast.FunctionDef,
                                                         str]]:
        """Find a def named ``name`` in ``mod``, preferring one nested
        inside the function ``near`` (the staging site's scope)."""
        cands = [f for f in mod.functions if f.node.name == name]
        if not cands:
            resolved = self.project.resolve_import(mod, name)
            if resolved is None:
                return None
            mod2, node = resolved
            if not isinstance(node, ast.FunctionDef):
                return None
            return mod2, node, node.name
        if near:
            nested = [f for f in cands if f.qualname.startswith(near + ".")]
            if nested:
                return mod, nested[0].node, nested[0].qualname
        return mod, cands[0].node, cands[0].qualname

    def resolve(self, mod: ModuleInfo, expr: ast.expr, near: Optional[str],
                static_names: Set[str], static_nums: Set[int]
                ) -> Optional[_Resolved]:
        bound_pos = 0
        bound_kw: Set[str] = set()
        while isinstance(expr, ast.Call):
            cname = call_name(expr)
            if cname == "partial":
                if not expr.args:
                    return None
                bound_pos += len(expr.args) - 1
                bound_kw |= {kw.arg for kw in expr.keywords
                             if kw.arg is not None}
                expr = expr.args[0]
            elif cname in _WRAPPERS:
                if not expr.args:
                    return None
                expr = expr.args[0]
            else:
                return None
        if isinstance(expr, ast.Lambda):
            return _Resolved(mod, expr, "<lambda>", bound_pos, bound_kw,
                             static_names, static_nums)
        chain = attr_chain(expr)
        if not chain:
            return None
        if len(chain) == 1:
            hit = self.local_def(mod, chain[0], near)
        elif len(chain) == 2 and chain[0] not in ("self", "cls"):
            # module-attribute reference like ``sampling.sample``
            hit = self._module_member(mod, chain[0], chain[1])
        else:
            return None
        if hit is None:
            return None
        mod2, node, qual = hit
        return _Resolved(mod2, node, qual, bound_pos, bound_kw,
                         static_names, static_nums)

    def _module_member(self, mod: ModuleInfo, alias: str, member: str
                       ) -> Optional[Tuple[ModuleInfo, ast.FunctionDef,
                                           str]]:
        """Resolve ``alias.member`` where ``alias`` was imported via
        ``from repro.pkg import alias`` (a submodule import)."""
        for node in mod.tree.body:
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if (a.asname or a.name) == alias:
                        target = self.project._module_for(
                            f"{node.module}.{a.name}", mod, node.level)
                        if target is None:
                            continue
                        for f in target.functions:
                            if f.qualname == member:
                                return target, f.node, f.qualname
        return None


def _banned_call(call: ast.Call, traced: Set[str]) -> Optional[str]:
    chain = attr_chain(call.func)
    if not chain:
        return None
    root = chain[0]
    if root == "random" and len(chain) > 1:
        return f"host randomness {'.'.join(chain)}()"
    if root in ("np", "numpy") and len(chain) > 2 \
            and chain[1] == "random":
        return f"host randomness {'.'.join(chain)}()"
    if root == "time":
        return f"wall clock {'.'.join(chain)}() baked in at trace time"
    if root == "datetime" and len(chain) > 1:
        return f"wall clock {'.'.join(chain)}()"
    if chain == ["print"]:
        return "print() traced as a side effect"
    if chain in (["open"], ["input"]):
        return f"host I/O {chain[0]}()"
    if chain[-1] == "block_until_ready":
        return "block_until_ready() inside traced code"
    if chain[-1] == "item" and len(chain) >= 2:
        return ".item() forces a host sync inside traced code"
    if root in ("np", "numpy") and chain[-1] in ("asarray", "array"):
        for a in call.args:
            for n in ast.walk(a):
                if isinstance(n, ast.Name) and n.id in traced:
                    return (f"np.{chain[-1]}() pulls traced value "
                            f"'{n.id}' to the host")
    return None


def _cond_violations(cond: ast.expr, traced: Set[str]) -> List[str]:
    """Traced names driving host control flow, minus the legal idioms."""
    allowed: Set[int] = set()

    def mark_allowed(node: ast.AST) -> None:
        for n in ast.walk(node):
            allowed.add(id(n))

    for node in ast.walk(cond):
        if isinstance(node, ast.Compare):
            ops_none = all(isinstance(op, (ast.Is, ast.IsNot))
                           for op in node.ops)
            cmps_none = all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators)
            if ops_none and cmps_none:
                mark_allowed(node)
        elif isinstance(node, ast.Attribute) \
                and node.attr in _SHAPE_ATTRS:
            mark_allowed(node)
        elif isinstance(node, ast.Call) \
                and call_name(node) in ("len", "isinstance", "getattr",
                                        "hasattr"):
            mark_allowed(node)

    bad = []
    for n in ast.walk(cond):
        if isinstance(n, ast.Name) and n.id in traced \
                and id(n) not in allowed:
            bad.append(n.id)
    return sorted(set(bad))


def _walk_body(res: _Resolved, resolver: _Resolver,
               visited: Set[Tuple[str, str]], depth: int,
               top: bool) -> List[Finding]:
    """Banned-call scan (transitive); host-branching scan (top level,
    where the traced-parameter set is actually known)."""
    key = (res.mod.rel, res.qualname)
    if key in visited or depth > _TRANSITIVE_DEPTH:
        return []
    visited.add(key)
    traced = res.traced_params() if not isinstance(res.fn, ast.Lambda) \
        else {a.arg for a in res.fn.args.args}
    out: List[Finding] = []
    body = res.fn.body if isinstance(res.fn, ast.FunctionDef) \
        else [ast.Expr(res.fn.body)]

    def walk(node: ast.AST, in_nested: bool) -> None:
        for child in ast.iter_child_nodes(node):
            nested = in_nested or isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
            if isinstance(child, ast.Call):
                why = _banned_call(child, traced if not in_nested
                                   else set())
                if why is not None:
                    out.append(Finding(
                        RULE, res.mod.rel, child.lineno, res.qualname,
                        f"{why} (staged into jit/scan/pallas)"))
                elif not in_nested:
                    # descend into repo-local callees for banned calls
                    chain = attr_chain(child.func)
                    if len(chain) == 1:
                        hit = resolver.local_def(res.mod, chain[0],
                                                 res.qualname)
                        if hit is not None:
                            sub = _Resolved(hit[0], hit[1], hit[2])
                            out.extend(_walk_body(
                                sub, resolver, visited, depth + 1,
                                top=False))
            if top and not nested and isinstance(child,
                                                 (ast.If, ast.While)):
                for name in _cond_violations(child.test, traced):
                    out.append(Finding(
                        RULE, res.mod.rel, child.lineno, res.qualname,
                        f"traced parameter '{name}' drives host control "
                        f"flow (if/while on a traced value)"))
            walk(child, nested)

    for stmt in body:
        walk(stmt, False)
        if top and isinstance(stmt, (ast.If, ast.While)):
            for name in _cond_violations(stmt.test, traced):
                out.append(Finding(
                    RULE, res.mod.rel, stmt.lineno, res.qualname,
                    f"traced parameter '{name}' drives host control "
                    f"flow (if/while on a traced value)"))
    return out


def _index_map_findings(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if call_name(node) not in ("BlockSpec", "PrefetchScalarGridSpec"):
            continue
        lambdas: List[ast.Lambda] = []
        for a in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(a, ast.Lambda):
                lambdas.append(a)
        for lam in lambdas:
            for n in ast.walk(lam.body):
                if isinstance(n, ast.Call):
                    chain = attr_chain(n.func)
                    if not chain or chain[0] not in _PURE_INDEX_ROOTS:
                        name = ".".join(chain) or "<expr>"
                        out.append(Finding(
                            RULE, mod.rel, lam.lineno, "<index_map>",
                            f"Pallas index_map calls {name}(); index "
                            f"maps must be side-effect-free arithmetic"))
                elif isinstance(n, ast.NamedExpr):
                    out.append(Finding(
                        RULE, mod.rel, lam.lineno, "<index_map>",
                        "Pallas index_map contains an assignment "
                        "expression"))
    return out


def _entry_sites(mod: ModuleInfo) -> List[Tuple[ast.Call, Optional[str],
                                                str]]:
    """(call, enclosing-qualname, kind) for every staging call."""
    encl: Dict[int, str] = {}
    for f in mod.functions:
        for n in ast.walk(f.node):
            encl.setdefault(id(n), f.qualname)
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        name = chain[-1] if chain else ""
        if name == "jit" and (len(chain) == 1 or chain[0] == "jax"):
            out.append((node, encl.get(id(node)), "jit"))
        elif name == "scan" and len(chain) >= 2 and chain[-2] == "lax":
            out.append((node, encl.get(id(node)), "scan"))
        elif name in ("shard_map", "_shard_map"):
            out.append((node, encl.get(id(node)), "shard_map"))
        elif name == "pallas_call":
            out.append((node, encl.get(id(node)), "pallas"))
    return out


def check(project: Project) -> List[Finding]:
    resolver = _Resolver(project)
    out: List[Finding] = []
    for mod in project.in_dir(SCOPE):
        out.extend(_index_map_findings(mod))
        for call, near, kind in _entry_sites(mod):
            if not call.args:
                continue
            static_names: Set[str] = set()
            static_nums: Set[int] = set()
            if kind == "jit":
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        static_names |= set(_const_str_items(kw.value))
                    elif kw.arg == "static_argnums" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        static_nums |= {
                            e.value for e in kw.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int)}
            target = call.args[0]
            if kind == "scan" and isinstance(target, ast.Call) \
                    and call_name(target) in _WRAPPERS and target.args:
                target = target.args[0]
            res = resolver.resolve(mod, target, near, static_names,
                                   static_nums)
            if res is None:
                continue
            out.extend(_walk_body(res, resolver, set(), 0, top=True))
    # dedup (the same body may be staged from several sites)
    seen: Set[str] = set()
    uniq: List[Finding] = []
    for f in out:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            uniq.append(f)
    return uniq
