"""The five repo-specific checkers (DESIGN.md SS18).

Each module exposes ``check(project) -> list[Finding]``; ``ALL_CHECKERS``
is the ordered registry ``scripts/analyze.py`` and the tests run.
"""
from repro.analysis.checkers.accounting import check as check_accounting
from repro.analysis.checkers.config_drift import check as check_config_drift
from repro.analysis.checkers.host_sync import check as check_host_sync
from repro.analysis.checkers.purity import check as check_purity
from repro.analysis.checkers.resource import check as check_resource

ALL_CHECKERS = (
    check_resource,
    check_host_sync,
    check_purity,
    check_accounting,
    check_config_drift,
)

__all__ = ["ALL_CHECKERS", "check_resource", "check_host_sync",
           "check_purity", "check_accounting", "check_config_drift"]
