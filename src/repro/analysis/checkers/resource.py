"""Checker 1 — resource pairing (rule ``resource-pairing``).

Every acquire-style call in ``serving/`` must be paired with a release,
checked on the function's CFG:

* ``all_paths`` pairs (the residency plan/charge bracket, whose contract
  is "every plan must be charged exactly once"): a release must lie on
  EVERY path from the acquire to the normal function exit — including
  paths through exception handlers (a handler that swallows the
  exception between plan and charge leaks the plan). Paths that escape
  via an uncaught raise are exempt: the serve aborts wholesale.
* ``reach`` pairs (reservations, allocations, trace request spans,
  stream start/commit): a release must be *reachable* from the acquire
  within the function. Ownership commonly outlives one function, so two
  structural exemptions apply before a finding is raised:

  - *conduit*: the acquired value is returned to the caller (directly or
    via a name that reaches a ``return``) — ownership transfers up.
  - *class owner*: the enclosing class defines or calls a matching
    release somewhere (the resource parks in instance state; e.g. the
    scheduler's ``admit`` allocates, ``retire``/``preempt_one`` free).

  A module whose functions acquire but that contains NO release anywhere
  still gets a finding — the class-owner exemption never silently
  approves a leak-only type.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Callable, FrozenSet, List, Set, Tuple

from repro.analysis.cfg import build_cfg
from repro.analysis.core import (Finding, FunctionInfo, ModuleInfo, Project,
                                 call_name, call_recv, stmt_calls)

RULE = "resource-pairing"
SCOPE = "repro/serving/"


def _any_recv(_: str) -> bool:
    return True


def _stream_recv(recv: str) -> bool:
    return recv.endswith("stream")


@dataclass(frozen=True)
class Pair:
    name: str
    acquire: FrozenSet[str]
    release: FrozenSet[str]
    mode: str                                  # "all_paths" | "reach"
    acquire_recv: Callable[[str], bool] = _any_recv
    release_recv: Callable[[str], bool] = _any_recv


PAIRS: Tuple[Pair, ...] = (
    # ResidencyPlan bracket: "Every plan must be charged exactly once"
    # (kv_manager.ResidencyPlan). stall_plan/stall_charge are the
    # engine's closures over the same calls.
    Pair("residency-plan",
         acquire=frozenset({"plan_residency", "stall_plan"}),
         release=frozenset({"charge_residency", "stall_charge",
                            "_issue_fetch"}),
         mode="all_paths"),
    # lookahead page reservations roll forward (commit) or back (release)
    Pair("kv-reservation",
         acquire=frozenset({"reserve_ahead", "reserve_lookahead"}),
         release=frozenset({"commit_tokens", "commit_speculative",
                            "release_reserved", "free_seq", "retire",
                            "preempt_one", "drop"}),
         mode="reach"),
    # page allocations are freed when the sequence leaves the pool
    Pair("kv-allocation",
         acquire=frozenset({"allocate", "allocate_shared"}),
         release=frozenset({"free_seq", "retire", "preempt_one", "drop"}),
         mode="reach",
         acquire_recv=lambda r: r in ("kv", "self")),
    # every trace request span opened by submit() is closed by retire()
    # (or the trace is finalized, which audits stragglers)
    Pair("trace-request-span",
         acquire=frozenset({"submit"}),
         release=frozenset({"retire", "finalize"}),
         mode="reach",
         acquire_recv=lambda r: r == "trace"),
    # a virtual-stream op that starts must commit its duration
    Pair("stream-span",
         acquire=frozenset({"start"}),
         release=frozenset({"commit"}),
         mode="reach",
         acquire_recv=_stream_recv,
         release_recv=_stream_recv),
)


def _pair_calls(stmt: ast.stmt, names: FrozenSet[str],
                recv_ok: Callable[[str], bool]) -> List[ast.Call]:
    return [c for c in stmt_calls(stmt)
            if call_name(c) in names and recv_ok(call_recv(c))]


def _returned_names(fn: ast.FunctionDef) -> Set[str]:
    """Names that flow into a return statement of ``fn`` (one hop)."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            for n in ast.walk(node.value):
                if isinstance(n, ast.Name):
                    out.add(n.id)
    return out


def _is_conduit(fn: ast.FunctionDef, stmt: ast.stmt,
                acq_call: ast.Call) -> bool:
    """The acquired value escapes to the caller via a return."""
    if isinstance(stmt, ast.Return):
        return True
    if isinstance(stmt, ast.Assign):
        ret_names = _returned_names(fn)
        for tgt in stmt.targets:
            for n in ast.walk(tgt):
                if isinstance(n, ast.Name) and n.id in ret_names:
                    return True
    return False


def _class_releases(info: FunctionInfo, mod: ModuleInfo,
                    pair: Pair) -> bool:
    """The enclosing class (or, for module-level functions, the module)
    defines or calls one of the pair's release methods somewhere."""
    scope: ast.AST = info.cls if info.cls is not None else mod.tree
    for node in ast.walk(scope):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in pair.release:
            return True
        if isinstance(node, ast.Call) \
                and call_name(node) in pair.release \
                and pair.release_recv(call_recv(node)):
            return True
    return False


def _check_function(mod: ModuleInfo, info: FunctionInfo,
                    pair: Pair) -> List[Finding]:
    fn = info.node
    # cheap pre-filter before building a CFG
    if not any(call_name(c) in pair.acquire for c in
               (n for n in ast.walk(fn) if isinstance(n, ast.Call))):
        return []
    cfg = build_cfg(fn)
    acquires: List[Tuple[int, ast.stmt, ast.Call]] = []
    release_nodes: Set[int] = set()
    for node in cfg.stmt_nodes():
        stmt = node.stmt
        acs = _pair_calls(stmt, pair.acquire, pair.acquire_recv)
        rels = _pair_calls(stmt, pair.release, pair.release_recv)
        if rels:
            release_nodes.add(node.idx)
        for c in acs:
            acquires.append((node.idx, stmt, c))

    out: List[Finding] = []
    for idx, stmt, call in acquires:
        if idx in release_nodes:
            continue                      # acquire+release in one stmt
        if _is_conduit(fn, stmt, call):
            continue                      # ownership returns to the caller
        succs = [v for v, _ in cfg.succ[idx]]
        if pair.mode == "all_paths":
            bad = cfg.exit in cfg.reachable(succs, blocked=release_nodes)
            if bad:
                out.append(Finding(
                    RULE, mod.rel, stmt.lineno, info.qualname,
                    f"'{call_name(call)}' ({pair.name}) can reach the "
                    f"function exit without any of "
                    f"{sorted(pair.release)} on some path "
                    f"(exception edges included)"))
        else:
            ok = bool(release_nodes & cfg.reachable(succs))
            if ok:
                continue
            if _class_releases(info, mod, pair):
                continue
            out.append(Finding(
                RULE, mod.rel, stmt.lineno, info.qualname,
                f"'{call_name(call)}' ({pair.name}) never reaches a "
                f"release ({', '.join(sorted(pair.release))}) — not "
                f"returned to the caller, and the enclosing "
                f"{'class' if info.cls else 'module'} has no release"))
    return out


def check(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for mod in project.in_dir(SCOPE):
        for info in mod.functions:
            for pair in PAIRS:
                out.extend(_check_function(mod, info, pair))
    return out
