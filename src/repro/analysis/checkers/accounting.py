"""Checker 4 — accounting completeness (rules ``accounting``,
``channel-vocab``).

``ServeStats`` is the serving stack's ledger; ``trace.reconcile()`` is
its audit. This checker closes the loop statically:

* every ``ServeStats`` field must be WRITTEN by some ``serving/``
  module (a field nothing writes is dead weight that silently reports
  zero), and
* every field must either appear in the ``trace.reconcile(...)`` call
  (the audited set) or carry an entry in the EXEMPT table below, whose
  justification documents why the trace cannot cross-check it. A stale
  exemption — for a field that no longer exists or that became
  reconciled — is itself a finding, so the table cannot rot.

``channel-vocab``: ``channel_bytes`` keys come from the fixed
``"src->dst"`` vocabulary in :mod:`repro.serving.channels`. Any
``"a->b"`` string literal in a serving module must be a known label, and
f-strings must not build labels inline — they must route through
``channels.make_label`` so the runtime validates direction.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set

from repro.analysis.core import (Finding, ModuleInfo, Project, attr_chain,
                                 call_name, call_recv)
from repro.serving.channels import CHANNEL_LABELS

RULE = "accounting"
VOCAB_RULE = "channel-vocab"
SCOPE = "repro/serving/"
ENGINE_REL = "repro/serving/engine.py"
CHANNELS_REL = "repro/serving/channels.py"

_LABEL_RE = re.compile(r"^[a-z0-9_]+->[a-z0-9_]+$")

# Fields the trace genuinely cannot audit, with the reason why. The
# reconciled set is parsed from the live trace.reconcile(...) call, so
# a field that later joins the audit flips its entry here to "stale".
EXEMPT: Dict[str, str] = {
    "prefill_s": "phase wall time; the trace's per-span times are "
                 "derived FROM it, a cross-check would be circular",
    "decode_s": "phase wall time; same circularity as prefill_s",
    "serve_s": "stream-clock makespan; finalize() consumes it as input",
    "requests": "workload size, an input not a measurement",
    "decode_steps": "device micro-step count; no trace event per step "
                    "by design (one span per fused block)",
    "preemptions": "scheduler event count; preemption spans carry no "
                   "aggregate to diff against",
    "prefill_tokens_computed": "chunk arithmetic audited by "
                               "test_prefix_cache token-count asserts",
    "cached_prefix_tokens": "prefix-cache hit accounting, audited "
                            "dynamically against kv.dedup_tokens",
    "pages_deduped": "kv-manager counter folded 1:1 into stats",
    "cow_copies": "kv-manager counter folded 1:1 into stats",
    "peak_pages_used": "a max, not a flow; cannot be conserved",
    "prefill_compiles": "compile-cache size, host-side observability",
    "host_syncs": "host round-trip count, enforced statically by the "
                  "host-sync rule and by sync-bound tests",
    "decode_compiles": "compile-cache size, host-side observability",
    "spill_bytes": "conserved against channel_bytes['ddr->hbs'], which "
                   "IS reconciled; a second check would double-count",
    "fetch_bytes": "conserved against channel_bytes['hbs->ddr'] (same)",
    "pages_spilled": "kv-manager counter folded 1:1 into stats",
    "pages_fetched": "kv-manager counter folded 1:1 into stats",
    "peak_fast_pages": "a max, not a flow; cannot be conserved",
    "prefetch_hits": "hit/miss split audited by tier-residency tests",
    "prefetch_misses": "hit/miss split audited by tier-residency tests",
    "clean_demotions": "free residency flips move no bytes, so the "
                       "byte-conservation audit cannot see them",
    "chiplet_promotions": "conserved against channel_bytes"
                          "['ddr->chiplet'] per-page-size",
    "chiplet_demotions": "conserved against channel_bytes"
                         "['chiplet->ddr'] per-page-size",
    "tier_touches": "EMA inputs; rates derived from them are asserted "
                    "in chiplet tests, totals are not conserved",
    "stall_saved_s": "counterfactual (barrier minus pipelined); only "
                     "the real stall_s is observable in the trace",
    "kv_split_at_peak": "snapshot at peak occupancy, not a flow",
    "draft_proposed": "spec accounting audited by acceptance-rate "
                      "asserts in test_spec_decode",
    "draft_accepted": "spec accounting audited by acceptance-rate "
                      "asserts in test_spec_decode",
    "spec_blocks": "verify-pass count; one spec_verify span each, but "
                   "spans are not counted by reconcile",
}


def _servestats_fields(engine: ModuleInfo) -> List[str]:
    for node in engine.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "ServeStats":
            return [s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)]
    return []


def _written_fields(mods: List[ModuleInfo], fields: Set[str]) -> Set[str]:
    written: Set[str] = set()
    for mod in mods:
        for node in ast.walk(mod.tree):
            tgts: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                tgts = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                tgts = [node.target]
            elif isinstance(node, ast.Call):
                # list/dict growth: stats.ttft.append(...), .update(...)
                chain = attr_chain(node.func)
                if chain and chain[-1] in ("append", "extend", "update"):
                    written |= set(chain) & fields
                continue
            for tgt in tgts:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Attribute) and n.attr in fields:
                        written.add(n.attr)
    return written


def _reconciled_fields(engine: ModuleInfo, fields: Set[str]) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(engine.tree):
        if isinstance(node, ast.Call) and call_name(node) == "reconcile" \
                and call_recv(node) == "trace":
            for kw in node.keywords:
                if kw.arg in fields:
                    out.add(kw.arg)
            for n in ast.walk(node):
                if isinstance(n, ast.Attribute) and n.attr in fields:
                    out.add(n.attr)
    return out


def _vocab_findings(mods: List[ModuleInfo]) -> List[Finding]:
    out: List[Finding] = []
    for mod in mods:
        if mod.rel == CHANNELS_REL:
            continue
        docstrings = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Module, ast.ClassDef,
                                 ast.FunctionDef, ast.AsyncFunctionDef)):
                body = getattr(node, "body", [])
                if body and isinstance(body[0], ast.Expr) and isinstance(
                        body[0].value, ast.Constant):
                    docstrings.add(id(body[0].value))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Constant) \
                    and isinstance(node.value, str) \
                    and id(node) not in docstrings \
                    and _LABEL_RE.match(node.value):
                if node.value not in CHANNEL_LABELS:
                    out.append(Finding(
                        VOCAB_RULE, mod.rel, node.lineno, "<module>",
                        f"channel label {node.value!r} is not in the "
                        f"fixed vocabulary "
                        f"({', '.join(CHANNEL_LABELS)})"))
            elif isinstance(node, ast.JoinedStr):
                for part in node.values:
                    if isinstance(part, ast.Constant) \
                            and isinstance(part.value, str) \
                            and "->" in part.value:
                        out.append(Finding(
                            VOCAB_RULE, mod.rel, node.lineno, "<module>",
                            "channel label built inline with an "
                            "f-string; route it through "
                            "channels.make_label so direction is "
                            "validated"))
                        break
    return out


def check(project: Project) -> List[Finding]:
    engine = project.module(ENGINE_REL)
    serving = project.in_dir(SCOPE)
    out: List[Finding] = []
    if engine is not None:
        fields = _servestats_fields(engine)
        fieldset = set(fields)
        if fields:
            written = _written_fields(serving, fieldset)
            reconciled = _reconciled_fields(engine, fieldset)
            cls_line = next(
                (n.lineno for n in engine.tree.body
                 if isinstance(n, ast.ClassDef)
                 and n.name == "ServeStats"), 1)
            for f in fields:
                if f not in written:
                    out.append(Finding(
                        RULE, ENGINE_REL, cls_line, "ServeStats",
                        f"field '{f}' is never written by any serving "
                        f"module"))
                if f not in reconciled and f not in EXEMPT:
                    out.append(Finding(
                        RULE, ENGINE_REL, cls_line, "ServeStats",
                        f"field '{f}' is neither reconciled in "
                        f"trace.reconcile() nor exempted (add it to the "
                        f"audit, or justify an exemption in "
                        f"checkers/accounting.py)"))
            for f, why in EXEMPT.items():
                if f not in fieldset:
                    out.append(Finding(
                        RULE, ENGINE_REL, cls_line, "ServeStats",
                        f"stale exemption: '{f}' is not a ServeStats "
                        f"field"))
                elif f in reconciled:
                    out.append(Finding(
                        RULE, ENGINE_REL, cls_line, "ServeStats",
                        f"stale exemption: '{f}' is reconciled now — "
                        f"drop the exemption"))
                elif not why.strip():
                    out.append(Finding(
                        RULE, ENGINE_REL, cls_line, "ServeStats",
                        f"exemption for '{f}' has no justification"))
    out.extend(_vocab_findings(serving))
    return out
