"""Deterministic synthetic token pipeline.

Stateless-by-step: ``batch_at(step)`` is a pure function of (seed, step), so
a restarted/rescaled job resumes mid-epoch exactly (fault tolerance without
data-loader state in checkpoints). Tokens follow a Zipf-ish marginal with a
Markov structure so the LM loss actually decreases during the e2e example.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass
class SyntheticTextDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefix_len: int = 0     # VLM patch / audio-frame stub embeddings
    d_model: int = 0
    pad_id: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kz, km, kp = jax.random.split(key, 3)
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # zipf-ish marginals via squared uniform -> low ids more likely
        u = jax.random.uniform(kz, (B, S))
        base = (u * u * (V - 2)).astype(jnp.int32) + 1
        # markov-ish: with p=0.5 repeat (prev + 1) mod V  -> learnable structure
        rep = jax.random.bernoulli(km, 0.5, (B, S))
        shifted = jnp.roll(base, 1, axis=1) + 1
        tokens = jnp.where(rep, shifted % V, base)
        batch = {"tokens": tokens, "labels": tokens}
        if self.prefix_len and self.d_model:
            batch["prefix_emb"] = jax.random.normal(
                kp, (B, self.prefix_len, self.d_model), jnp.float32) * 0.02
        return batch


def for_arch(cfg: ArchConfig, seq_len: int, global_batch: int,
             seed: int = 0) -> SyntheticTextDataset:
    prefix = cfg.prefix_len or (cfg.source_len if cfg.family == "encdec"
                                else 0)
    return SyntheticTextDataset(vocab=cfg.vocab, seq_len=seq_len,
                                global_batch=global_batch, seed=seed,
                                prefix_len=prefix, d_model=cfg.d_model)


def make_batch_iterator(ds: SyntheticTextDataset, start_step: int = 0,
                        sharding=None) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        b = ds.batch_at(step)
        if sharding is not None:
            b = {k: jax.device_put(v, sharding[k] if isinstance(sharding, dict)
                                   else sharding) for k, v in b.items()}
        yield b
        step += 1
