"""Mixture-of-experts FFN (pure JAX, EP-shardable).

Dispatch is sort-free and dense-einsum-free on the expert axis: tokens are
sorted by expert id and run through ``jax.lax.ragged_dot`` grouped GEMMs, so
compiled FLOPs equal routed FLOPs (top-k of E), which keeps the roofline's
MODEL_FLOPS/HLO_FLOPS honest. Supports DeepSeek-style shared experts and
Arctic-style parallel dense residual (configured via ``MoEConfig``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.jax_compat import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.jax_compat import shard_map as _shard_map

from repro.configs.base import ArchConfig
from repro.models import common as cm


def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d, e = cfg.d_model, m.n_experts
    dff = m.d_ff_expert
    keys = jax.random.split(key, 8)
    n_up = 2 * dff if cfg.gated_mlp else dff
    p = {
        "router": cm.dense_init(keys[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(keys[1], (e, d, n_up), jnp.float32)
                 * (d ** -0.5)).astype(dtype),
        "w_down": (jax.random.normal(keys[2], (e, dff, d), jnp.float32)
                   * (dff ** -0.5)).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = init_dense_ffn(keys[3], cfg, dff * m.n_shared, dtype)
    if m.dense_residual:
        p["residual"] = init_dense_ffn(keys[4], cfg,
                                       m.d_ff_dense or cfg.d_ff, dtype)
    return p


def init_dense_ffn(key, cfg: ArchConfig, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    n_up = 2 * d_ff if cfg.gated_mlp else d_ff
    return {"up": cm.dense_init(k1, cfg.d_model, n_up, dtype),
            "down": cm.dense_init(k2, d_ff, cfg.d_model, dtype)}


def dense_ffn(p, x, gated: bool):
    h = cm.dense(p["up"], x)
    if gated:
        gate, up = jnp.split(h, 2, axis=-1)
        h = cm.swiglu(gate, up)
    else:
        h = jax.nn.gelu(h)
    return cm.dense(p["down"], h)


def _act(h, gated: bool):
    if gated:
        gate, up = jnp.split(h, 2, axis=-1)
        return cm.swiglu(gate, up)
    return jax.nn.gelu(h)


def _ragged_path(p, xf, expert_ids, gate_vals, m, gated: bool):
    """Sort + ragged_dot grouped GEMM (true ragged; best on TPU runtime)."""
    T, k = expert_ids.shape
    flat_expert = expert_ids.reshape(-1)
    order = jnp.argsort(flat_expert)
    inv = jnp.argsort(order)
    xs = jnp.repeat(xf, k, axis=0)[order]
    group_sizes = jnp.bincount(flat_expert, length=m.n_experts).astype(
        jnp.int32)
    h = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    h = _act(h, gated)
    ys = jax.lax.ragged_dot(h, p["w_down"], group_sizes)
    ys = ys[inv].reshape(T, k, -1)
    return jnp.einsum("tkd,tk->td", ys.astype(jnp.float32), gate_vals)


def _capacity_path(p, xf, expert_ids, gate_vals, m, gated: bool,
                   capacity_factor: float, expert_sharding=None,
                   out_sharding=None):
    """Capacity-dropped dispatch via batched expert GEMMs.

    Compiled FLOPs = E*C*ffn = tokens*top_k*capacity_factor*ffn — only the
    slack factor above routed FLOPs (ragged_dot's generic lowering counts
    dense T x E work, which would poison the roofline's useful-FLOPs ratio).
    """
    T, k = expert_ids.shape
    E = m.n_experts
    C = max(int(T * k * capacity_factor / E), 1)
    flat_expert = expert_ids.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_expert)                          # slot -> T*k idx
    sorted_eid = flat_expert[order]
    group_start = jnp.cumsum(
        jnp.bincount(flat_expert, length=E)).astype(jnp.int32)
    start_of = jnp.concatenate([jnp.zeros((1,), jnp.int32), group_start[:-1]])
    rank = jnp.arange(T * k, dtype=jnp.int32) - start_of[sorted_eid]
    keep = rank < C
    # slot table (E, C): original replica index, or T*k (drop sentinel)
    dest = jnp.where(keep, sorted_eid * C + rank, E * C)  # E*C is OOB -> drop
    slot = jnp.full((E * C,), T * k, jnp.int32)
    slot = slot.at[dest].set(order, mode="drop").reshape(E, C)
    xpad = jnp.concatenate([xf, jnp.zeros((1,) + xf.shape[1:], xf.dtype)], 0)
    tok_idx = jnp.where(slot < T * k, slot // k, T)            # T = pad row
    xg = cm.constrain(xpad[tok_idx], expert_sharding)          # (E, C, d)
    h = jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
    h = _act(h, gated)
    yg = cm.constrain(jnp.einsum("ecf,efd->ecd", h, p["w_down"]),
                      expert_sharding)                         # (E, C, d)
    # combine back by INVERSE GATHER (each token-replica reads its slot row)
    # — a scatter-add here materializes a replicated (T, d) f32 buffer and
    # an all-reduce over it per layer (~2.3 TB/step on arctic prefill_32k)
    slot_of = jnp.full((T * k,), E * C, jnp.int32).at[order].set(
        jnp.where(keep, dest, E * C))                          # (T*k,)
    ygpad = jnp.concatenate(
        [yg.reshape(E * C, -1),
         jnp.zeros((1, yg.shape[-1]), yg.dtype)], axis=0)
    ys = cm.constrain(ygpad[slot_of], out_sharding)            # (T*k, d)
    out = jnp.einsum("tkd,tk->td", ys.reshape(T, k, -1).astype(jnp.float32),
                     gate_vals)
    return cm.constrain(out, out_sharding)


def _shard_map_path(p, xf, m, gated: bool, capacity_factor: float, mesh):
    """Shard-local EP dispatch (SSPerf iteration 4, the fix that held).

    Everything is LOCAL: each data shard routes its own tokens and runs
    them through the model-sharded experts it co-hosts; the only
    collective is a psum of the (T_local, d) combine over "model"
    (~30 MB/layer vs ~65 GB/layer of f32 masked all-reduces that GSPMD
    emits for cross-shard dispatch gathers)."""
    E, k = m.n_experts, m.top_k
    ms = mesh.shape.get("model", 1)
    E_loc = E // ms
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape) or None

    def body(xf_l, router_w, w_up_l, w_down_l):
        T_l, d = xf_l.shape
        C = max(int(T_l * k * capacity_factor / E), 1)
        logits = xf_l.astype(jnp.float32) @ router_w          # (T_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        flat = expert_ids.reshape(-1)
        order = jnp.argsort(flat)
        sorted_eid = flat[order]
        start_of = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            jnp.cumsum(jnp.bincount(flat, length=E)).astype(jnp.int32)[:-1]])
        rank = jnp.arange(T_l * k, dtype=jnp.int32) - start_of[sorted_eid]
        keep = rank < C
        dest = jnp.where(keep, sorted_eid * C + rank, E * C)
        slot = jnp.full((E * C,), T_l * k, jnp.int32)
        slot = slot.at[dest].set(order, mode="drop")
        i = jax.lax.axis_index("model")
        slot_loc = jax.lax.dynamic_slice_in_dim(
            slot, i * E_loc * C, E_loc * C).reshape(E_loc, C)
        xpad = jnp.concatenate(
            [xf_l, jnp.zeros((1, d), xf_l.dtype)], axis=0)
        tok_idx = jnp.where(slot_loc < T_l * k, slot_loc // k, T_l)
        xg = xpad[tok_idx]                                    # (E_loc, C, d)
        h = jnp.einsum("ecd,edf->ecf", xg, w_up_l)
        h = _act(h, gated)
        yg = jnp.einsum("ecf,efd->ecd", h, w_down_l)          # (E_loc, C, d)
        # local inverse-gather combine
        slot_of = jnp.full((T_l * k,), E * C, jnp.int32).at[order].set(
            jnp.where(keep, dest, E * C))
        e_of = slot_of // C
        local = (e_of >= i * E_loc) & (e_of < (i + 1) * E_loc)
        loc_idx = jnp.where(local, slot_of - i * E_loc * C, E_loc * C)
        ygpad = jnp.concatenate(
            [yg.reshape(E_loc * C, d),
             jnp.zeros((1, d), yg.dtype)], axis=0)
        ys = ygpad[jnp.minimum(loc_idx, E_loc * C)]
        ys = jnp.where(local[:, None], ys, 0)
        out = jnp.einsum("tkd,tk->td",
                         ys.reshape(T_l, k, d).astype(jnp.float32),
                         gate_vals)
        out = jax.lax.psum(out, "model")
        # aux stats (replicated over model; psum-free)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,)).at[flat].add(1.0) / (T_l * k)
        lb = E * jnp.sum(me * ce)
        rz = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        return out, lb, rz

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(ba, None),
                  jax.sharding.PartitionSpec(None, None),
                  jax.sharding.PartitionSpec("model", None, None),
                  jax.sharding.PartitionSpec("model", None, None)),
        out_specs=(jax.sharding.PartitionSpec(ba, None),
                   jax.sharding.PartitionSpec(),
                   jax.sharding.PartitionSpec()),
        **{_CHECK_KW: False})
    return fn(xf, p["router"]["w"], p["w_up"], p["w_down"])


def moe_ffn(p, x, cfg: ArchConfig, *, impl: str = "capacity",
            capacity_factor: float = 1.25, expert_sharding=None,
            out_sharding=None, shard_map_mesh=None):
    """x: (B, S, d) -> (B, S, d), plus aux-loss dict."""
    m = cfg.moe
    B, S, d = x.shape
    T, k = B * S, m.top_k
    xf = x.reshape(T, d)

    logits = cm.dense(p["router"], xf.astype(jnp.float32))      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if impl == "shard_map" and shard_map_mesh is not None:
        out, lb, rz = _shard_map_path(p, xf, m, cfg.gated_mlp,
                                      capacity_factor, shard_map_mesh)
        out = out.astype(x.dtype)
        if m.n_shared:
            out = out + dense_ffn(p["shared"], xf, cfg.gated_mlp)
        if m.dense_residual:
            out = out + dense_ffn(p["residual"], xf, cfg.gated_mlp)
        return out.reshape(B, S, d), {"load_balance": lb, "router_z": rz}
    if impl == "ragged":
        out = _ragged_path(p, xf, expert_ids, gate_vals, m, cfg.gated_mlp)
    else:
        out = _capacity_path(p, xf, expert_ids, gate_vals, m, cfg.gated_mlp,
                             capacity_factor, expert_sharding, out_sharding)
    out = out.astype(x.dtype)

    if m.n_shared:
        out = out + dense_ffn(p["shared"], xf, cfg.gated_mlp)
    if m.dense_residual:
        out = out + dense_ffn(p["residual"], xf, cfg.gated_mlp)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((m.n_experts,)).at[expert_ids.reshape(-1)].add(
        1.0) / (T * k)
    aux = {"load_balance": m.n_experts * jnp.sum(me * ce),
           "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)}
    return out.reshape(B, S, d), aux
