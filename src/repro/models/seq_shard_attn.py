"""Sequence-parallel decode attention via shard_map (beyond-paper SSPerf fix).

Problem: a decode step writes one token into a LENGTH-sharded KV cache.
GSPMD cannot scatter across the sharded dim with a traced index and falls
back to "involuntary full rematerialization": it all-gathers the whole
per-layer cache every step (~150 GiB/step on command-r-plus decode_32k).

Fix: do the update + attention manually under shard_map over the "model"
axis. Each shard owns a contiguous KV range: the new token is written
locally by exactly one shard; scores are computed against the local range
only; the softmax is combined with two tiny collectives (pmax of the block
max, psum of the normalizer and weighted values). Per-step collective
traffic drops from O(cache bytes) to O(B * H * dh).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.jax_compat import SHARD_MAP_CHECK_KW as _CHECK_KW
from repro.jax_compat import shard_map as _shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _local_attn_update(q, k_new, v_new, ck, cv, pos, *, axis: str,
                       scale: float, softcap: float, window: int = 0):
    """Per-shard body. q: (B,1,H,dh) replicated; k/v_new: (B,1,Hkv,dh)
    replicated; ck/cv: (B, Lloc, Hkv, dh) local shard of the cache."""
    B, _, H, dh = q.shape
    Lloc, Hkv = ck.shape[1], ck.shape[2]
    i = jax.lax.axis_index(axis)
    base = i * Lloc
    # ---- local write (exactly one shard is in range) ----
    idx = pos - base
    in_range = (idx >= 0) & (idx < Lloc)
    safe = jnp.clip(idx, 0, Lloc - 1)
    ck_w = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype),
                                        (0, safe, 0, 0))
    cv_w = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype),
                                        (0, safe, 0, 0))
    ck = jnp.where(in_range, ck_w, ck)
    cv = jnp.where(in_range, cv_w, cv)
    # ---- local scores ----
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, dh)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, ck,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = base + jnp.arange(Lloc)
    valid = kpos <= pos
    if window:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    # ---- distributed online softmax ----
    m_loc = s.max(axis=-1)                               # (B,Hkv,g)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_glob[..., None]))
    l_loc = p.sum(axis=-1)
    o_loc = jnp.einsum("bhgl,blhd->bhgd", p.astype(cv.dtype), cv,
                       preferred_element_type=jnp.float32)
    l_glob = jax.lax.psum(l_loc, axis)
    o_glob = jax.lax.psum(o_loc, axis)
    out = (o_glob / jnp.maximum(l_glob, 1e-30)[..., None])
    return out.reshape(B, 1, H, dh).astype(q.dtype), ck, cv


def decode_attn_seq_sharded(q, k_new, v_new, ck, cv, pos, mesh, *,
                            axis: str = "model", scale: Optional[float] = None,
                            softcap: float = 0.0, window: int = 0):
    """shard_map wrapper. Cache sharded P(None, axis, None, None); q and
    the new KV replicated over ``axis`` (few MB). Returns (out, ck, cv)."""
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    body = partial(_local_attn_update, axis=axis, scale=scale,
                   softcap=softcap, window=window)
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    rep4 = P(ba, None, None, None)
    cache_spec = P(ba, axis, None, None)
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P()),
        out_specs=(rep4, cache_spec, cache_spec),
        **{_CHECK_KW: False})
    return fn(q, k_new, v_new, ck, cv, pos)
