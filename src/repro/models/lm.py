"""Unified decoder-only LM covering dense/GQA, MLA, MoE, local:global and
prefix-LM architectures — pure JAX, layer stacks executed with ``lax.scan``
(identical-shape layers are stacked; shape-divergent prefix layers, e.g.
DeepSeek-V2's first dense layer, run unscanned).

Public surface (used by launch/serving/tests):
    init_params(cfg, key, opts)          -> params pytree
    forward(cfg, params, tokens, opts[, prefix_emb])   -> logits
    train_loss(cfg, params, batch, opts) -> (loss, metrics)
    init_cache(cfg, batch, max_len, opts)-> cache pytree
    prefill(cfg, params, tokens, cache, opts[, prefix_emb]) -> (logits, cache)
    decode_step(cfg, params, token, pos, cache, opts)  -> (logits, cache)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import sampling as sampling_mod


@dataclass(frozen=True)
class RuntimeOptions:
    dtype: str = "bfloat16"
    attn_impl: str = "xla"          # xla | pallas
    moe_impl: str = "capacity"      # capacity | ragged
    remat: str = "none"             # none | block  (activation checkpointing)
    cache_dtype: str = ""           # "" -> same as dtype; "int8" -> quantized
    capacity_factor: float = 1.25
    # flash-scan attention tiling knobs (hillclimb levers; SSPerf)
    block_q: int = 512
    block_kv: int = 1024
    flash_acc: str = "float32"      # "bfloat16" halves carry HBM traffic
    # NamedSharding for the (B, S, d) residual stream. Without an explicit
    # constraint GSPMD propagation can drop the batch sharding entirely
    # (observed: batch replicated, d_model model-sharded => 16x activation
    # memory and redundant compute). Set by the launcher; None in tests.
    residual_sharding: object = None
    # MoE dispatch shardings (SSPerf): expert-major (E, C, d) tensors on
    # "model" (EP all-to-all) and the combine buffer back on the batch axes
    # (kills the replicated (T, d) f32 all-reduce, ~2.3 TB/step on arctic)
    moe_expert_sharding: object = None
    moe_out_sharding: object = None
    # ZeRO-3 per-layer weight gathering (SSPerf iteration 3): tuple of
    # (param path suffix, NamedSharding-without-data-axes); applied to the
    # layer slice inside the scan body
    zero3_gather: tuple = ()
    # sequence-parallel decode attention (SSPerf iteration 2): manual
    # shard_map update+attend for LENGTH-sharded caches — avoids GSPMD's
    # full-cache all-gather on every decode step
    seq_shard_attn: bool = False
    seq_shard_mesh: object = None
    # shard-local EP MoE dispatch (SSPerf iteration 4)
    moe_shard_map_mesh: object = None
    # head-sharded paged serving (DESIGN.md SS16): a jax Mesh with a
    # "model" axis partitions the paged KV pool's KV-head dim; the paged
    # attend runs per shard under shard_map and all-gathers head outputs
    # (bitwise identical to single-device). None: replicated paged path.
    kv_shard_mesh: object = None

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


# ------------------------------ layers -------------------------------- #

def _init_attn(key, cfg: ArchConfig, dtype):
    H, Hkv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    b = cfg.qkv_bias
    return {
        "wq": cm.dense_init(ks[0], d, H * hd, dtype, bias=b),
        "wk": cm.dense_init(ks[1], d, Hkv * hd, dtype, bias=b),
        "wv": cm.dense_init(ks[2], d, Hkv * hd, dtype, bias=b),
        "wo": cm.dense_init(ks[3], H * hd, d, dtype),
    }


def _init_layer(key, cfg: ArchConfig, dtype, *, is_moe: bool, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.mla is not None:
        p["attn"] = mla_mod.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = _init_attn(k1, cfg, dtype)
    if is_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = moe_mod.init_dense_ffn(k3, cfg, d_ff, dtype)
    return p


def _layer_split(cfg: ArchConfig) -> Tuple[int, bool]:
    """(n_unscanned_prefix_layers, stack_is_moe)."""
    if cfg.moe is not None and cfg.moe.first_dense:
        return cfg.moe.first_dense, True
    return 0, cfg.moe is not None


def _kind_array(cfg: ArchConfig, start: int, n: int):
    """Per-layer attention kind: 0=global/causal, 1=local/sliding."""
    kinds = [1 if cfg.attention_kind(start + i) == "local" else 0
             for i in range(n)]
    return jnp.asarray(kinds, jnp.int32)


def init_params(cfg: ArchConfig, key, opts: RuntimeOptions = RuntimeOptions()):
    dtype = opts.jdtype
    n_pre, stack_moe = _layer_split(cfg)
    n_stack = cfg.n_layers - n_pre
    k_emb, k_pre, k_stack, k_out = jax.random.split(key, 4)
    params = {"embed": cm.embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
              "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if n_pre:
        dff = cfg.moe.d_ff_dense or cfg.d_ff
        params["head_layers"] = [
            _init_layer(k, cfg, dtype, is_moe=False, d_ff=dff)
            for k in jax.random.split(k_pre, n_pre)]
    params["stack"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype, is_moe=stack_moe, d_ff=cfg.d_ff)
    )(jax.random.split(k_stack, n_stack))
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_out, cfg.d_model, cfg.vocab,
                                          dtype, scale=cfg.d_model ** -0.5)
    return params


# ----------------------------- forward -------------------------------- #

def _attn_apply(p, x, cfg: ArchConfig, opts: RuntimeOptions, *, kind,
                positions, mask_kind: str, prefix_len: int):
    """Full-sequence attention (train/prefill). Returns out and (k, v)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = cm.dense(p["wq"], x).reshape(B, S, H, hd)
    k = cm.dense(p["wk"], x).reshape(B, S, Hkv, hd)
    v = cm.dense(p["wv"], x).reshape(B, S, Hkv, hd)
    q = cm.apply_rope(q, positions)
    k = cm.apply_rope(k, positions)

    def run(mk, window):
        return cm.attention(q, k, v, mask_kind=mk, window=window,
                            prefix_len=prefix_len, softcap=cfg.logit_softcap,
                            impl=opts.attn_impl, block_q=opts.block_q,
                            block_kv=opts.block_kv, acc_dtype=opts.flash_acc)
    if cfg.sliding_window and cfg.local_global_ratio:
        # kind is traced (scanned layer): both branches built once in HLO
        out = jax.lax.cond(
            kind == 1,
            lambda: run("sliding", cfg.sliding_window),
            lambda: run(mask_kind, 0))
    elif cfg.sliding_window:
        out = run("sliding", cfg.sliding_window)
    else:
        out = run(mask_kind, 0)
    out = cm.dense(p["wo"], out.reshape(B, S, H * hd))
    return out, (k, v)


def _ffn_apply(p, x, cfg: ArchConfig, opts: RuntimeOptions):
    if "moe" in p:
        y, aux = moe_mod.moe_ffn(p["moe"], x, cfg, impl=opts.moe_impl,
                                 capacity_factor=opts.capacity_factor,
                                 expert_sharding=opts.moe_expert_sharding,
                                 out_sharding=opts.moe_out_sharding,
                                 shard_map_mesh=opts.moe_shard_map_mesh)
        return y, aux
    return moe_mod.dense_ffn(p["mlp"], x, cfg.gated_mlp), {}


def _block(p, x, cfg, opts, *, kind, positions, mask_kind, prefix_len):
    x = cm.constrain(x, opts.residual_sharding)
    p = cm.constrain_tree(p, opts.zero3_gather)
    if cfg.mla is not None:
        h, kv = mla_mod.mla_prefill_attn(p["attn"], cm.rms_norm(x, p["ln1"]),
                                         cfg, positions, impl=opts.attn_impl)
    else:
        h, kv = _attn_apply(p["attn"], cm.rms_norm(x, p["ln1"]), cfg, opts,
                            kind=kind, positions=positions,
                            mask_kind=mask_kind, prefix_len=prefix_len)
    x = x + h
    h, aux = _ffn_apply(p, cm.rms_norm(x, p["ln2"]), cfg, opts)
    return x + h, kv, aux


def _logits(cfg, params, x):
    x = cm.rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        return x @ params["embed"]["emb"].T
    return cm.dense(params["lm_head"], x)


def _embed_tokens(cfg, params, tokens, prefix_emb):
    x = params["embed"]["emb"][tokens]
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-style scale
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ArchConfig, params, tokens, opts: RuntimeOptions = RuntimeOptions(),
            prefix_emb=None, *, collect_kv: bool = False,
            return_hidden: bool = False):
    """Full-sequence forward. tokens: (B, S) int32.

    prefix_emb: (B, P, d) stub frontend output (VLM patches), prepended.
    Returns (logits, aux) or (logits, aux, kvs) when collect_kv."""
    B = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens, prefix_emb)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask_kind = ("prefix" if (cfg.prefix_bidirectional and cfg.prefix_len)
                 else "causal")
    prefix_len = cfg.prefix_len if cfg.prefix_bidirectional else 0
    n_pre, _ = _layer_split(cfg)
    aux_sum = {"load_balance": 0.0, "router_z": 0.0}
    kvs = []

    for lp in params.get("head_layers", []):
        x, kv, aux = _block(lp, x, cfg, opts, kind=jnp.int32(0),
                            positions=positions, mask_kind=mask_kind,
                            prefix_len=prefix_len)
        kvs.append(kv)
        for k2 in aux:
            aux_sum[k2] = aux_sum.get(k2, 0.0) + aux[k2]

    kinds = _kind_array(cfg, n_pre, cfg.n_layers - n_pre)

    def scan_body(carry, xs):
        lp, kind = xs
        h, kv, aux = _block(lp, carry, cfg, opts, kind=kind,
                            positions=positions, mask_kind=mask_kind,
                            prefix_len=prefix_len)
        outs = (kv, aux) if collect_kv else (None, aux)
        return h, outs

    body = scan_body
    if opts.remat == "block":
        body = jax.checkpoint(scan_body)
    x, (kv_stack, aux_stack) = jax.lax.scan(body, x, (params["stack"], kinds))
    for k2 in aux_sum:
        if aux_stack and k2 in aux_stack:
            aux_sum[k2] = aux_sum[k2] + jnp.sum(aux_stack[k2])
    if return_hidden:
        return cm.rms_norm(x, params["final_norm"]), aux_sum
    logits = _logits(cfg, params, x)
    if collect_kv:
        return logits, aux_sum, (kvs, kv_stack)
    return logits, aux_sum


def train_loss(cfg: ArchConfig, params, batch: Dict, opts=RuntimeOptions()):
    """batch: {"tokens": (B,S), "labels": (B,S)} (+"prefix_emb" for VLM).

    Uses chunked cross-entropy: (B,S,vocab) logits never materialize."""
    h, aux = forward(cfg, params, batch["tokens"], opts,
                     prefix_emb=batch.get("prefix_emb"), return_hidden=True)
    labels = batch["labels"]
    Pfx = (batch["prefix_emb"].shape[1]
           if batch.get("prefix_emb") is not None else 0)
    S = labels.shape[1]
    h_pred = h[:, Pfx:Pfx + S - 1]
    if cfg.tie_embeddings:
        loss = cm.chunked_xent(h_pred, params["embed"]["emb"],
                               labels[:, 1:], tied=True)
    else:
        loss = cm.chunked_xent(h_pred, params["lm_head"]["w"],
                               labels[:, 1:], tied=False)
    total = loss
    if cfg.moe is not None:
        total = total + 0.01 * aux["load_balance"] + 1e-4 * aux["router_z"]
    return total, {"nll": loss, **{k: jnp.asarray(v) for k, v in aux.items()}}


# ------------------------------ serving ------------------------------- #

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               opts: RuntimeOptions = RuntimeOptions()):
    """KV cache pytree. ``opts.cache_dtype='int8'`` enables the tiered-KV
    policy: int8 cache + per-(layer, kv-head) scales — the paper's
    "shrink the Q/K/V traffic class" realized as a bandwidth/capacity
    reduction (DESIGN.md SS3). MLA archs already compress the cache."""
    quant = opts.cache_dtype == "int8" and cfg.mla is None
    dtype = (jnp.int8 if quant else
             (jnp.dtype(opts.cache_dtype) if opts.cache_dtype else opts.jdtype))
    n_pre, _ = _layer_split(cfg)
    n_stack = cfg.n_layers - n_pre

    def one(_):
        if cfg.mla is not None:
            return mla_mod.init_mla_cache(cfg, batch, max_len, opts.jdtype)
        c = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                            dtype),
             "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                            dtype)}
        if quant:
            c["k_scale"] = jnp.ones((cfg.n_kv_heads,), jnp.float32)
            c["v_scale"] = jnp.ones((cfg.n_kv_heads,), jnp.float32)
        return c
    cache = {"stack": jax.vmap(one)(jnp.arange(n_stack))}
    if n_pre:
        cache["head"] = [one(None) for _ in range(n_pre)]
    return cache


def _decode_attn(p, x, cfg, opts, cache_layer, pos, *, kind):
    """Single-token attention against the cache. x: (B,1,d)."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1))
    q = cm.dense(p["wq"], x).reshape(B, 1, H, hd)
    k = cm.dense(p["wk"], x).reshape(B, 1, Hkv, hd)
    v = cm.dense(p["wv"], x).reshape(B, 1, Hkv, hd)
    q = cm.apply_rope(q, positions)
    k = cm.apply_rope(k, positions)
    quant = "k_scale" in cache_layer
    if opts.seq_shard_attn and not quant:
        from repro.models.seq_shard_attn import decode_attn_seq_sharded

        def seq_att(window):
            return decode_attn_seq_sharded(
                q, k, v, cache_layer["k"], cache_layer["v"], pos,
                opts.seq_shard_mesh, scale=hd ** -0.5,
                softcap=cfg.logit_softcap, window=window)
        if cfg.sliding_window and cfg.local_global_ratio:
            out, ck, cv = jax.lax.cond(
                kind == 1, lambda: seq_att(cfg.sliding_window),
                lambda: seq_att(0))
        elif cfg.sliding_window:
            out, ck, cv = seq_att(cfg.sliding_window)
        else:
            out, ck, cv = seq_att(0)
        out = cm.dense(p["wo"], out.reshape(B, 1, H * hd))
        return out, {"k": ck, "v": cv}
    if quant:
        # quantize the new entries with the prefill scales (tiered policy)
        ksc, vsc = cache_layer["k_scale"], cache_layer["v_scale"]
        kq = _quantize_with(k, ksc)
        vq = _quantize_with(v, vsc)
        ck, cv = cm.update_cache(cache_layer["k"], cache_layer["v"],
                                 kq, vq, pos)
        ck_f = ck.astype(q.dtype) * ksc[None, None, :, None].astype(q.dtype)
        cv_f = cv.astype(q.dtype) * vsc[None, None, :, None].astype(q.dtype)
    else:
        ck, cv = cm.update_cache(cache_layer["k"], cache_layer["v"], k, v,
                                 pos)
        ck_f, cv_f = ck.astype(q.dtype), cv.astype(q.dtype)

    def att(mk, w):
        return cm.attention(q, ck_f, cv_f,
                            mask_kind=mk, window=w, q_offset=pos,
                            softcap=cfg.logit_softcap, impl=opts.attn_impl,
                            block_q=opts.block_q, block_kv=opts.block_kv,
                            acc_dtype=opts.flash_acc)
    if cfg.sliding_window and cfg.local_global_ratio:
        out = jax.lax.cond(kind == 1,
                           lambda: att("sliding", cfg.sliding_window),
                           lambda: att("causal", 0))
    elif cfg.sliding_window:
        out = att("sliding", cfg.sliding_window)
    else:
        out = att("causal", 0)
    out = cm.dense(p["wo"], out.reshape(B, 1, H * hd))
    new_cache = {"k": ck, "v": cv}
    if quant:
        new_cache["k_scale"] = cache_layer["k_scale"]
        new_cache["v_scale"] = cache_layer["v_scale"]
    return out, new_cache


def _decode_block(lp, x, cfg, opts, cache_layer, pos, *, kind):
    x = cm.constrain(x, opts.residual_sharding)
    if cfg.mla is not None:
        h, new_cache = mla_mod.mla_decode_attn(
            lp["attn"], cm.rms_norm(x, lp["ln1"]), cfg, cache_layer, pos)
    else:
        h, new_cache = _decode_attn(lp["attn"], cm.rms_norm(x, lp["ln1"]),
                                    cfg, opts, cache_layer, pos, kind=kind)
    x = x + h
    h, _ = _ffn_apply(lp, cm.rms_norm(x, lp["ln2"]), cfg, opts)
    return x + h, new_cache


def decode_step(cfg: ArchConfig, params, token, pos, cache,
                opts: RuntimeOptions = RuntimeOptions()):
    """One new token for every sequence. token: (B,) int32; pos: scalar."""
    x = _embed_tokens(cfg, params, token[:, None], None)
    n_pre, _ = _layer_split(cfg)
    new_head = []
    for lp, cl in zip(params.get("head_layers", []), cache.get("head", [])):
        x, nc = _decode_block(lp, x, cfg, opts, cl, pos, kind=jnp.int32(0))
        new_head.append(nc)
    kinds = _kind_array(cfg, n_pre, cfg.n_layers - n_pre)

    def scan_body(carry, xs):
        lp, cl, kind = xs
        h, nc = _decode_block(lp, carry, cfg, opts, cl, pos, kind=kind)
        return h, nc
    x, new_stack = jax.lax.scan(scan_body, x,
                                (params["stack"], cache["stack"], kinds))
    logits = _logits(cfg, params, x)[:, 0]
    new_cache = {"stack": new_stack}
    if new_head:
        new_cache["head"] = new_head
    return logits, new_cache


def prefill(cfg: ArchConfig, params, tokens, cache,
            opts: RuntimeOptions = RuntimeOptions(), prefix_emb=None):
    """Run the prompt, fill the cache, return last-position logits."""
    logits, _, (kv_head, kv_stack) = forward(cfg, params, tokens, opts,
                                             prefix_emb=prefix_emb,
                                             collect_kv=True)

    def fill(buf, val):
        return jax.lax.dynamic_update_slice(
            buf, val.astype(buf.dtype), (0,) * buf.ndim)

    if cfg.mla is not None:
        new_stack = {"c": jax.vmap(fill)(cache["stack"]["c"], kv_stack[0]),
                     "k_rope": jax.vmap(fill)(cache["stack"]["k_rope"],
                                              kv_stack[1])}
    elif "k_scale" in cache["stack"]:
        def qfill(buf, val):   # per-layer quantize with fresh scales
            sc = _amax_scale(val, (0, 1, 3))               # (Hkv,)
            return fill(buf, _quantize_with(val, sc)), sc
        ks_new, ksc = jax.vmap(qfill)(cache["stack"]["k"], kv_stack[0])
        vs_new, vsc = jax.vmap(qfill)(cache["stack"]["v"], kv_stack[1])
        new_stack = {"k": ks_new, "v": vs_new, "k_scale": ksc,
                     "v_scale": vsc}
    else:
        new_stack = {"k": jax.vmap(fill)(cache["stack"]["k"], kv_stack[0]),
                     "v": jax.vmap(fill)(cache["stack"]["v"], kv_stack[1])}
    new_cache = {"stack": new_stack}
    if cache.get("head"):
        new_head = []
        for cl, kv in zip(cache["head"], kv_head):
            if cfg.mla is not None:
                new_head.append({"c": fill(cl["c"], kv[0]),
                                 "k_rope": fill(cl["k_rope"], kv[1])})
            else:
                new_head.append({"k": fill(cl["k"], kv[0]),
                                 "v": fill(cl["v"], kv[1])})
        new_cache["head"] = new_head
    return logits[:, -1], new_cache


# --------------------------- paged serving ---------------------------- #
# Page-pool KV cache for continuous batching (DESIGN.md SS10): fixed-size
# pages shared by all sequences, indirected through per-sequence page
# tables. Page 0 is reserved as the null page — padded page-table entries
# and inactive batch slots write/read it harmlessly (reads are masked by
# seq_lens, writes land on garbage nobody consumes).


def paged_supported(cfg: ArchConfig) -> Optional[str]:
    """None when the paged-KV path covers this config; else the skip reason."""
    if cfg.mla is not None:
        return "MLA latent cache is already compressed; paged path covers GQA"
    if cfg.family not in ("dense", "moe"):
        return f"family {cfg.family!r} is not covered by the paged KV path"
    if _layer_split(cfg)[0]:
        return "unscanned prefix layers not supported by the paged cache"
    if cfg.sliding_window:
        return "sliding-window layers need windowed page masking"
    if cfg.enc_layers:
        return "cross-attention caches are not paged"
    return None


def init_paged_cache(cfg: ArchConfig, n_pages: int, page_size: int,
                     opts: RuntimeOptions = RuntimeOptions()):
    """Pooled KV pages: (n_layers, n_pages, page_size, Hkv, dh) per k/v.

    ``opts.cache_dtype='int8'`` stores int8 pages with per-(layer, kv-head)
    scales (statically calibrated at the first prefill — the tiered-KV
    policy of DESIGN.md SS3 applied to the page pool)."""
    reason = paged_supported(cfg)
    if reason:
        raise NotImplementedError(f"paged KV cache: {reason}")
    quant = opts.cache_dtype == "int8"
    dtype = (jnp.int8 if quant else
             (jnp.dtype(opts.cache_dtype) if opts.cache_dtype else opts.jdtype))
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    c = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if quant:
        c["k_scale"] = jnp.ones((cfg.n_layers, cfg.n_kv_heads), jnp.float32)
        c["v_scale"] = jnp.ones((cfg.n_layers, cfg.n_kv_heads), jnp.float32)
    return {"stack": c}


def layer_dma_slices(cfg: ArchConfig) -> int:
    """Natural DMA slice count for layer-overlapped page migration
    (DESIGN.md SS17): the paged pool's leading axis is ``n_layers``, so a
    page's layer-``l`` slice — ``page_bytes / n_layers`` of k+v — is one
    contiguous region per pool array, fetchable as one link of a chained
    DMA descriptor. The layer loop (``lax.scan`` over ``params["stack"]``)
    consumes slices strictly in order, which is what lets the engine
    pipeline slice ``l``'s transfer under layer ``l-1``'s compute."""
    return max(int(cfg.n_layers), 1)


def page_layer_nbytes(cfg: ArchConfig, page_size: int,
                      dtype_bytes: int = 2) -> float:
    """Bytes of ONE layer's k+v slice of a page — the chained-descriptor
    slice granularity used by layer-overlapped migration."""
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
    return float(per_tok * page_size)


def _amax_scale(val, axes):
    """Per-kv-head symmetric int8 scale: amax/127 reduced over ``axes``."""
    return jnp.maximum(jnp.abs(val.astype(jnp.float32)).max(axes),
                       1e-6) / 127.0


def _quantize_with(val, scale):
    """val: (..., Hkv, dh); scale: (..., Hkv) absolute per-head scales."""
    return jnp.clip(jnp.round(val.astype(jnp.float32)
                              / scale[..., :, None]), -127, 127)


def _head_shards(opts: RuntimeOptions, n_kv_heads: int) -> int:
    """Shard count of ``opts.kv_shard_mesh`` when it head-divides, else 0."""
    if opts.kv_shard_mesh is None:
        return 0
    from repro.kernels import sharded as ksh
    return ksh.head_shards(opts.kv_shard_mesh, n_kv_heads)


def _chunk_attend(q, kp, vp, ksc, vsc, page_table, start, n_valid, *,
                  cfg: ArchConfig, opts: RuntimeOptions):
    """Attend a (B, C, H', hd) query chunk over pooled pages.

    Head counts come from the operands, not ``cfg``, so the same body
    serves the replicated pool AND one head shard of it (the per-shard
    body under ``kernels.sharded.sharded_attend``). ``ksc``/``vsc`` are
    the int8 per-head scales matching kp/vp's head slice, or None."""
    B, C, H, hd = q.shape
    Hkv, ps = kp.shape[2], kp.shape[1]
    n_pp = page_table.shape[1]
    quant = ksc is not None
    out = None
    if opts.attn_impl == "pallas" and not cfg.logit_softcap:
        from repro.kernels import ops as kops
        if jnp.ndim(start) == 1:
            # per-sequence window start => speculative-verify entry (SS14)
            out = kops.try_spec_verify_attention(
                q, kp, vp, page_table, start,
                n_valid - jnp.asarray(start, jnp.int32), scale=hd ** -0.5,
                k_scale=ksc, v_scale=vsc)
        else:
            out = kops.try_chunk_prefill_attention(
                q, kp, vp, page_table, start, n_valid, scale=hd ** -0.5,
                k_scale=ksc, v_scale=vsc)
    if out is None:
        # XLA path: gather the pages densely, causal-mask by position
        kd = kp[page_table].reshape(B, n_pp * ps, Hkv, hd)
        vd = vp[page_table].reshape(B, n_pp * ps, Hkv, hd)
        if quant:
            kd = kd.astype(q.dtype) * ksc[None, None, :, None].astype(q.dtype)
            vd = vd.astype(q.dtype) * vsc[None, None, :, None].astype(q.dtype)
        else:
            kd, vd = kd.astype(q.dtype), vd.astype(q.dtype)
        start_v = jnp.asarray(start, jnp.int32)
        if start_v.ndim == 0:
            out = cm.attention(q, kd, vd, mask_kind="causal", q_offset=start,
                               kv_valid=n_valid, softcap=cfg.logit_softcap,
                               impl="xla")
        else:
            # per-sequence window start (speculative verify, SS14):
            # cm.attention's q_offset is scalar-only, so build the (B, C, L)
            # mask explicitly — same numerics as its small path otherwise
            L = n_pp * ps
            group = H // Hkv
            qpos = start_v[:, None] + jnp.arange(C)[None, :]
            qpos = jnp.minimum(qpos, n_valid[:, None] - 1)   # clip pad rows
            m = jnp.arange(L)[None, None, :] <= qpos[:, :, None]
            qg = q.reshape(B, C, Hkv, group, hd)
            s = jnp.einsum("bshgd,blhd->bshgl", qg, kd,
                           preferred_element_type=jnp.float32) * (hd ** -0.5)
            if cfg.logit_softcap:
                s = jnp.tanh(s / cfg.logit_softcap) * cfg.logit_softcap
            s = jnp.where(m[:, :, None, None, :], s, cm.NEG_INF)
            pr = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bshgl,blhd->bshgd", pr.astype(vd.dtype), vd,
                             preferred_element_type=jnp.float32)
            out = out.reshape(B, C, H, hd).astype(q.dtype)
    return out


def prefill_paged(cfg: ArchConfig, params, tokens, cache, page_table,
                  true_len, opts: RuntimeOptions = RuntimeOptions(), *,
                  calibrate: bool = False):
    """Prefill that scatters KV into pool pages instead of a dense buffer.

    tokens: (B, S) right-padded prompts with S a multiple of page_size —
    causal masking keeps pad-token KV from influencing valid positions, and
    decode later masks reads by seq_lens. page_table: (B, S // page_size)
    physical pages owned by each prompt; true_len: (B,) actual prompt
    lengths. ``calibrate=True`` (first prefill only) sets the int8 scales
    from this batch; afterwards writes clip against the frozen scales.

    Returns (logits at position true_len-1 per sequence, new cache)."""
    logits, _, (_, kv_stack) = forward(cfg, params, tokens, opts,
                                       collect_kv=True)
    st = cache["stack"]
    ps = st["k"].shape[2]
    B, S = tokens.shape
    npp = S // ps
    flat_ids = page_table.reshape(-1)                   # (B * npp,)

    def chunked(val):                                   # (L,B,S,Hkv,dh)
        nl = val.shape[0]
        return val.reshape(nl, B * npp, ps, *val.shape[3:])

    if "k_scale" in st:
        if calibrate:
            # pad rows beyond true_len carry garbage KV — keep them out of
            # the frozen per-(layer, head) scales
            pos_ok = (jnp.arange(S)[None] < true_len[:, None]
                      )[None, :, :, None, None]
            ksc = _amax_scale(jnp.where(pos_ok, kv_stack[0], 0), (1, 2, 4))
            vsc = _amax_scale(jnp.where(pos_ok, kv_stack[1], 0), (1, 2, 4))
        else:
            ksc, vsc = st["k_scale"], st["v_scale"]
        kq = _quantize_with(kv_stack[0], ksc[:, None, None])
        vq = _quantize_with(kv_stack[1], vsc[:, None, None])
        new = {"k": st["k"].at[:, flat_ids].set(chunked(kq).astype(jnp.int8)),
               "v": st["v"].at[:, flat_ids].set(chunked(vq).astype(jnp.int8)),
               "k_scale": ksc, "v_scale": vsc}
    else:
        new = {"k": st["k"].at[:, flat_ids].set(
                   chunked(kv_stack[0]).astype(st["k"].dtype)),
               "v": st["v"].at[:, flat_ids].set(
                   chunked(kv_stack[1]).astype(st["v"].dtype))}
    last = jnp.take_along_axis(
        logits, (true_len - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return last, {"stack": new}


def _paged_chunk_attn(p, x, cfg: ArchConfig, opts: RuntimeOptions,
                      cache_layer, positions, page_table, start, n_valid, *,
                      calibrate: bool):
    """Chunk-prefill attention against pooled KV pages. x: (B, C, d).

    Scatters the chunk's KV into the pages covering ``positions`` first,
    then attends causally (by absolute position) across every page the
    sequence owns — previously cached prefix pages included."""
    B, C, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = cm.dense(p["wq"], x).reshape(B, C, H, hd)
    k = cm.dense(p["wk"], x).reshape(B, C, Hkv, hd)
    v = cm.dense(p["wv"], x).reshape(B, C, Hkv, hd)
    q = cm.apply_rope(q, positions)
    k = cm.apply_rope(k, positions)
    quant = "k_scale" in cache_layer
    kp, vp = cache_layer["k"], cache_layer["v"]
    P, ps = kp.shape[0], kp.shape[1]
    n_pp = page_table.shape[1]

    if quant:
        if calibrate:
            # first chunk of the pool's life sets the frozen scales; keep
            # the chunk's right-padding out of them
            ok = (positions < n_valid[:, None])[..., None, None]
            ksc = _amax_scale(jnp.where(ok, k, 0), (0, 1, 3))
            vsc = _amax_scale(jnp.where(ok, v, 0), (0, 1, 3))
        else:
            ksc, vsc = cache_layer["k_scale"], cache_layer["v_scale"]
        k_store = _quantize_with(k, ksc[None, None]).astype(jnp.int8)
        v_store = _quantize_with(v, vsc[None, None]).astype(jnp.int8)
    else:
        ksc = vsc = None
        k_store, v_store = k.astype(kp.dtype), v.astype(vp.dtype)

    # scatter the chunk's KV at absolute positions [start, start + C); pad
    # positions past the reserve land on the null page (entries past the
    # sequence's pages are 0, and positions past the table are clipped to 0
    # explicitly — gather would silently clamp to the LAST entry)
    blk = positions // ps
    pid = jnp.take_along_axis(page_table, jnp.minimum(blk, n_pp - 1), axis=1)
    pid = jnp.where(blk < n_pp, pid, 0)                             # (B, C)
    flat = (pid * ps + positions % ps).reshape(-1)
    kp = (kp.reshape(P * ps, Hkv, hd).at[flat]
          .set(k_store.reshape(B * C, Hkv, hd)).reshape(kp.shape))
    vp = (vp.reshape(P * ps, Hkv, hd).at[flat]
          .set(v_store.reshape(B * C, Hkv, hd)).reshape(vp.shape))

    n_sh = _head_shards(opts, Hkv)
    if n_sh:
        # head-sharded attend (SS16): the pool/q head dims partition over
        # the mesh, the scatter above already ran shard-wise under GSPMD,
        # and the per-shard body below is this very function's replicated
        # path on an Hkv/N slice — bitwise identical after the gather
        from repro.kernels import sharded as ksh

        def attend(q_l, kp_l, vp_l, ks_l, vs_l, pt, st, nv):
            return _chunk_attend(q_l, kp_l, vp_l,
                                 ks_l if quant else None,
                                 vs_l if quant else None,
                                 pt, st, nv, cfg=cfg, opts=opts)
        ones = jnp.ones((Hkv,), jnp.float32)
        out = ksh.sharded_attend(
            opts.kv_shard_mesh, attend, q, kp, vp,
            ksc if quant else ones, vsc if quant else ones,
            (page_table, jnp.asarray(start, jnp.int32), n_valid),
            q_head_axis=2)
    else:
        out = _chunk_attend(q, kp, vp, ksc, vsc, page_table, start,
                            n_valid, cfg=cfg, opts=opts)
    out = cm.dense(p["wo"], out.reshape(B, C, H * hd))
    new_cache = {"k": kp, "v": vp}
    if quant:
        new_cache["k_scale"] = ksc
        new_cache["v_scale"] = vsc
    return out, new_cache


def prefill_paged_chunk(cfg: ArchConfig, params, tokens, cache, page_table,
                        start, n_valid,
                        opts: RuntimeOptions = RuntimeOptions(), *,
                        calibrate: bool = False):
    """One fixed-size prefill chunk against the paged pool (DESIGN.md SS11).

    tokens: (B, C) the chunk's tokens, right-padded; page_table: (B,
    n_pages_per_seq) the sequence's full padded table; start: scalar int32
    absolute position of tokens[:, 0] (earlier positions already hold valid
    KV — from previous chunks or shared prefix pages); n_valid: (B,) total
    valid tokens once this chunk lands (= start + true chunk length).

    The fixed (B, C) shape is the point: every prompt, whatever its length
    or cache hit, prefills through this one compiled program instead of
    compiling per padded prompt length. ``calibrate=True`` (first chunk
    only) sets the int8 scales. Returns (logits (B, C, vocab), new cache).
    """
    B, C = tokens.shape
    x = _embed_tokens(cfg, params, tokens, None)
    start = jnp.asarray(start, jnp.int32)
    positions = jnp.broadcast_to(start + jnp.arange(C)[None, :], (B, C))

    def scan_body(carry, xs):
        lp, cl = xs
        h = cm.constrain(carry, opts.residual_sharding)
        a, nc = _paged_chunk_attn(lp["attn"], cm.rms_norm(h, lp["ln1"]),
                                  cfg, opts, cl, positions, page_table,
                                  start, n_valid, calibrate=calibrate)
        h = h + a
        f, _ = _ffn_apply(lp, cm.rms_norm(h, lp["ln2"]), cfg, opts)
        return h + f, nc
    x, new_stack = jax.lax.scan(scan_body, x, (params["stack"],
                                               cache["stack"]))
    logits = _logits(cfg, params, x)
    return logits, {"stack": new_stack}


def copy_pages(cache, pairs):
    """Apply queued copy-on-write page copies to the pool.

    pairs: (N, 2) int32 (src, dst) physical page ids — the output of
    ``PagedKVManager.drain_copies``. Must run before the next KV write."""
    st = cache["stack"]
    src, dst = pairs[:, 0], pairs[:, 1]
    new = dict(st)
    new["k"] = st["k"].at[:, dst].set(st["k"][:, src])
    new["v"] = st["v"].at[:, dst].set(st["v"][:, src])
    return {"stack": new}


def _decode_attend(q, kp, vp, ksc, vsc, page_table, valid, *,
                   cfg: ArchConfig, opts: RuntimeOptions):
    """Attend a (B, 1, H', hd) single-position query over pooled pages.

    Head counts come from the operands (see ``_chunk_attend``) so the
    body runs unchanged on one head shard of the pool."""
    B, _, H, hd = q.shape
    Hkv, ps = kp.shape[2], kp.shape[1]
    n_pp = page_table.shape[1]
    quant = ksc is not None
    out = None
    if opts.attn_impl == "pallas" and not cfg.logit_softcap:
        from repro.kernels import ops as kops
        out = kops.try_paged_decode_attention(
            q[:, 0], kp, vp, page_table, valid, scale=hd ** -0.5,
            k_scale=ksc, v_scale=vsc)
        if out is not None:
            out = out[:, None]                          # (B, 1, H, hd)
    if out is None:
        # XLA path: gather the sequence's pages densely, mask by seq_lens
        kd = kp[page_table].reshape(B, n_pp * ps, Hkv, hd)
        vd = vp[page_table].reshape(B, n_pp * ps, Hkv, hd)
        if quant:
            kd = kd.astype(q.dtype) * ksc[None, None, :, None].astype(q.dtype)
            vd = vd.astype(q.dtype) * vsc[None, None, :, None].astype(q.dtype)
        else:
            kd, vd = kd.astype(q.dtype), vd.astype(q.dtype)
        out = cm.attention(q, kd, vd, mask_kind="full", kv_valid=valid,
                           softcap=cfg.logit_softcap, impl="xla")
    return out


def _paged_decode_attn(p, x, cfg: ArchConfig, opts: RuntimeOptions,
                       cache_layer, seq_lens, page_table):
    """Single-token attention against pooled KV pages. x: (B, 1, d)."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = seq_lens[:, None]                       # ragged positions
    q = cm.dense(p["wq"], x).reshape(B, 1, H, hd)
    k = cm.dense(p["wk"], x).reshape(B, 1, Hkv, hd)
    v = cm.dense(p["wv"], x).reshape(B, 1, Hkv, hd)
    q = cm.apply_rope(q, positions)
    k = cm.apply_rope(k, positions)
    quant = "k_scale" in cache_layer
    kp, vp = cache_layer["k"], cache_layer["v"]
    P, ps = kp.shape[0], kp.shape[1]

    if quant:
        ksc, vsc = cache_layer["k_scale"], cache_layer["v_scale"]
        k_store = _quantize_with(k[:, 0], ksc[None]).astype(jnp.int8)
        v_store = _quantize_with(v[:, 0], vsc[None]).astype(jnp.int8)
    else:
        ksc = vsc = None
        k_store, v_store = k[:, 0].astype(kp.dtype), v[:, 0].astype(vp.dtype)

    # write the new token's KV at (page_table[b, len//ps], len % ps); the
    # flat index collapses to the null page for inactive slots (pt == 0)
    pid = jnp.take_along_axis(page_table, (seq_lens // ps)[:, None],
                              axis=1)[:, 0]
    flat = pid * ps + seq_lens % ps                     # (B,)
    kp = kp.reshape(P * ps, Hkv, hd).at[flat].set(k_store).reshape(kp.shape)
    vp = vp.reshape(P * ps, Hkv, hd).at[flat].set(v_store).reshape(vp.shape)
    valid = seq_lens + 1

    n_sh = _head_shards(opts, Hkv)
    if n_sh:
        from repro.kernels import sharded as ksh

        def attend(q_l, kp_l, vp_l, ks_l, vs_l, pt, vl):
            return _decode_attend(q_l, kp_l, vp_l,
                                  ks_l if quant else None,
                                  vs_l if quant else None,
                                  pt, vl, cfg=cfg, opts=opts)
        ones = jnp.ones((Hkv,), jnp.float32)
        out = ksh.sharded_attend(
            opts.kv_shard_mesh, attend, q, kp, vp,
            ksc if quant else ones, vsc if quant else ones,
            (page_table, valid), q_head_axis=2)
    else:
        out = _decode_attend(q, kp, vp, ksc, vsc, page_table, valid,
                             cfg=cfg, opts=opts)
    out = cm.dense(p["wo"], out.reshape(B, 1, H * hd))
    new_cache = {"k": kp, "v": vp}
    if quant:
        new_cache["k_scale"] = cache_layer["k_scale"]
        new_cache["v_scale"] = cache_layer["v_scale"]
    return out, new_cache


def decode_step_paged(cfg: ArchConfig, params, token, seq_lens, page_table,
                      cache, opts: RuntimeOptions = RuntimeOptions()):
    """One ragged decode step over the paged pool.

    token: (B,) int32 last sampled token per slot; seq_lens: (B,) tokens
    already cached (the new token lands at this position); page_table:
    (B, n_pages_per_seq). Inactive slots (page_table rows all zero,
    seq_len 0) write to the null page and produce ignorable logits.
    Returns (logits (B, V), new cache)."""
    x = _embed_tokens(cfg, params, token[:, None], None)

    def scan_body(carry, xs):
        lp, cl = xs
        h = cm.constrain(carry, opts.residual_sharding)
        a, nc = _paged_decode_attn(lp["attn"], cm.rms_norm(h, lp["ln1"]),
                                   cfg, opts, cl, seq_lens, page_table)
        h = h + a
        f, _ = _ffn_apply(lp, cm.rms_norm(h, lp["ln2"]), cfg, opts)
        return h + f, nc
    x, new_stack = jax.lax.scan(scan_body, x, (params["stack"], cache["stack"]))
    logits = _logits(cfg, params, x)[:, 0]
    return logits, {"stack": new_stack}


# ------------------------ fused multi-step decode ---------------------- #
# DESIGN.md SS12: the decode hot loop pays one host round-trip per token
# when sampling happens on the host. The fused path scans K micro-steps on
# device — sample (greedy or stochastic from carried per-slot keys), write
# KV, advance lengths, latch an EOS/budget done-mask — and hands the host
# a (B, K) token block per sync.


def sample_greedy(logits, temperature: float = 0.0):
    """Back-compat shim over ``repro.models.sampling`` (the real home of
    on-device token choice since SS14). Greedy argmax matches ``np.argmax``
    exactly (both take the first maximum), which is what keeps the fused
    path token-identical to the host-sampled loop. Stochastic sampling
    needs a per-slot PRNG key — use ``sampling.sample(logits, keys, ...)``
    (threaded through the fused scan by ``decode_steps_paged(keys=...)``)."""
    if temperature != 0.0:
        raise ValueError(
            "sample_greedy is greedy-only; stochastic sampling lives in "
            "repro.models.sampling.sample and needs per-slot PRNG keys")
    return sampling_mod.sample_greedy(logits)


def decode_steps_paged(cfg: ArchConfig, params, tokens, seq_lens, page_table,
                       cache, n_steps: int,
                       opts: RuntimeOptions = RuntimeOptions(), *,
                       eos_id: Optional[int] = None, pad_id: int = 0,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0, keys=None, done=None, quota=None):
    """Fused K-step decode over the paged pool (DESIGN.md SS12).

    ``jax.lax.scan`` over ``n_steps`` micro-steps: each step writes the
    carried token's KV at its slot's current length, attends, samples the
    next token on device, and advances per-slot lengths — no host sync
    until the whole (B, n_steps) block is pulled. Every KV position the
    scan writes must be page-backed up front (``PagedKVManager.
    reserve_ahead``): the scan cannot allocate.

    tokens: (B,) last sampled token per slot (its KV is written by the
    first micro-step); seq_lens: (B,) tokens whose KV already landed;
    done: (B,) bool slots that start inactive (their page-table rows are
    masked to the null page, they emit ``pad_id``); quota: (B,) int32 max
    tokens each slot may emit this block (default ``n_steps``) — the
    device-side mirror of each request's remaining budget. A slot latches
    done after emitting EOS (``eos_id``) or exhausting its quota; latched
    slots stop advancing lengths and their writes land on the null page.

    Sampling: greedy argmax at ``temperature<=0``; otherwise
    temperature/top-k/top-p from ``keys`` — (B, 2) uint32 per-slot PRNG
    keys threaded through the scan carry (each micro-step splits its slot
    key, consuming one stream element per emitted token, so a request's
    randomness depends only on its own key lineage, never on batch
    composition). When ``keys`` is given the return is a 3-tuple
    ``(tokens, cache, advanced_keys)``; the caller must carry the
    advanced keys into the next block.

    With ``n_steps=1`` this is exactly ``decode_step_paged`` + host
    sampling (the K=1 engine equivalence guarantee). Returns ((B, n_steps)
    int32 token block, new cache[, advanced keys])."""
    B = tokens.shape[0]
    if temperature > 0.0 and keys is None:
        raise ValueError("stochastic fused decode needs per-slot PRNG keys "
                         "(keys=(B, 2) uint32)")
    if done is None:
        done = jnp.zeros((B,), bool)
    if quota is None:
        quota = jnp.full((B,), n_steps, jnp.int32)
    quota = jnp.asarray(quota, jnp.int32)
    stochastic = keys is not None and temperature > 0.0

    def micro_step(carry, _):
        tok, lens, dn, n_emit, ks, c = carry
        # latched slots write into (and read from) the null page only
        pt = jnp.where(dn[:, None], 0, page_table)
        logits, c = decode_step_paged(cfg, params, tok, lens, pt, c, opts)
        if stochastic:
            sub = sampling_mod.split_keys(ks, 2)          # (B, 2, 2)
            step_keys, ks = sub[:, 0], sub[:, 1]
            chosen = sampling_mod.sample(logits, step_keys,
                                         temperature=temperature,
                                         top_k=top_k, top_p=top_p)
        else:
            chosen = sampling_mod.sample_greedy(logits)
        nxt = jnp.where(dn, jnp.int32(pad_id), chosen)
        n_emit = n_emit + jnp.where(dn, 0, 1)
        new_dn = dn | (n_emit >= quota)
        if eos_id is not None:
            new_dn = new_dn | (~dn & (nxt == eos_id))
        lens = jnp.where(dn, lens, lens + 1)   # this step's write landed
        return (nxt, lens, new_dn, n_emit, ks, c), nxt

    init_keys = (jnp.asarray(keys, jnp.uint32) if keys is not None
                 else jnp.zeros((B, 2), jnp.uint32))
    init = (jnp.asarray(tokens, jnp.int32), jnp.asarray(seq_lens, jnp.int32),
            done, jnp.zeros((B,), jnp.int32), init_keys, cache)
    (_, _, _, _, out_keys, cache), toks = jax.lax.scan(micro_step, init, None,
                                                       length=n_steps)
    toks = jnp.moveaxis(toks, 0, 1)
    if keys is not None:
        return toks, cache, out_keys
    return toks, cache


# ------------------------- speculative decoding ------------------------ #
# DESIGN.md SS14: a draft (n-gram lookup or a small model) proposes up to
# K tokens; ONE paged multi-query verify pass scores the whole window
# against the target model; leftover/rejection sampling keeps the output
# distribution exactly the target's. Every accepted draft token amortizes
# a full weight + KV streaming pass — the bandwidth lever the paper's
# interactivity analysis asks for on constrained platforms.


def decode_verify_paged(cfg: ArchConfig, params, tokens, seq_lens, n_fed,
                        page_table, cache,
                        opts: RuntimeOptions = RuntimeOptions()):
    """One paged multi-query pass over a (B, C) token window (SS14).

    tokens: (B, C) window ``[t_last, d_1 .. d_{C-1}]`` per slot —
    t_last is the last committed token (its KV has NOT landed yet; the
    pass writes it, exactly like the first micro-step of the fused scan)
    followed by draft proposals; seq_lens: (B,) tokens whose KV already
    landed (the window starts there); n_fed: (B,) real window tokens per
    slot (<= C; shorter drafts right-pad). All C KV positions a slot may
    write must be page-backed (``reserve_ahead(draft_len + 1)``).

    Logits row j of slot b is the target distribution for the token AFTER
    window token j — rows 0..n_fed-2 verify the draft, row n_fed-1 is the
    correction/bonus row. Pad rows write KV beyond the fed window into
    reserved (or null) pages: never committed, overwritten before any
    read. Returns (logits (B, C, vocab), new cache)."""
    B, C = tokens.shape
    x = _embed_tokens(cfg, params, jnp.asarray(tokens, jnp.int32), None)
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    n_valid = seq_lens + jnp.asarray(n_fed, jnp.int32)
    positions = seq_lens[:, None] + jnp.arange(C)[None, :]

    def scan_body(carry, xs):
        lp, cl = xs
        h = cm.constrain(carry, opts.residual_sharding)
        a, nc = _paged_chunk_attn(lp["attn"], cm.rms_norm(h, lp["ln1"]),
                                  cfg, opts, cl, positions, page_table,
                                  seq_lens, n_valid, calibrate=False)
        h = h + a
        f, _ = _ffn_apply(lp, cm.rms_norm(h, lp["ln2"]), cfg, opts)
        return h + f, nc
    x, new_stack = jax.lax.scan(scan_body, x, (params["stack"],
                                               cache["stack"]))
    logits = _logits(cfg, params, x)
    return logits, {"stack": new_stack}


def spec_decode_verify(cfg: ArchConfig, params, tokens, draft_len, seq_lens,
                       page_table, cache, keys,
                       opts: RuntimeOptions = RuntimeOptions(), *,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0, pad_id: int = 0):
    """Verify a draft window and accept/reject in one device round (SS14).

    tokens: (B, C) fed window ``[t_last, d_1 .. d_{C-1}]``; draft_len:
    (B,) real proposals per slot (<= C-1; the pass feeds draft_len + 1
    tokens); keys: (B, 2) per-slot PRNG keys (unused at temperature 0 and
    returned unchanged there). Emits ``n_acc + 1`` tokens per active slot
    — accepted draft prefix plus one corrected/bonus token — so progress
    is always >= 1 token per pass, and at temperature 0 the emitted
    stream is token-identical to non-speculative greedy decode.

    Returns (out (B, C) int32 [row: accepted drafts, correction, pads],
    n_acc (B,), advanced keys (B, 2), new cache)."""
    draft_len = jnp.asarray(draft_len, jnp.int32)
    logits, cache = decode_verify_paged(cfg, params, tokens, seq_lens,
                                        draft_len + 1, page_table, cache,
                                        opts)
    out, n_acc, new_keys = sampling_mod.spec_accept(
        logits, jnp.asarray(tokens, jnp.int32)[:, 1:], draft_len, keys,
        temperature=temperature, top_k=top_k, top_p=top_p, pad_id=pad_id)
    return out, n_acc, new_keys, cache
