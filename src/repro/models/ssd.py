"""Mamba-2 block via the SSD (state-space duality) algorithm, pure JAX.

Training / prefill uses the chunked SSD decomposition (arXiv:2405.21060):
intra-chunk quadratic term + inter-chunk recurrence over chunk states —
sub-quadratic in sequence length and scan-friendly for XLA.
Decode keeps an O(1) recurrent state per layer: (conv tail, SSM state).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    return s, d, di, nh, s.n_groups, s.state_dim


def init_mamba(key, cfg: ArchConfig, dtype):
    s, d, di, nh, ng, N = _dims(cfg)
    conv_dim = di + 2 * ng * N
    keys = jax.random.split(key, 6)
    dt_init = jnp.exp(jax.random.uniform(keys[3], (nh,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": cm.dense_init(keys[0], d, 2 * di + 2 * ng * N + nh, dtype),
        "conv_w": (jax.random.normal(keys[1], (s.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": cm.dense_init(keys[2], di, d, dtype),
    }


def _split_in(cfg, h):
    s, d, di, nh, ng, N = _dims(cfg)
    z, xBC, dt = jnp.split(h, [di, 2 * di + 2 * ng * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width K. xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan. x:(b,S,nh,hd) dt:(b,S,nh) A:(nh,) B,C:(b,S,ng,N).

    Returns y:(b,S,nh,hd) and final state (b,nh,hd,N)."""
    b, S, nh, hd = x.shape
    ng, N = B.shape[2], B.shape[3]
    rep = nh // ng
    nc = -(-S // chunk)
    Sp = nc * chunk
    if Sp != S:
        padd = ((0, 0), (0, Sp - S))
        x = jnp.pad(x, padd + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, padd + ((0, 0),))
        B = jnp.pad(B, padd + ((0, 0), (0, 0)))
        C = jnp.pad(C, padd + ((0, 0), (0, 0)))
    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, ng, N)
    Cc = C.reshape(b, nc, chunk, ng, N)
    a = dtc * A                                    # (b,nc,Q,nh) decay logs
    cum = jnp.cumsum(a, axis=2)
    # intra-chunk: Y[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
    Lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (b,nc,Q,Q,nh)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(Lmat), 0.0)
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)            # (b,nc,Q,Q,ng)
    CB = jnp.repeat(CB, rep, axis=-1)                        # -> nh
    M = CB * Lmat * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", M, xc)
    # chunk states: S_c = sum_j B_j dt_j x_j exp(cum_last - cum_j)
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                   # (b,nc,Q,nh)
    Bh = jnp.repeat(Bc, rep, axis=3)                         # (b,nc,Q,nh,N)
    states = jnp.einsum("bcqhn,bcqh,bcqhd->bchdn",
                        Bh, seg * dtc, xc)                   # (b,nc,nh,hd,N)
    # inter-chunk recurrence over chunk boundary states
    lam = jnp.exp(cum[:, :, -1, :])                          # (b,nc,nh)

    def scan_fn(h, xs):
        st, lm = xs
        h_new = h * lm[..., None, None] + st
        return h_new, h
    h0 = jnp.zeros((b, nh, hd, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         lam.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                 # (b,nc,nh,hd,N)
    # inter-chunk contribution: C_i . H_{c-1} * exp(cum_i)
    Ch = jnp.repeat(Cc, rep, axis=3)                         # (b,nc,Q,nh,N)
    y_inter = jnp.einsum("bcqhn,bchdn->bcqhd", Ch,
                         h_prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, Sp, nh, hd)[:, :S]
    return y, hT


def mamba_forward(p, x, cfg: ArchConfig, *, return_state: bool = False):
    """Full-sequence SSD block. x: (B,S,d) -> (B,S,d) [+ decode state]."""
    s, d, di, nh, ng, N = _dims(cfg)
    h = cm.dense(p["in_proj"], x)
    z, xBC_raw, dt = _split_in(cfg, h)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xin, B, C = jnp.split(xBC, [di, di + ng * N], axis=-1)
    Bm = B.reshape(*B.shape[:2], ng, N).astype(jnp.float32)
    Cm = C.reshape(*C.shape[:2], ng, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(*xin.shape[:2], nh, s.head_dim).astype(jnp.float32)
    y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    y = y + xh * p["D"][:, None]
    y = y.reshape(*y.shape[:2], di).astype(x.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    p["norm_w"])
    out = cm.dense(p["out_proj"], y)
    if not return_state:
        return out
    K = s.conv_width - 1
    tail = xBC_raw[:, -K:, :]
    pad = jnp.zeros((x.shape[0], max(K - x.shape[1], 0), tail.shape[-1]),
                    tail.dtype)
    state = {"conv": jnp.concatenate([pad, tail], axis=1),
             "ssm": hT}
    return out, state


def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    s, d, di, nh, ng, N = _dims(cfg)
    conv_dim = di + 2 * ng * N
    return {"conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, nh, s.head_dim, N), jnp.float32)}


def mamba_decode(p, x_t, state, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """One-token step. x_t: (B,d); O(1) state update."""
    s, d, di, nh, ng, N = _dims(cfg)
    h = cm.dense(p["in_proj"], x_t)                        # (B, ...)
    z, xBC, dt = _split_in(cfg, h)
    window = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)
    conv_out = (window * p["conv_w"]).sum(axis=1) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    xin, B, C = jnp.split(xBC, [di, di + ng * N], axis=-1)
    Bm = B.reshape(-1, ng, N).astype(jnp.float32)
    Cm = C.reshape(-1, ng, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(-1, nh, s.head_dim).astype(jnp.float32)
    rep = nh // ng
    Bh = jnp.repeat(Bm, rep, axis=1)                       # (B,nh,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A)                                # (B,nh)
    h_new = (state["ssm"] * decay[..., None, None]
             + jnp.einsum("bhn,bh,bhd->bhdn", Bh, dt, xh))
    y = jnp.einsum("bhn,bhdn->bhd", Ch, h_new) + xh * p["D"][:, None]
    y = y.reshape(-1, di).astype(x_t.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype),
                    p["norm_w"])
    new_state = {"conv": window[:, 1:], "ssm": h_new}
    return cm.dense(p["out_proj"], y), new_state
