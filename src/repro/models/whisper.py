"""Whisper-style encoder-decoder transformer (conv frontend is a STUB:
``input_specs`` feeds 1500 precomputed frame embeddings straight into the
encoder). Pre-LN LayerNorm + GELU MLP + learned decoder positions, per
arXiv:2212.04356. Cross-KV is computed once per request at prefill.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import moe as moe_mod


def _init_attn(key, cfg, d, dtype, bias=True):
    H, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": cm.dense_init(ks[0], d, H * hd, dtype, bias=bias),
            "wk": cm.dense_init(ks[1], d, H * hd, dtype),
            "wv": cm.dense_init(ks[2], d, H * hd, dtype, bias=bias),
            "wo": cm.dense_init(ks[3], H * hd, d, dtype, bias=bias)}


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {"ln1": _ln_init(d, dtype), "attn": _init_attn(k1, cfg, d, dtype),
            "ln2": _ln_init(d, dtype),
            "mlp": moe_mod.init_dense_ffn(k2, cfg, cfg.d_ff, dtype)}


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {"ln1": _ln_init(d, dtype), "self": _init_attn(k1, cfg, d, dtype),
            "ln_x": _ln_init(d, dtype), "cross": _init_attn(k2, cfg, d, dtype),
            "ln2": _ln_init(d, dtype),
            "mlp": moe_mod.init_dense_ffn(k3, cfg, cfg.d_ff, dtype)}


def init_params(cfg: ArchConfig, key, opts):
    dtype = opts.jdtype
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "embed": cm.embed_init(ks[0], cfg.vocab, d, dtype),
        "pos_dec": (jax.random.normal(ks[1], (cfg.max_context, d),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_stack": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.enc_layers)),
        "dec_stack": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(ks[3], cfg.n_layers)),
        "ln_enc": _ln_init(d, dtype),
        "ln_dec": _ln_init(d, dtype),
    }


def _sinusoid(n: int, d: int):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(p, xq, xkv, cfg, *, mask_kind, q_offset=0, impl="xla", cache=None,
         pos=None):
    B, S, _ = xq.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = cm.dense(p["wq"], xq).reshape(B, S, H, hd)
    if cache is not None and xkv is None:            # cross-attn from cache
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = cm.dense(p["wk"], xkv).reshape(B, -1, H, hd)
        v = cm.dense(p["wv"], xkv).reshape(B, -1, H, hd)
        new_cache = None
        if cache is not None:                         # self-attn decode
            ck, cv = cm.update_cache(cache["k"], cache["v"], k, v, pos)
            k, v = ck, cv
            new_cache = {"k": ck, "v": cv}
    out = cm.attention(q, k.astype(q.dtype), v.astype(q.dtype),
                       mask_kind=mask_kind, q_offset=q_offset, impl=impl)
    return cm.dense(p["wo"], out.reshape(B, S, H * hd)), new_cache


def encode(cfg: ArchConfig, params, frames, opts):
    """frames: (B, source_len, d) stub embeddings -> encoder output."""
    x = frames.astype(opts.jdtype) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(opts.jdtype)[None]

    def body(h, lp):
        h = cm.constrain(h, opts.residual_sharding)
        a, _ = _mha(lp["attn"], cm.layer_norm(h, **lp["ln1"]),
                    cm.layer_norm(h, **lp["ln1"]), cfg, mask_kind="full",
                    impl=opts.attn_impl)
        h = h + a
        h = h + moe_mod.dense_ffn(lp["mlp"],
                                  cm.layer_norm(h, **lp["ln2"]), False)
        return h, None
    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return cm.layer_norm(x, **params["ln_enc"])


def _dec_forward(cfg, params, tokens, enc_out, opts, *, collect_kv=False):
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"]["emb"][tokens] + params["pos_dec"][None, :S]

    def body(h, lp):
        h = cm.constrain(h, opts.residual_sharding)
        hn = cm.layer_norm(h, **lp["ln1"])
        a, _ = _mha(lp["self"], hn, hn, cfg, mask_kind="causal",
                    impl=opts.attn_impl)
        kv = None
        if collect_kv:
            kv = (cm.dense(lp["self"]["wk"], hn).reshape(B, S, H, hd),
                  cm.dense(lp["self"]["wv"], hn).reshape(B, S, H, hd))
        h = h + a
        c, _ = _mha(lp["cross"], cm.layer_norm(h, **lp["ln_x"]), enc_out,
                    cfg, mask_kind="full", impl=opts.attn_impl)
        h = h + c
        h = h + moe_mod.dense_ffn(lp["mlp"],
                                  cm.layer_norm(h, **lp["ln2"]), False)
        return h, kv
    x, kvs = jax.lax.scan(body, x, params["dec_stack"])
    x = cm.layer_norm(x, **params["ln_dec"])
    if collect_kv == "hidden":
        return x
    logits = x @ params["embed"]["emb"].T
    return (logits, kvs) if collect_kv else logits


def forward(cfg: ArchConfig, params, tokens, opts, prefix_emb=None, **_):
    """prefix_emb = frame embeddings (B, source_len, d) from the stub."""
    assert prefix_emb is not None, "whisper needs frame embeddings"
    enc_out = encode(cfg, params, prefix_emb, opts)
    return _dec_forward(cfg, params, tokens, enc_out, opts), {}


def _fill(buf, val):
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                        (0,) * buf.ndim)


def train_loss(cfg, params, batch, opts):
    enc_out = encode(cfg, params, batch["prefix_emb"], opts)
    h = _dec_forward(cfg, params, batch["tokens"], enc_out, opts,
                     collect_kv="hidden")
    loss = cm.chunked_xent(h[:, :-1], params["embed"]["emb"],
                           batch["labels"][:, 1:], tied=True)
    return loss, {"nll": loss}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, opts):
    dtype = jnp.dtype(opts.cache_dtype) if opts.cache_dtype else opts.jdtype
    H, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers
    return {
        "self": {"k": jnp.zeros((L, batch, max_len, H, hd), dtype),
                 "v": jnp.zeros((L, batch, max_len, H, hd), dtype)},
        "cross": {"k": jnp.zeros((L, batch, cfg.source_len, H, hd), dtype),
                  "v": jnp.zeros((L, batch, cfg.source_len, H, hd), dtype)},
    }


def prefill(cfg: ArchConfig, params, tokens, cache, opts, prefix_emb=None):
    """Encode audio once; pre-compute cross-KV; run the decoder prompt."""
    enc_out = encode(cfg, params, prefix_emb, opts)
    H, hd = cfg.n_heads, cfg.head_dim
    B = tokens.shape[0]

    def cross_kv(lp):
        k = cm.dense(lp["cross"]["wk"], enc_out).reshape(B, -1, H, hd)
        v = cm.dense(lp["cross"]["wv"], enc_out).reshape(B, -1, H, hd)
        return k, v
    ck, cv = jax.lax.map(cross_kv, params["dec_stack"])
    logits, kvs = _dec_forward(cfg, params, tokens, enc_out, opts,
                               collect_kv=True)
    cache = {"self": {"k": _fill(cache["self"]["k"], kvs[0]),
                      "v": _fill(cache["self"]["v"], kvs[1])},
             "cross": {"k": ck.astype(cache["cross"]["k"].dtype),
                       "v": cv.astype(cache["cross"]["v"].dtype)}}
    return logits[:, -1], cache


def decode_step(cfg: ArchConfig, params, token, pos, cache, opts):
    x = (params["embed"]["emb"][token][:, None, :]
         + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1)[None])

    def body(h, xs):
        lp, self_c, cross_c = xs
        h = cm.constrain(h, opts.residual_sharding)
        a, new_self = _mha(lp["self"], cm.layer_norm(h, **lp["ln1"]),
                           cm.layer_norm(h, **lp["ln1"]), cfg,
                           mask_kind="causal", q_offset=pos,
                           impl=opts.attn_impl, cache=self_c, pos=pos)
        h = h + a
        c, _ = _mha(lp["cross"], cm.layer_norm(h, **lp["ln_x"]), None, cfg,
                    mask_kind="full", impl=opts.attn_impl, cache=cross_c)
        h = h + c
        h = h + moe_mod.dense_ffn(lp["mlp"],
                                  cm.layer_norm(h, **lp["ln2"]), False)
        return h, new_self
    x, new_self = jax.lax.scan(
        body, x, (params["dec_stack"], cache["self"], cache["cross"]))
    x = cm.layer_norm(x, **params["ln_dec"])
    logits = (x @ params["embed"]["emb"].T)[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}
