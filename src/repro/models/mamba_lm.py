"""Pure Mamba-2 LM (mamba2-130m): attention-free backbone, O(1) decode state.

The paper's Q/K/V-tier placement class has no target tensor here (no KV
cache) — see DESIGN.md SSArch-applicability; weight-tier placement applies.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import ssd


def init_params(cfg: ArchConfig, key, opts):
    dtype = opts.jdtype
    k1, k2 = jax.random.split(key)
    return {"embed": cm.embed_init(k1, cfg.vocab, cfg.d_model, dtype),
            "stack": jax.vmap(lambda k: ssd.init_mamba(k, cfg, dtype))(
                jax.random.split(k2, cfg.n_layers)),
            "final_norm": jnp.zeros((cfg.d_model,), dtype)}


def _embed(cfg, params, tokens):
    return params["embed"]["emb"][tokens]


def forward(cfg: ArchConfig, params, tokens, opts, prefix_emb=None, *,
            return_hidden: bool = False, **_):
    x = _embed(cfg, params, tokens)

    def body(h, lp):
        h = cm.constrain(h, opts.residual_sharding)
        return h + ssd.mamba_forward(lp, h, cfg), None
    body = jax.checkpoint(body) if opts.remat == "block" else body
    x, _ = jax.lax.scan(body, x, params["stack"])
    x = cm.rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, {}
    return x @ params["embed"]["emb"].T, {}


def train_loss(cfg, params, batch, opts):
    h, _ = forward(cfg, params, batch["tokens"], opts, return_hidden=True)
    loss = cm.chunked_xent(h[:, :-1], params["embed"]["emb"],
                           batch["labels"][:, 1:], tied=True)
    return loss, {"nll": loss}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, opts):
    states = jax.vmap(lambda _: ssd.init_mamba_state(cfg, batch, opts.jdtype))(
        jnp.arange(cfg.n_layers))
    return {"ssm_states": states}


def prefill(cfg: ArchConfig, params, tokens, cache, opts, prefix_emb=None):
    x = _embed(cfg, params, tokens)

    def body(h, lp):
        h = cm.constrain(h, opts.residual_sharding)
        y, st = ssd.mamba_forward(lp, h, cfg, return_state=True)
        return h + y, st
    x, states = jax.lax.scan(body, x, params["stack"])
    x = cm.rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"]["emb"].T)[:, -1]
    return logits, {"ssm_states": states}


def decode_step(cfg: ArchConfig, params, token, pos, cache, opts):
    x = _embed(cfg, params, token)[:, None, :]

    def body(h, xs):
        lp, st = xs
        h = cm.constrain(h, opts.residual_sharding)
        y, new_st = ssd.mamba_decode(lp, h[:, 0, :], st, cfg)
        return h + y[:, None, :], new_st
    x, new_states = jax.lax.scan(body, x, (params["stack"],
                                           cache["ssm_states"]))
    x = cm.rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"]["emb"].T)[:, 0]
    return logits, {"ssm_states": new_states}
