"""Uniform model API: dispatches on ``ArchConfig.family``.

    init_params / forward / train_loss / init_cache / prefill / decode_step
    input_specs(cfg, shape)  -> ShapeDtypeStruct stand-ins for the dry-run

Families: dense | moe | vlm -> lm.py;  ssm -> mamba_lm.py;
          hybrid -> zamba.py;  encdec -> whisper.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm, mamba_lm, whisper, zamba
from repro.models.lm import RuntimeOptions

_MODS = {"dense": lm, "moe": lm, "vlm": lm, "ssm": mamba_lm,
         "hybrid": zamba, "encdec": whisper}


def module_for(cfg: ArchConfig):
    return _MODS[cfg.family]


def init_params(cfg, key, opts: RuntimeOptions = RuntimeOptions()):
    return module_for(cfg).init_params(cfg, key, opts)


def forward(cfg, params, tokens, opts=RuntimeOptions(), prefix_emb=None):
    return module_for(cfg).forward(cfg, params, tokens, opts,
                                   prefix_emb=prefix_emb)


def train_loss(cfg, params, batch, opts=RuntimeOptions()):
    return module_for(cfg).train_loss(cfg, params, batch, opts)


def init_cache(cfg, batch, max_len, opts=RuntimeOptions()):
    return module_for(cfg).init_cache(cfg, batch, max_len, opts)


def prefill(cfg, params, tokens, cache, opts=RuntimeOptions(),
            prefix_emb=None):
    return module_for(cfg).prefill(cfg, params, tokens, cache, opts,
                                   prefix_emb=prefix_emb)


def decode_step(cfg, params, token, pos, cache, opts=RuntimeOptions()):
    return module_for(cfg).decode_step(cfg, params, token, pos, cache, opts)


def decode_steps(cfg, params, token, pos, cache, n_steps: int,
                 opts=RuntimeOptions(), *, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0, keys=None):
    """Fused K-step decode over the dense cache (DESIGN.md SS12).

    Scans ``module_for(cfg).decode_step`` ``n_steps`` times with on-device
    sampling between steps, so the host syncs once per (B, n_steps) token
    block instead of once per token. Family-generic: any ``decode_step``
    with a shape-stable cache pytree scans. token: (B,) int32 last sampled
    token; pos: scalar int32 write position of that token's KV; keys:
    optional (B, 2) per-slot PRNG keys (required when temperature > 0; the
    return gains the advanced keys). Returns ((B, n_steps) token block,
    new cache[, advanced keys])."""
    from repro.models import sampling
    mod = module_for(cfg)
    if temperature > 0.0 and keys is None:
        raise ValueError("stochastic fused decode needs per-slot PRNG keys "
                         "(keys=(B, 2) uint32)")
    stochastic = keys is not None and temperature > 0.0

    def micro_step(carry, _):
        tok, p, ks, c = carry
        logits, c = mod.decode_step(cfg, params, tok, p, c, opts)
        if stochastic:
            sub = sampling.split_keys(ks, 2)
            step_keys, ks = sub[:, 0], sub[:, 1]
            nxt = sampling.sample(logits, step_keys, temperature=temperature,
                                  top_k=top_k, top_p=top_p)
        else:
            nxt = sampling.sample_greedy(logits)
        return (nxt, p + 1, ks, c), nxt

    B = jnp.shape(token)[0]
    init_keys = (jnp.asarray(keys, jnp.uint32) if keys is not None
                 else jnp.zeros((B, 2), jnp.uint32))
    init = (jnp.asarray(token, jnp.int32), jnp.asarray(pos, jnp.int32),
            init_keys, cache)
    (_, _, out_keys, cache), toks = jax.lax.scan(micro_step, init, None,
                                                 length=n_steps)
    toks = jnp.moveaxis(toks, 0, 1)
    if keys is not None:
        return toks, cache, out_keys
    return toks, cache


# ------------------------- paged KV (continuous batching) -------------- #
# Only the decoder-only GQA families page their KV; other families report
# a reason via paged_supported (DESIGN.md SS10).

def paged_supported(cfg) -> Optional[str]:
    mod = module_for(cfg)
    if not hasattr(mod, "paged_supported"):
        return f"family {cfg.family!r} has no paged serving path"
    return mod.paged_supported(cfg)


def init_paged_cache(cfg, n_pages, page_size, opts=RuntimeOptions()):
    return module_for(cfg).init_paged_cache(cfg, n_pages, page_size, opts)


def prefill_paged(cfg, params, tokens, cache, page_table, true_len,
                  opts=RuntimeOptions(), *, calibrate: bool = False):
    return module_for(cfg).prefill_paged(cfg, params, tokens, cache,
                                         page_table, true_len, opts,
                                         calibrate=calibrate)


def decode_step_paged(cfg, params, token, seq_lens, page_table, cache,
                      opts=RuntimeOptions()):
    return module_for(cfg).decode_step_paged(cfg, params, token, seq_lens,
                                             page_table, cache, opts)


def decode_steps_paged(cfg, params, tokens, seq_lens, page_table, cache,
                       n_steps, opts=RuntimeOptions(), *, eos_id=None,
                       pad_id: int = 0, temperature: float = 0.0,
                       top_k: int = 0, top_p: float = 1.0, keys=None,
                       done=None, quota=None):
    return module_for(cfg).decode_steps_paged(
        cfg, params, tokens, seq_lens, page_table, cache, n_steps, opts,
        eos_id=eos_id, pad_id=pad_id, temperature=temperature, top_k=top_k,
        top_p=top_p, keys=keys, done=done, quota=quota)


def decode_verify_paged(cfg, params, tokens, seq_lens, n_fed, page_table,
                        cache, opts=RuntimeOptions()):
    """One paged multi-query verify pass (DESIGN.md SS14)."""
    return module_for(cfg).decode_verify_paged(cfg, params, tokens, seq_lens,
                                               n_fed, page_table, cache, opts)


def spec_decode_verify(cfg, params, tokens, draft_len, seq_lens, page_table,
                       cache, keys, opts=RuntimeOptions(), *,
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0, pad_id: int = 0):
    """Verify a draft window + leftover/rejection sampling (DESIGN.md SS14)."""
    return module_for(cfg).spec_decode_verify(
        cfg, params, tokens, draft_len, seq_lens, page_table, cache, keys,
        opts, temperature=temperature, top_k=top_k, top_p=top_p,
        pad_id=pad_id)


def prefill_paged_chunk(cfg, params, tokens, cache, page_table, start,
                        n_valid, opts=RuntimeOptions(), *,
                        calibrate: bool = False):
    return module_for(cfg).prefill_paged_chunk(cfg, params, tokens, cache,
                                               page_table, start, n_valid,
                                               opts, calibrate=calibrate)


def copy_pages(cfg, cache, pairs):
    """Apply (src, dst) COW page copies to the pooled cache."""
    return module_for(cfg).copy_pages(cache, pairs)


# --------------------------- input specs ------------------------------- #

@dataclass(frozen=True)
class ShapeSpec:
    """One cell of the assigned (arch x shape) grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_runnable(cfg: ArchConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape == "long_500k" and not cfg.has_subquadratic_context:
        return ("full-attention KV at 500k ctx (sub-quadratic required; "
                "see DESIGN.md SS5)")
    return None


def input_specs(cfg: ArchConfig, shape: str, opts=RuntimeOptions()) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train: {"tokens","labels"[, "prefix_emb"]}
    prefill: {"tokens"[, "prefix_emb"]}
    decode: {"token", "pos"} (cache/params provided separately by the
    launcher via jax.eval_shape over init fns)."""
    sp = SHAPES[shape]
    B = sp.global_batch
    dt = opts.jdtype
    i32 = jnp.int32
    S = sp.seq_len
    if sp.kind in ("train", "prefill"):
        text_len = S - (cfg.prefix_len or 0) if cfg.family == "vlm" else S
        d = {"tokens": jax.ShapeDtypeStruct((B, text_len), i32)}
        if sp.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((B, text_len), i32)
        if cfg.family == "vlm":
            d["prefix_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), dt)
        if cfg.family == "encdec":
            d["prefix_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.source_len, cfg.d_model), dt)
        return d
    return {"token": jax.ShapeDtypeStruct((B,), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}
