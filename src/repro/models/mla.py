"""Multi-head Latent Attention (DeepSeek-V2), pure JAX.

Prefill uses the standard (decompressed) path; decode uses the ABSORBED path
so the per-token cache is only ``kv_lora_rank + rope_head_dim`` wide — the
architectural realization of the paper's "shrink the Q/K/V traffic class".
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm


def init_mla(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "q_down": cm.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "q_up": cm.dense_init(ks[1], m.q_lora_rank,
                              H * (m.qk_nope_head_dim + m.rope_head_dim),
                              dtype),
        "kv_down": cm.dense_init(ks[2], d, m.kv_lora_rank + m.rope_head_dim,
                                 dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "k_up": (jax.random.normal(ks[3], (H, m.kv_lora_rank,
                                           m.qk_nope_head_dim), jnp.float32)
                 * (m.kv_lora_rank ** -0.5)).astype(dtype),
        "v_up": (jax.random.normal(ks[4], (H, m.kv_lora_rank, m.v_head_dim),
                                   jnp.float32)
                 * (m.kv_lora_rank ** -0.5)).astype(dtype),
        "o_proj": cm.dense_init(ks[5], H * m.v_head_dim, d, dtype),
    }


def _q_proj(p, x, cfg: ArchConfig, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q = cm.dense(p["q_up"], cm.rms_norm(cm.dense(p["q_down"], x),
                                        p["q_norm"]))
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = cm.apply_rope(q_rope, positions)
    return q_nope, q_rope


def _kv_latent(p, x, cfg: ArchConfig, positions):
    """Compressed latent + shared rope key — this IS the cache entry."""
    m = cfg.mla
    ckv = cm.dense(p["kv_down"], x)                       # (B,S,r+dr)
    c, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c = cm.rms_norm(c, p["kv_norm"])
    k_rope = cm.apply_rope(k_rope[:, :, None, :], positions)[:, :, 0, :]
    return c, k_rope


def mla_prefill_attn(p, x, cfg: ArchConfig, positions, *, impl="xla"):
    """Standard (decompressed) MLA attention over the full sequence."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c, k_rope = _kv_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,hrd->bshd", c, p["k_up"].astype(c.dtype))
    v = jnp.einsum("bsr,hrd->bshd", c, p["v_up"].astype(c.dtype))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.rope_head_dim) ** -0.5
    out = cm.attention(q_full, k_full, v, mask_kind="causal", scale=scale,
                       impl=impl)
    out = out.reshape(B, S, H * m.v_head_dim)
    return cm.dense(p["o_proj"], out), (c, k_rope)


def mla_decode_attn(p, x, cfg: ArchConfig, cache: Dict, pos
                    ) -> Tuple[jax.Array, Dict]:
    """Absorbed decode: score/combine directly in the latent space.

    cache = {"c": (B, Lmax, r), "k_rope": (B, Lmax, dr)}; pos: scalar int.
    Per-step KV read = Lmax*(r+dr) bytes — independent of head count."""
    m = cfg.mla
    B, S, _ = x.shape                                      # S == 1
    H = cfg.n_heads
    positions = jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, S))
    q_nope, q_rope = _q_proj(p, x, cfg, positions)
    c_new, k_rope_new = _kv_latent(p, x, cfg, positions)
    cache_c = jax.lax.dynamic_update_slice(
        cache["c"], c_new.astype(cache["c"].dtype), (0, pos, 0))
    cache_kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, pos, 0))
    # absorb q through W_uk: (B,1,H,dn) @ (H,r,dn) -> (B,1,H,r)
    q_lat = jnp.einsum("bshd,hrd->bshr", q_nope,
                       p["k_up"].astype(q_nope.dtype))
    scale = (m.qk_nope_head_dim + m.rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bshr,blr->bhsl", q_lat.astype(jnp.float32),
                       cache_c.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,bld->bhsl", q_rope.astype(jnp.float32),
                        cache_kr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale                      # (B,H,1,L)
    L = cache_c.shape[1]
    valid = jnp.arange(L)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, cm.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhsl,blr->bshr", probs,
                     cache_c.astype(jnp.float32))          # (B,1,H,r)
    out = jnp.einsum("bshr,hrd->bshd", ctx,
                     p["v_up"].astype(jnp.float32))        # (B,1,H,dv)
    out = out.reshape(B, S, H * m.v_head_dim).astype(x.dtype)
    return cm.dense(p["o_proj"], out), {"c": cache_c, "k_rope": cache_kr}


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {"c": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype)}
