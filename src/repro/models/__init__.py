"""Pure-JAX model zoo: dense/GQA, MLA, MoE, SSM (SSD), hybrid, enc-dec, VLM."""
from repro.models.api import (RuntimeOptions, SHAPES, ShapeSpec,
                              cell_runnable, copy_pages, decode_step,
                              decode_step_paged, decode_steps,
                              decode_steps_paged, decode_verify_paged,
                              forward, init_cache, init_paged_cache,
                              init_params, input_specs, module_for,
                              paged_supported, prefill, prefill_paged,
                              prefill_paged_chunk, spec_decode_verify,
                              train_loss)
from repro.models.lm import layer_dma_slices, page_layer_nbytes

__all__ = ["RuntimeOptions", "SHAPES", "ShapeSpec", "cell_runnable",
           "copy_pages", "decode_step", "decode_step_paged", "decode_steps",
           "decode_steps_paged", "decode_verify_paged", "forward",
           "init_cache", "init_paged_cache", "init_params", "input_specs",
           "layer_dma_slices", "module_for", "page_layer_nbytes",
           "paged_supported", "prefill", "prefill_paged",
           "prefill_paged_chunk", "spec_decode_verify", "train_loss"]
