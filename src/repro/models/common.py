"""Shared pure-JAX building blocks: init, norms, RoPE, masks, attention core.

No flax in this container — parameters are plain pytrees (nested dicts of
jnp arrays), modules are (init_fn, apply_fn) pairs.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DType = jnp.dtype


# ------------------------------ init ---------------------------------- #

def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    if bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype):
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32)
                    * 0.02).astype(dtype)}


# ------------------------------ norms --------------------------------- #

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ------------------------------ RoPE ----------------------------------- #

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------ masks ---------------------------------- #

NEG_INF = -1e30


def causal_mask(S: int, L: int, q_offset: int = 0):
    """(S, L) True where query i may attend key j."""
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(L)[None, :]
    return kpos <= qpos


def sliding_mask(S: int, L: int, window: int, q_offset: int = 0):
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(L)[None, :]
    return (kpos <= qpos) & (kpos > qpos - window)


def prefix_lm_mask(S: int, L: int, prefix_len: int, q_offset: int = 0):
    """Bidirectional over the first ``prefix_len`` positions, causal after."""
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(L)[None, :]
    return (kpos <= qpos) | (kpos < prefix_len)


def length_mask(L: int, valid_len):
    return jnp.arange(L)[None, :] < valid_len


# --------------------------- attention core ---------------------------- #
# Masks are LAZY (kind + params), materialized per block — a full (S, L)
# mask/score tensor at 32k context would dwarf HBM. This is the XLA-level
# analogue of the paper's hierarchical tiling; the Pallas kernel does the
# same blocking explicitly in VMEM.

def _block_mask(kind: str, qpos, kpos, *, window: int = 0,
                prefix_len: int = 0, kv_valid: Optional[jnp.ndarray] = None):
    q = qpos[:, None]
    kk = kpos[None, :]
    if kind == "causal":
        m = kk <= q
    elif kind == "sliding":
        m = (kk <= q) & (kk > q - window)
    elif kind == "prefix":
        m = (kk <= q) | (kk < prefix_len)
    elif kind == "full":
        m = jnp.ones((q.shape[0], kk.shape[1]), bool)
    else:
        raise ValueError(kind)
    if kv_valid is not None:   # (B,) valid KV length (decode caches)
        m = m[None] & (kk[None] < kv_valid[:, None, None])
    return m


def _scores_block(qg, kb, scale, softcap):
    # bf16 operands + f32 accumulation: upcasting the KV operand would
    # materialize an f32 copy of the whole cache (2x HBM) — observed as a
    # carried f32[L,B,Lshard,H,dh] twin of the cache in decode graphs
    s = jnp.einsum("bshgd,blhd->bshgl", qg, kb,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def attention(q, k, v, *, mask_kind: str = "causal", window: int = 0,
              prefix_len: int = 0, q_offset=0, kv_valid=None,
              scale: Optional[float] = None, softcap: float = 0.0,
              impl: str = "xla", block_q: int = 512, block_kv: int = 1024,
              acc_dtype: str = "float32"):
    """GQA attention with lazy masks and flash-style KV blocking.

    q: (B,S,H,dh); k/v: (B,L,Hkv,dh); kv_valid: optional (B,) valid length.
    ``impl='pallas'`` routes to the Pallas flash kernel when eligible.
    """
    B, S, H, dh = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.try_flash_attention(
            q, k, v, mask_kind=mask_kind, window=window,
            prefix_len=prefix_len, q_offset=q_offset, kv_valid=kv_valid,
            scale=scale, softcap=softcap)
        if out is not None:
            return out
    group = H // Hkv
    qg = q.reshape(B, S, Hkv, group, dh)
    qpos = jnp.arange(S) + q_offset

    if S * L <= 1 << 21:  # small: single block, no scan
        kpos = jnp.arange(L)
        s = _scores_block(qg, k, scale, softcap)          # (B,S,Hkv,g,L)
        m = _block_mask(mask_kind, qpos, kpos, window=window,
                        prefix_len=prefix_len, kv_valid=kv_valid)
        m = m[:, :, None, None, :] if m.ndim == 3 else m[None, :, None, None, :]
        s = jnp.where(m, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bshgl,blhd->bshgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, S, H, dv).astype(q.dtype)

    # flash-style: outer scan over Q blocks, inner scan over KV blocks with
    # online softmax. Peak live block: (B, bq, Hkv, g, bkv) — independent of
    # S and L. NOTE: causal masking zeroes but does not SKIP upper blocks on
    # this XLA path (~2x attention FLOPs at long S); the Pallas kernel skips
    # them properly on TPU (see kernels/flash_attention.py + SSPerf).
    nq, nkv = -(-S // block_q), -(-L // block_kv)
    Sp, Lp = nq * block_q, nkv * block_kv
    qp = jnp.pad(qg, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, block_q, Hkv, group, dh).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nkv, block_kv, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nkv, block_kv, Hkv, dv).transpose(1, 0, 2, 3, 4)
    base_valid = (kv_valid if kv_valid is not None
                  else jnp.full((B,), L, jnp.int32))

    def q_step(_, qxs):
        qblk, qi = qxs                                   # (B,bq,Hkv,g,dh)
        qpos_blk = qi * block_q + jnp.arange(block_q) + q_offset

        @jax.checkpoint
        def kv_step(carry, xs):
            m_i, l_i, acc = carry
            kblk, vblk, j = xs
            kpos = j * block_kv + jnp.arange(block_kv)
            s = _scores_block(qblk, kblk, scale, softcap)  # (B,bq,Hkv,g,bkv)
            msk = _block_mask(mask_kind, qpos_blk, kpos, window=window,
                              prefix_len=prefix_len, kv_valid=base_valid)
            s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            # fully-masked block: s == m_new == NEG_INF would give exp(0)=1
            p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
            corr = jnp.exp(jnp.minimum(m_i - m_new, 0.0))
            l_new = l_i * corr + p.sum(axis=-1)
            upd = jnp.einsum("bshgl,blhd->bshgd", p.astype(vblk.dtype),
                             vblk, preferred_element_type=jnp.float32)
            acc = (acc * corr[..., None].astype(acc.dtype)
                   + upd.astype(acc.dtype))
            return (m_new, l_new, acc), None

        adt = jnp.dtype(acc_dtype)
        init = (jnp.full((B, block_q, Hkv, group), NEG_INF, jnp.float32),
                jnp.zeros((B, block_q, Hkv, group), jnp.float32),
                jnp.zeros((B, block_q, Hkv, group, dv), adt))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init,
                                          (kb, vb, jnp.arange(nkv)))
        blk_out = (acc.astype(jnp.float32)
                   / jnp.maximum(l_f, 1e-30)[..., None])
        return None, blk_out.astype(q.dtype)

    # checkpointed scans: the backward recomputes scores blockwise instead
    # of saving the full (S, L) residuals — flash-attention memory behaviour
    _, outb = jax.lax.scan(jax.checkpoint(q_step), None,
                           (qb, jnp.arange(nq)))
    out = outb.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, Hkv, group, dv)
    return out[:, :S].reshape(B, S, H, dv)


# ------------------------------ misc ----------------------------------- #

def constrain(x, sharding):
    """with_sharding_constraint when a sharding is provided (else no-op)."""
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def constrain_tree(tree, path_shardings):
    """ZeRO-3 weight gathering: constrain each leaf whose path SUFFIX
    matches an entry of ``path_shardings`` (tuple of (path, sharding)).

    Applied to one layer's param slice inside the scan body, this forces
    GSPMD to all-gather the data-axis weight shards per layer (~weight
    bytes) instead of all-reducing activation-sized partial matmul outputs
    (~token bytes — 40x larger at 32k-token prefill)."""
    if not path_shardings:
        return tree
    table = dict(path_shardings)

    def rule(path, leaf):
        ps = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                      for p in path)
        for suffix, sh in table.items():
            if suffix.endswith(ps) or ps.endswith(suffix):
                return jax.lax.with_sharding_constraint(leaf, sh)
        return leaf
    return jax.tree_util.tree_map_with_path(rule, tree)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def gelu_mlp(p, x):
    return dense(p["fc2"], jax.nn.gelu(dense(p["fc1"], x)))


def update_cache(cache_k, cache_v, k_new, v_new, pos):
    """Write k/v (B,S,Hkv,dh) at position ``pos`` into (B,Lmax,Hkv,dh)."""
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    return ck, cv


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean token NLL; positions with label==ignore are masked out."""
    valid = labels != ignore
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def chunked_xent(h, w, labels, *, tied: bool = False, chunk: int = 512,
                 ignore: int = -1):
    """Cross-entropy WITHOUT materializing (B, S, V) logits.

    Scans over sequence chunks: peak live logits = (B, chunk, V_shard).
    h: (B,S,d) final hidden states; w: (d,V) head or (V,d) tied embedding.
    At 256-way batches x 4k seq x 256k vocab the full logits tensor is
    tens of GB per device — this is what makes train_4k cells fit HBM."""
    B, S, d = h.shape
    nc = -(-S // chunk)
    Sp = nc * chunk
    hp = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, Sp - S)), constant_values=ignore)
    hc = hp.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h_c, lab_c = xs
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", h_c.astype(jnp.float32),
                                w.astype(jnp.float32))
        else:
            logits = jnp.einsum("bsd,dv->bsv", h_c.astype(jnp.float32),
                                w.astype(jnp.float32))
        valid = lab_c != ignore
        safe = jnp.where(valid, lab_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.int32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)
