"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every ``attn_every`` backbone blocks (arXiv:2411.15242).

The shared block sees concat(hidden, initial_embedding) (width 2d), runs
attention + MLP with weights shared across sites, and returns to the
backbone through a per-site linear projection.
Structure: outer scan over sites x inner scan over the site's mamba layers.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import ssd


def _layout(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.attn_every or cfg.n_layers
    n_sites = cfg.n_layers // per
    return n_sites, per


def init_params(cfg: ArchConfig, key, opts):
    dtype = opts.jdtype
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    n_sites, per = _layout(cfg)
    ks = jax.random.split(key, 8)
    mamba_stack = jax.vmap(lambda k: ssd.init_mamba(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_layers))
    # reshape to (sites, per, ...) for the nested scan
    mamba_stack = jax.tree.map(
        lambda a: a.reshape(n_sites, per, *a.shape[1:]), mamba_stack)
    kq, kk, kv, ko, km = jax.random.split(ks[1], 5)
    shared = {
        "ln1": jnp.zeros((2 * d,), dtype),
        "wq": cm.dense_init(kq, 2 * d, H * hd, dtype),
        "wk": cm.dense_init(kk, 2 * d, H * hd, dtype),
        "wv": cm.dense_init(kv, 2 * d, H * hd, dtype),
        "wo": cm.dense_init(ko, H * hd, H * hd, dtype),
        "ln2": jnp.zeros((H * hd,), dtype),
        "mlp": moe_mod.init_dense_ffn(km, cfg.replace(d_model=H * hd),
                                      cfg.d_ff, dtype),
    }
    site_proj = jax.vmap(
        lambda k: cm.dense_init(k, H * hd, d, dtype))(
        jax.random.split(ks[2], n_sites))
    return {"embed": cm.embed_init(ks[3], cfg.vocab, d, dtype),
            "mamba": mamba_stack, "shared": shared, "site_proj": site_proj,
            "final_norm": jnp.zeros((d,), dtype)}


def _shared_block(sp, x, emb0, cfg, opts, *, positions, cache=None, pos=None):
    """x, emb0: (B,S,d). Returns (delta (B,S,H*hd), kv or new cache)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    u = cm.rms_norm(jnp.concatenate([x, emb0], axis=-1), sp["ln1"])
    q = cm.dense(sp["wq"], u).reshape(B, S, H, hd)
    k = cm.dense(sp["wk"], u).reshape(B, S, H, hd)
    v = cm.dense(sp["wv"], u).reshape(B, S, H, hd)
    q = cm.apply_rope(q, positions)
    k = cm.apply_rope(k, positions)
    if cache is None:
        out = cm.attention(q, k, v, mask_kind="causal", impl=opts.attn_impl)
        kv = (k, v)
    else:
        ck, cv = cm.update_cache(cache["k"], cache["v"], k, v, pos)
        out = cm.attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                           mask_kind="causal", q_offset=pos,
                           impl=opts.attn_impl)
        kv = {"k": ck, "v": cv}
    h = cm.dense(sp["wo"], out.reshape(B, S, H * hd))
    h = h + moe_mod.dense_ffn(sp["mlp"], cm.rms_norm(h, sp["ln2"]),
                              cfg.gated_mlp)
    return h, kv


def forward(cfg: ArchConfig, params, tokens, opts, prefix_emb=None, *,
            collect_kv: bool = False, return_hidden: bool = False):
    B, S = tokens.shape
    d = cfg.d_model
    x = params["embed"]["emb"][tokens] * jnp.asarray(math.sqrt(d),
                                                     params["embed"]["emb"].dtype)
    emb0 = x
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def site_body(carry, xs):
        h = cm.constrain(carry, opts.residual_sharding)
        site_mamba, site_proj = xs

        def mamba_body(hh, lp):
            return hh + ssd.mamba_forward(lp, hh, cfg), None
        h, _ = jax.lax.scan(mamba_body, h, site_mamba)
        delta, kv = _shared_block(params["shared"], h, emb0, cfg, opts,
                                  positions=positions)
        h = h + cm.dense(site_proj, delta)
        return h, (kv if collect_kv else None)

    body = jax.checkpoint(site_body) if opts.remat == "block" else site_body
    x, kvs = jax.lax.scan(body, x, (params["mamba"], params["site_proj"]))
    x = cm.rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, {}
    logits = x @ params["embed"]["emb"].T
    if collect_kv:
        return logits, {}, kvs
    return logits, {}


def train_loss(cfg, params, batch, opts):
    h, _ = forward(cfg, params, batch["tokens"], opts, return_hidden=True)
    loss = cm.chunked_xent(h[:, :-1], params["embed"]["emb"],
                           batch["labels"][:, 1:], tied=True)
    return loss, {"nll": loss}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, opts):
    dtype = jnp.dtype(opts.cache_dtype) if opts.cache_dtype else opts.jdtype
    n_sites, per = _layout(cfg)
    H, hd = cfg.n_heads, cfg.head_dim
    mamba_states = jax.vmap(lambda _: ssd.init_mamba_state(cfg, batch, opts.jdtype))(
        jnp.arange(cfg.n_layers))
    mamba_states = jax.tree.map(
        lambda a: a.reshape(n_sites, per, *a.shape[1:]), mamba_states)
    attn = {"k": jnp.zeros((n_sites, batch, max_len, H, hd), dtype),
            "v": jnp.zeros((n_sites, batch, max_len, H, hd), dtype)}
    return {"mamba": mamba_states, "attn": attn}


def decode_step(cfg: ArchConfig, params, token, pos, cache, opts):
    B = token.shape[0]
    d = cfg.d_model
    x = params["embed"]["emb"][token][:, None, :] * jnp.asarray(
        math.sqrt(d), params["embed"]["emb"].dtype)
    emb0 = x

    def site_body(carry, xs):
        h = cm.constrain(carry, opts.residual_sharding)
        site_mamba, site_proj, site_attn_cache, site_states = xs

        def mamba_body(hh, xs2):
            lp, st = xs2
            y, new_st = ssd.mamba_decode(lp, hh[:, 0, :], st, cfg)
            return hh + y[:, None, :], new_st
        h, new_states = jax.lax.scan(mamba_body, h, (site_mamba, site_states))
        delta, new_kv = _shared_block(
            params["shared"], h, emb0, cfg, opts,
            positions=jnp.broadcast_to(jnp.asarray(pos)[None, None], (B, 1)),
            cache=site_attn_cache, pos=pos)
        h = h + cm.dense(site_proj, delta)
        return h, (new_states, new_kv)

    x, (new_mamba, new_attn) = jax.lax.scan(
        site_body, x,
        (params["mamba"], params["site_proj"], cache["attn"], cache["mamba"]))
    x = cm.rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"]["emb"].T)[:, 0]
    return logits, {"mamba": new_mamba, "attn": new_attn}


def prefill(cfg: ArchConfig, params, tokens, cache, opts, prefix_emb=None):
    """Chunked-SSD prefill: full-sequence forward per site, extracting the
    decode states (SSM + conv tail) and the shared-attention KV."""
    B, S = tokens.shape
    d = cfg.d_model
    x = params["embed"]["emb"][tokens] * jnp.asarray(
        math.sqrt(d), params["embed"]["emb"].dtype)
    emb0 = x
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def site_body(carry, xs):
        h = cm.constrain(carry, opts.residual_sharding)
        site_mamba, site_proj = xs

        def mamba_body(hh, lp):
            y, st = ssd.mamba_forward(lp, hh, cfg, return_state=True)
            return hh + y, st
        h, states = jax.lax.scan(mamba_body, h, site_mamba)
        delta, kv = _shared_block(params["shared"], h, emb0, cfg, opts,
                                  positions=positions)
        h = h + cm.dense(site_proj, delta)
        return h, (states, kv)

    x, (mamba_states, kvs) = jax.lax.scan(
        site_body, x, (params["mamba"], params["site_proj"]))
    x = cm.rms_norm(x, params["final_norm"])
    logits = (x @ params["embed"]["emb"].T)[:, -1]

    def fill(buf, val):  # buf: (sites,B,Lmax,H,hd), val: (sites,B,S,H,hd)
        return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                            (0,) * buf.ndim)
    new_cache = {"mamba": mamba_states,
                 "attn": {"k": fill(cache["attn"]["k"], kvs[0]),
                          "v": fill(cache["attn"]["v"], kvs[1])}}
    return logits, new_cache
