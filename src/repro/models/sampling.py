"""On-device token sampling + speculative accept/reject (DESIGN.md SS14).

The fused decode scan (SS12) and the speculative verify pass both choose
tokens on device — a host round-trip per token is exactly the
synchronization overhead the paper's interactivity analysis charges
against constrained platforms. This module is the single home for that
choice:

* ``sample_greedy`` — argmax with ``np.argmax`` tie-breaking (first max),
  the invariant every token-identity test in the repo leans on.
* ``sample`` — temperature / top-k / top-p sampling from per-slot
  ``jax.random`` keys (one key row per batch slot, threaded through the
  ``lax.scan`` carry by ``decode_steps_paged``).
* ``spec_accept`` — standard leftover/rejection sampling for speculative
  decoding against *deterministic* draft proposals. Both draft modes
  (n-gram lookup and greedy small-model) propose a single token per
  position, i.e. a one-hot draft distribution q: accept draft d with
  probability p(d) (the clipped ratio min(1, p(d)/q(d)) with q(d)=1), and
  on rejection sample from the leftover max(0, p - q) ∝ p with d zeroed.
  This is unbiased for any p, and at temperature 0 it degenerates to
  "accept iff d == argmax(p)" — which is what makes spec-on output
  token-identical to greedy spec-off decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample_greedy(logits):
    """Greedy argmax over the last axis, int32. Matches ``np.argmax``
    tie-breaking (first maximum) — the fused-path identity invariant."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def filtered_logits(logits, *, temperature: float, top_k: int = 0,
                    top_p: float = 1.0):
    """Temperature-scale then top-k / top-p (nucleus) mask the logits.

    ``top_k``/``top_p`` are STATIC Python values (jit-baked, not traced).
    top_k <= 0 disables the k filter; top_p >= 1 disables the nucleus
    filter. The nucleus keeps the smallest prefix of probability-sorted
    tokens whose cumulative mass reaches ``top_p`` (the token that crosses
    the threshold is kept). Returns masked logits suitable for
    ``jax.random.categorical``."""
    if temperature <= 0.0:
        raise ValueError("filtered_logits needs temperature > 0; use "
                         "sample_greedy for temperature 0")
    s = logits.astype(jnp.float32) / temperature
    V = s.shape[-1]
    if top_k and top_k < V:
        kth = jax.lax.top_k(s, top_k)[0][..., -1:]
        s = jnp.where(s < kth, NEG_INF, s)
    if top_p < 1.0:
        probs = jax.nn.softmax(s, axis=-1)
        sp = jnp.sort(probs, axis=-1)[..., ::-1]            # descending
        csum = jnp.cumsum(sp, axis=-1)
        # mass strictly before each sorted slot; keep while it is < top_p
        before = csum - sp
        keep_sorted = before < top_p
        n_keep = keep_sorted.sum(axis=-1, keepdims=True)    # >= 1 always
        order = jnp.argsort(-probs, axis=-1)
        ranks = jnp.argsort(order, axis=-1)                 # rank per token
        s = jnp.where(ranks < n_keep, s, NEG_INF)
    return s


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """One token per batch slot. logits: (B, V); key: (B, 2) uint32 —
    one PRNG key row per slot, so each request's randomness depends only
    on its own key stream, never on batch composition. temperature <= 0
    is greedy (key unused)."""
    if temperature <= 0.0:
        return sample_greedy(logits)
    f = filtered_logits(logits, temperature=temperature, top_k=top_k,
                        top_p=top_p)
    return jax.vmap(jax.random.categorical)(key, f).astype(jnp.int32)


def split_keys(keys, n: int):
    """Row-wise ``jax.random.split``: (B, 2) -> (B, n, 2)."""
    return jax.vmap(lambda k: jax.random.split(k, n))(keys)


def spec_accept(logits, draft, draft_len, keys, *, temperature: float = 0.0,
                top_k: int = 0, top_p: float = 1.0, pad_id: int = 0):
    """Leftover/rejection sampling over one verify pass (DESIGN.md SS14).

    logits: (B, C, V) — row j is the target distribution for the token
    AFTER feeding window token j, where the fed window is
    ``[t_last, d_1 .. d_{C-1}]`` (so rows 0..C-2 score the draft tokens
    and row ``draft_len`` is the correction/bonus distribution).
    draft: (B, C-1) proposed tokens (col j verifies against row j);
    draft_len: (B,) valid proposals per slot (<= C-1); keys: (B, 2)
    uint32 per-slot PRNG keys.

    Accept rule (one-hot draft q): token d_j is accepted with probability
    p_j(d_j) given every earlier proposal accepted; at temperature 0 this
    is ``d_j == argmax(p_j)``. The first rejection at position r emits a
    token from the leftover distribution (p_r with d_r zeroed,
    renormalized); full acceptance emits a bonus token from row
    ``draft_len``. Either way exactly ``n_acc + 1`` tokens come out.

    Returns (out (B, C) int32 [accepted drafts, corrected/bonus, pads],
    n_acc (B,) int32, new_keys (B, 2))."""
    B, C, V = logits.shape
    K = C - 1
    draft = jnp.asarray(draft, jnp.int32)
    draft_len = jnp.asarray(draft_len, jnp.int32)
    jK = jnp.arange(K)[None, :]                              # (1, K)
    live = jK < draft_len[:, None]                           # real proposals

    if temperature <= 0.0:
        tgt = sample_greedy(logits)                          # (B, C)
        ok = (draft == tgt[:, :K]) & live if K else jnp.zeros((B, 0), bool)
        n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        corr = jnp.take_along_axis(tgt, n_acc[:, None], axis=1)[:, 0]
        new_keys = keys
    else:
        f = filtered_logits(logits, temperature=temperature, top_k=top_k,
                            top_p=top_p)
        probs = jax.nn.softmax(f, axis=-1)                   # (B, C, V)
        sub = split_keys(keys, 3)                            # (B, 3, 2)
        k_u, k_s, new_keys = sub[:, 0], sub[:, 1], sub[:, 2]
        if K:
            u = jax.vmap(lambda k: jax.random.uniform(k, (K,)))(k_u)
            p_d = jnp.take_along_axis(probs[:, :K], draft[..., None],
                                      axis=-1)[..., 0]       # (B, K)
            ok = (u < p_d) & live
        else:
            ok = jnp.zeros((B, 0), bool)
        n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        row_p = jnp.take_along_axis(probs, n_acc[:, None, None],
                                    axis=1)[:, 0]            # (B, V)
        rejected = n_acc < draft_len                         # vs full accept
        if K:
            d_rej = jnp.take_along_axis(
                draft, jnp.minimum(n_acc, K - 1)[:, None], axis=1)[:, 0]
            onehot = jax.nn.one_hot(d_rej, V, dtype=row_p.dtype)
            leftover = jnp.where(rejected[:, None],
                                 row_p * (1.0 - onehot), row_p)
        else:
            leftover = row_p
        # categorical is scale-invariant: no renormalization needed
        lg = jnp.where(leftover > 0, jnp.log(jnp.maximum(leftover, 1e-38)),
                       NEG_INF)
        corr = jax.vmap(jax.random.categorical)(k_s, lg).astype(jnp.int32)

    jC = jnp.arange(C)[None, :]                              # (1, C)
    drafts_padded = jnp.concatenate(
        [draft, jnp.full((B, 1), pad_id, jnp.int32)], axis=1)
    out = jnp.where(jC < n_acc[:, None], drafts_padded,
                    jnp.where(jC == n_acc[:, None], corr[:, None],
                              jnp.int32(pad_id)))
    return out.astype(jnp.int32), n_acc.astype(jnp.int32), new_keys
