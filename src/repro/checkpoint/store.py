"""Sharded, atomic, resharding-on-restore checkpointing (pure numpy+json).

Fault-tolerance contract:
* atomic: written to ``step_<N>.tmp`` then renamed — a killed writer never
  corrupts the latest checkpoint;
* restartable: ``restore_checkpoint(dir)`` loads the newest complete step;
* reshardable: leaves are stored unsharded (host gather) with the pytree
  encoded in the manifest — restore works under ANY mesh whose named-axis
  shardings are then applied by the caller (elastic world-size change);
* async: ``save_checkpoint(..., block=False)`` hands the host copy to a
  writer thread so the train loop keeps stepping;
* bounded: ``keep`` newest checkpoints survive GC.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_SEP = "__"

# numpy .npz cannot round-trip ml_dtypes (bfloat16 etc.): store raw bits
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str):
    if dtype_name in _BITCAST:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir, step: int, tree, *, keep: int = 3,
                    block: bool = True) -> threading.Thread:
    """Write ``tree`` (params/opt/rng/step...) for ``step`` atomically."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    # host-gather BEFORE handing to the writer thread (device buffers may
    # be donated/overwritten by the next step)
    host_flat = {}
    dtype_names = {}
    for k, v in _flatten(tree).items():
        arr, name = _encode(np.asarray(jax.device_get(v)))
        host_flat[k] = arr
        dtype_names[k] = name
    treedef = jax.tree_util.tree_structure(tree)

    def write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz", **host_flat)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(host_flat),
            "shapes": {k: list(v.shape) for k, v in host_flat.items()},
            "dtypes": dtype_names,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        # GC old checkpoints
        steps = sorted(_complete_steps(ckpt_dir))
        for s in steps[:-keep]:
            shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if block:
        t.join()
    return t


def _complete_steps(ckpt_dir: pathlib.Path):
    for p in ckpt_dir.glob("step_*"):
        if p.suffix == ".tmp" or not (p / "manifest.json").exists():
            continue
        try:
            yield int(p.name.split("_")[1])
        except ValueError:
            continue


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = list(_complete_steps(ckpt_dir))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, like, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays/specs).

    ``shardings``: optional pytree of NamedSharding — leaves are device_put
    with them (resharding across a different mesh 'just works')."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step}" / "leaves.npz")
    manifest = json.loads(
        (ckpt_dir / f"step_{step}" / "manifest.json").read_text())
    dtype_names = manifest["dtypes"]
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    flat_shard = _flatten(shardings) if shardings is not None else {}

    def rebuild(tree_like):
        leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
        rebuilt = []
        for path, leaf in leaves_paths[0]:
            key = _SEP.join(
                str(p.key) if isinstance(p, jax.tree_util.DictKey)
                else str(p.idx) for p in path)
            arr = _decode(data[key], dtype_names.get(key, ""))
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            if arr.dtype != want_dtype:
                arr = arr.astype(want_dtype)
            if key in flat_shard and flat_shard[key] is not None:
                arr = jax.device_put(arr, flat_shard[key])
            rebuilt.append(arr)
        return jax.tree_util.tree_unflatten(leaves_paths[1], rebuilt)

    return rebuild(like), step
