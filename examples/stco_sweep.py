"""System-Technology Co-Optimization sweep (the paper's methodology,
extended beyond the paper): for EVERY architecture in the assigned pool,
find the cheapest memory technology configuration that reaches 10 TPS
interactivity at batch 1.

Run: PYTHONPATH=src python examples/stco_sweep.py
"""
from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.core import (all_hbs, ddr_only, hbs, lpddr6, npu_hierarchy,
                        qkv_in_ddr, run_inference)

CANDIDATES = [
    # (label, relative cost rank, hierarchy factory, placement)
    ("LPDDR6 only", 0,
     lambda: npu_hierarchy(lpddr6(173.0)), ddr_only()),
    ("3x LPDDR6 only", 1,
     lambda: npu_hierarchy(lpddr6(520.0)), ddr_only()),
    ("LPDDR6 + HBS512/10us (qkv-in-ddr)", 2,
     lambda: npu_hierarchy(lpddr6(173.0), hbs(512.0, 10.0)), qkv_in_ddr()),
    ("3xLPDDR6 + HBS512/10us (qkv-in-ddr)", 3,
     lambda: npu_hierarchy(lpddr6(520.0), hbs(512.0, 10.0)), qkv_in_ddr()),
]

print(f"{'arch':22s} {'params':>8s}  cheapest config reaching 10 TPS "
      f"(prefill/decode 512/512)")
for arch in ASSIGNED_ARCHS + PAPER_ARCHS:
    cfg = get_config(arch)
    fit_label, fit_tps = "NONE (needs faster memory)", 0.0
    for label, _, mk_hier, place in CANDIDATES:
        hier = mk_hier()
        # DDR-only candidates must actually hold the model
        weights = cfg.n_params() * 2
        ddr_cap = hier.level("ddr").capacity
        if "only" in label and weights > ddr_cap:
            continue
        rep = run_inference(cfg, hier, place, 512, 512, n_samples=5)
        if rep.tps >= 10.0:
            fit_label, fit_tps = label, rep.tps
            break
    print(f"{arch:22s} {cfg.n_params()/1e9:7.1f}B  {fit_label} "
          f"{'(TPS %.1f)' % fit_tps if fit_tps else ''}")
