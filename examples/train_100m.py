"""End-to-end driver: train a ~100M-param qwen-family model for a few
hundred steps on the synthetic pipeline, with checkpoint/restart.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300]
(On this CPU container a ~100M model steps slowly; --tiny uses a ~10M
model with identical plumbing.)
"""
import argparse
import shutil

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train100m")
ap.add_argument("--fresh", action="store_true")
args = ap.parse_args()

base = get_config("qwen2.5-3b")
if args.tiny:
    cfg = reduced(base, d_model=128, n_layers=4, vocab=2048)
else:
    # ~100M params: 12 layers x d=512, 16k vocab, GQA 8:2
    cfg = base.replace(n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
                       head_dim=64, d_ff=2048, vocab=16384, max_context=1024)
print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")

if args.fresh:
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

tcfg = TrainConfig(
    steps=args.steps, seq_len=256, global_batch=8, n_micro=2,
    ckpt_every=50, ckpt_dir=args.ckpt_dir, log_every=10,
    optimizer=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))
out = train(cfg, tcfg, RuntimeOptions(dtype="float32"))
print(f"done: loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f} over "
      f"{out['last_step']} steps (resume-capable: rerun to continue)")
assert out["final_loss"] < out["losses"][0], "loss did not decrease"
