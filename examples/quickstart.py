"""Quickstart: the paper's analytical model in 30 lines.

Reproduces Table I (LLaVa-1.5-13B, prefill/decode 200/200) and shows the
STCO loop: pick a memory technology + placement -> predicted TPS.

Run: PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import (all_hbs, chiplet_qkv, hbs, lpddr6, npu_hierarchy,
                        qkv_in_ddr, run_inference, sram_chiplet)

cfg = get_config("llava15-13b")
print(f"model: {cfg.name}  params={cfg.n_params()/1e9:.1f}B  "
      f"KV/token={cfg.kv_bytes_per_token()/1e3:.0f} KB")

print("\n--- paper Table I (HBS latency 10 us) ---")
rows = [
    ("I   LPDDR6 + HBS@173GB/s, all in HBS", 173.0, 173.0, all_hbs()),
    ("II  LPDDR6 + HBS@520GB/s, all in HBS", 173.0, 520.0, all_hbs()),
    ("II' 3xDDR  + HBS@512GB/s, all in HBS", 520.0, 512.0, all_hbs()),
    ("III 3xDDR  + HBS@512GB/s, Q/K/V in DDR", 520.0, 512.0, qkv_in_ddr()),
]
for label, ddr_bw, hbs_bw, place in rows:
    hier = npu_hierarchy(lpddr6(ddr_bw), hbs(hbs_bw, latency_us=10.0))
    rep = run_inference(cfg, hier, place, prefill_len=200, decode_len=200)
    print(f"{label:42s} TPS={rep.tps:5.2f}  bottleneck={rep.bottleneck}")

print("\n--- chiplet study (Llama-3.2-1B, 128/384) ---")
small = get_config("llama3.2-1b")
h = npu_hierarchy(lpddr6(173.0, latency_ns=500.0),
                  chiplet=sram_chiplet(512.0))
rep = run_inference(small, h, chiplet_qkv(), 128, 384)
print(f"chiplet-QKV @512GB/s: TPS={rep.tps:.1f} "
      f"(attention {rep.decode_group_share('attn')[1]*100:.0f}% of GEMM "
      f"time -> limited gain, paper takeaway IV)")
