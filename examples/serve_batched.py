"""End-to-end serving: batched greedy decode with native vs int8 tiered KV.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, init_params
from repro.serving import ServeEngine

cfg = reduced(get_config("llama3.2-1b"), d_model=256, n_layers=6, vocab=4096)
opts = RuntimeOptions(dtype="float32")
params = init_params(cfg, jax.random.PRNGKey(0), opts)

prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 1, cfg.vocab)

for policy in ("native", "int8"):
    eng = ServeEngine(cfg, params, opts, kv_policy=policy, max_len=256)
    outs = eng.generate(jnp.asarray(prompts), 64)
    s = eng.stats
    print(f"kv={policy:7s} prefill={s.prefill_s*1e3:6.0f}ms "
          f"decode={s.decode_s*1e3:6.0f}ms TPS={s.tps:7.1f} "
          f"sample={outs[0][:8]}")

print("\nragged requests via bucketing:")
eng = ServeEngine(cfg, params, opts, max_len=256)
reqs = [[1, 2, 3]] * 2 + [[5, 6, 7, 8, 9, 10]] * 3
outs = eng.serve_bucketed(reqs, 8)
print(f"{len(outs)} responses, lens={[len(o) for o in outs]}, "
      f"aggregate TPS={eng.stats.tps:.1f}")

print("\nsame ragged requests, continuous batching over the paged KV pool:")
ceng = ServeEngine(cfg, params, opts, max_len=256, scheduler="continuous",
                   page_size=16, max_batch=8)
couts = ceng.serve(reqs, 8)
assert couts == outs          # token-identical, fewer decode steps
print(f"{len(couts)} responses, decode steps "
      f"{ceng.stats.decode_steps} (vs {eng.stats.decode_steps} static), "
      f"aggregate TPS={ceng.stats.tps:.1f}")
