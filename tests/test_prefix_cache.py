"""Shared-prefix KV page reuse + chunked prefill (DESIGN.md SS11).

Covers the chunk-prefill kernel vs its jnp oracle, manager refcount /
COW / eviction invariants (incl. a hypothesis property test), chunked
scheduling, and engine-level equivalence: prefix cache on vs off is
token-identical under the native kv_policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.decode_attention as da
import repro.kernels.ref as ref
from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.core import kv_dedup_factor, max_concurrency_without_spill
from repro.models import RuntimeOptions, init_params
from repro.serving import (ContinuousScheduler, PageAllocationError,
                           PagedKVManager, Request, ServeEngine)
from repro.serving.scheduler import PREFILLING, RUNNING


# ----------------------- chunk-prefill kernel -------------------------- #

@pytest.mark.parametrize("B,H,Hkv,dh,ps,C,start,real", [
    (1, 8, 2, 64, 16, 32, 0, 32),      # first chunk, GQA
    (1, 4, 1, 128, 16, 32, 32, 20),    # later chunk with right-padding, MQA
    (2, 4, 4, 64, 8, 16, 8, 16),       # MHA, mid-page grid skipping
])
def test_chunk_kernel_matches_oracle(B, H, Hkv, dh, ps, C, start, real):
    """Acceptance: the chunk-prefill Pallas kernel matches the jnp oracle
    in interpret mode."""
    npp = (start + C) // ps + 2
    P = B * npp + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, dh), jnp.float32)
    perm = np.asarray(jax.random.permutation(ks[0], P - 1)) + 1
    pt = jnp.asarray(perm[:B * npp].reshape(B, npp), jnp.int32)
    nv = jnp.full((B,), start + real, jnp.int32)
    out = da.chunk_prefill_attention(q, kp, vp, pt, start, nv,
                                     interpret=True)
    want = ref.chunk_prefill_attention_ref(q, kp, vp, pt, start, nv,
                                           scale=dh ** -0.5)
    np.testing.assert_allclose(out[:, :real], want[:, :real],
                               atol=1e-5, rtol=1e-5)


def test_chunk_kernel_int8():
    """Acceptance: int8 path within quantization tolerance of the fp ref."""
    B, C, H, Hkv, dh, ps, npp = 1, 16, 8, 2, 64, 32, 3
    P = npp + 2
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, dh), jnp.float32)
    pt = jnp.asarray([[2, 3, 1]], jnp.int32)
    start, nv = 32, jnp.asarray([48], jnp.int32)
    ki, vi, ksc, vsc = da.quantize_kv(kp, vp)
    out = da.chunk_prefill_attention(q, ki, vi, pt, start, nv, k_scale=ksc,
                                     v_scale=vsc, interpret=True)
    want = ref.chunk_prefill_attention_ref(q, ki, vi, pt, start, nv,
                                           scale=dh ** -0.5,
                                           k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    fp = ref.chunk_prefill_attention_ref(q, kp, vp, pt, start, nv,
                                         scale=dh ** -0.5)
    assert float(jnp.max(jnp.abs(out - fp))) < 0.05


@pytest.mark.parametrize("L,block_kv", [(100, 64), (97, 512), (130, 128)])
def test_decode_attention_non_multiple_block(L, block_kv):
    """Satellite: L not a multiple of block_kv no longer crashes — the KV
    tail is padded (and masked), keeping lane-aligned blocks even for
    prime L."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 4, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (2, L, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, L, 2, 64), jnp.float32)
    lens = jnp.asarray([7, L], jnp.int32)
    out = da.decode_attention(q, kc, vc, lens, block_kv=block_kv,
                              interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens, scale=64 ** -0.5)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


# ------------------------- manager: refcounts -------------------------- #

def _brute_used(kv):
    return len({p for sid in list(kv._seqs) for p in kv.seq_pages(sid)})


def _pool_ok(kv):
    assert kv.n_free + kv.n_evictable + kv.n_used == kv.n_pages - 1
    assert kv.n_used == _brute_used(kv)        # O(1) counter stays exact
    for sid in list(kv._seqs):
        for p in kv.seq_pages(sid):
            assert kv.page_ref(p) >= 1


def test_refcounted_sharing_and_eviction():
    kv = PagedKVManager(n_pages=16, page_size=4, enable_prefix_cache=True)
    doc = list(range(100, 112))                    # 3 full pages
    a = kv.allocate_shared(0, doc + [1, 2], reserve_tokens=16)
    assert a.n_cached == 0 and kv.n_used == 4
    kv.register_prefix(0, doc + [1, 2])            # indexes the 3 doc pages
    b = kv.allocate_shared(1, doc + [7, 8], reserve_tokens=16)
    assert b.n_cached == 12                        # full-page reuse
    assert b.pages[:3] == a.pages[:3]
    assert all(kv.page_ref(p) == 2 for p in a.pages[:3])
    assert kv.n_used == 5                          # 3 shared + 2 private
    _pool_ok(kv)

    kv.free_seq(0)                   # shared pages still held by seq 1
    assert kv.n_evictable == 0 and kv.n_used == 4
    _pool_ok(kv)
    kv.free_seq(1)                   # cached doc pages become evictable
    assert kv.n_evictable == 3 and kv.n_used == 0
    _pool_ok(kv)
    c = kv.allocate_shared(2, doc + [9], reserve_tokens=16)
    assert c.n_cached == 12                        # revived from evictable
    assert kv.n_evictable == 0 and kv.page_ref(c.pages[0]) == 1
    _pool_ok(kv)

    kv.free_seq(2)
    assert kv.n_used == 0 and kv.n_evictable == 3  # doc stays cached
    # pressure reclaims evictable pages LRU (no leak, index dropped)
    kv.allocate(9, 15 * 4)                         # whole pool
    assert kv.n_evictable == 0 and kv.evictions == 3
    assert not kv._index
    _pool_ok(kv)


def test_cow_on_shared_page_write():
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    doc = list(range(50, 58))                      # 2 full pages
    kv.allocate_shared(0, doc + [1])
    kv.register_prefix(0, doc + [1])
    kv.allocate_shared(1, doc + [2])
    shared = kv.seq_pages(0)[0]
    assert kv.page_ref(shared) == 2
    # seq 1 must not write into the shared page in place
    pair = kv.ensure_writable(1, 0)
    assert pair is not None and pair[0] == shared
    assert kv.seq_pages(1)[0] == pair[1] != shared
    assert kv.page_ref(shared) == 1 and kv.page_ref(pair[1]) == 1
    assert kv.seq_pages(0)[0] == shared            # owner untouched
    assert kv.drain_copies() == [pair]
    _pool_ok(kv)
    # exclusive-but-cached page: unregistered instead of copied
    assert kv.ensure_writable(0, 0) is None
    assert not kv.is_cached(kv.seq_pages(0)[0])
    _pool_ok(kv)


def test_partial_page_cow_match():
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    donor = [9, 9, 9, 9, 5, 6, 7, 8]               # 2 full pages
    kv.allocate_shared(0, donor + [1])
    kv.register_prefix(0, donor + [1])
    # matches page 0 fully, page 1 up to 2 tokens -> COW of page 1
    req = [9, 9, 9, 9, 5, 6, 70, 80, 3]
    b = kv.allocate_shared(1, req)
    assert b.n_cached == 6 and kv.cow_copies == 1
    src_dst = kv.drain_copies()
    assert src_dst == [(kv.seq_pages(0)[1], kv.seq_pages(1)[1])]
    assert kv.seq_pages(1)[0] == kv.seq_pages(0)[0]     # full page shared
    assert kv.seq_pages(1)[1] != kv.seq_pages(0)[1]     # partial is private
    _pool_ok(kv)


def test_identical_prompt_caps_last_token():
    """A fully-cached prompt still recomputes its final token (partial COW
    of the last page when the divergence is mid-page)."""
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    p = list(range(30, 38))                        # exactly 2 pages
    kv.allocate_shared(0, p)
    kv.register_prefix(0, p, n_valid=8)
    b = kv.allocate_shared(1, p)
    assert b.n_cached == 7                         # 1 full page + 3 via COW
    assert kv.cow_copies == 1
    _pool_ok(kv)


def test_append_token_into_shared_page_cows():
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    kv.allocate(0, 6)                              # 2 pages, 6 tokens
    kv.register_prefix(0, list(range(6)), n_valid=4)
    kv.allocate_shared(1, list(range(6)))          # shares page 0
    # force seq 1's tracked length onto the shared page boundary write
    last = kv.seq_pages(0)[0]
    kv._seqs[1].pages[1] = kv._seqs[1].pages[1]    # (layout unchanged)
    kv._seqs[1].n_tokens = 3                       # next write -> page 0
    before = kv.seq_pages(1)[0]
    assert kv.page_ref(before) == 2
    kv.append_token(1)
    after = kv.seq_pages(1)[0]
    assert after != before and kv.page_ref(before) == 1
    assert kv.drain_copies() == [(before, after)]
    assert last == before
    _pool_ok(kv)


def test_hypothesis_refcounted_pool_never_leaks():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.integers(0, 4), st.integers(0, 7),
                             st.integers(1, 30)), min_size=1, max_size=60)

    @settings(max_examples=60, deadline=None)
    @given(ops=ops, data=st.data())
    def run(ops, data):
        kv = PagedKVManager(n_pages=12, page_size=4,
                            enable_prefix_cache=True)
        for kind, sid, n in ops:
            alive = sid in kv._seqs
            try:
                if kind == 0 and not alive:
                    # tiny alphabet -> frequent shared prefixes
                    toks = data.draw(st.lists(st.integers(1, 3),
                                              min_size=1, max_size=20))
                    kv.allocate_shared(sid, toks)
                    kv._tokens = getattr(kv, "_tokens", {})
                    kv._tokens[sid] = toks
                elif kind == 1 and alive:
                    kv.append_token(sid)
                    kv._tokens[sid].append(data.draw(st.integers(1, 3)))
                elif kind == 2 and alive:
                    kv.register_prefix(sid, kv._tokens[sid])
                elif kind == 3 and alive:
                    kv.free_seq(sid)
                elif kind == 4 and alive:
                    kv.ensure_writable(sid, n % kv.seq_len(sid))
            except PageAllocationError:
                pass
            _pool_ok(kv)
        for sid in list(kv._seqs):
            kv.free_seq(sid)
        assert kv.n_used == 0                      # no leak, no double-free
        assert kv.n_free + kv.n_evictable == kv.n_pages - 1

    run()


# ------------------------ scheduler: chunking -------------------------- #

def test_scheduler_chunked_admit_and_budget():
    kv = PagedKVManager(64, 4, enable_prefix_cache=True)
    sched = ContinuousScheduler(kv, 4, prefill_chunk=8, prefill_budget=8)
    sched.submit(Request(rid=0, prompt=list(range(1, 20)), max_new_tokens=4))
    (slot, req), = sched.admit()
    assert req.state == PREFILLING and req.n_prefilled == 0
    assert sched.prefilling() == [(slot, req)]
    assert not sched.running()
    sched.finish_prefill(slot)
    assert req.state == RUNNING and sched.running() == [(slot, req)]
    with pytest.raises(ValueError):
        ContinuousScheduler(kv, 4, prefill_chunk=8, prefill_budget=4)


def test_scheduler_defers_shared_prefix_admission():
    kv = PagedKVManager(64, 4, enable_prefix_cache=True)
    sched = ContinuousScheduler(kv, 4, prefill_chunk=8)
    doc = [7] * 12
    a = Request(rid=0, prompt=doc + [1], max_new_tokens=2)
    b = Request(rid=1, prompt=doc + [2], max_new_tokens=2)
    sched.submit(a)
    sched.submit(b)
    assert len(sched.admit()) == 1                 # b waits for a's prefill
    assert sched.waiting and sched.waiting[0] is b
    a.n_prefilled = 13
    kv.register_prefix(0, a.prefill_tokens, n_valid=13)
    sched.finish_prefill(0)
    admitted = sched.admit()                       # prefix cached -> join
    assert len(admitted) == 1 and admitted[0][1] is b
    assert b.n_prefilled == 12                     # hit the 3 doc pages


# ----------------------- engine: end-to-end ---------------------------- #

@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def _shared_reqs(cfg, n=4, doc_len=17, q_len=4, seed=2):
    rng = np.random.default_rng(seed)
    doc = rng.integers(1, cfg.vocab, size=doc_len).tolist()
    return [doc + rng.integers(1, cfg.vocab, size=q_len).tolist()
            for _ in range(n)]


def test_prefix_cache_token_identical(small_model):
    """Acceptance: with kv_policy='native', outputs are token-identical
    with the prefix cache on vs off — and match the static engine."""
    cfg, opts, params = small_model
    reqs = _shared_reqs(cfg)
    want = ServeEngine(cfg, params, opts, max_len=40).serve(
        [r[:] for r in reqs], 6)
    outs, stats = {}, {}
    for pc in (False, True):
        eng = ServeEngine(cfg, params, opts, max_len=40,
                          scheduler="continuous", page_size=4, max_batch=4,
                          prefix_cache=pc, prefill_chunk=8)
        outs[pc] = eng.serve([r[:] for r in reqs], 6)
        stats[pc] = eng.stats
        assert eng.kv_manager.n_used == 0
    assert outs[False] == outs[True] == want
    # acceptance: >=30% fewer prefill tokens and fewer resident pages
    base = stats[False].prefill_tokens_computed
    assert stats[True].prefill_tokens_computed <= 0.7 * base
    assert stats[True].peak_pages_used < stats[False].peak_pages_used
    assert stats[True].pages_deduped > 0


@pytest.mark.slow
def test_cow_divergence_token_identical(small_model):
    """Mid-page divergence goes through COW and stays correct."""
    cfg, opts, params = small_model
    rng = np.random.default_rng(5)
    doc = rng.integers(1, cfg.vocab, size=12).tolist()
    reqs = [doc[:10], doc[:9] + [99, 98, 97]]      # diverge mid page (ps=4)
    want = ServeEngine(cfg, params, opts, max_len=40).serve(
        [r[:] for r in reqs], 6)
    eng = ServeEngine(cfg, params, opts, max_len=40, scheduler="continuous",
                      page_size=4, max_batch=1, prefix_cache=True,
                      prefill_chunk=8)
    assert eng.serve([r[:] for r in reqs], 6) == want
    assert eng.stats.cow_copies >= 1
    assert eng.stats.cached_prefix_tokens >= 9


def test_identical_prompts_share_all_but_last(small_model):
    cfg, opts, params = small_model
    rng = np.random.default_rng(6)
    p = rng.integers(1, cfg.vocab, size=16).tolist()
    reqs = [p[:] for _ in range(3)]
    want = ServeEngine(cfg, params, opts, max_len=40).serve(
        [r[:] for r in reqs], 6)
    eng = ServeEngine(cfg, params, opts, max_len=40, scheduler="continuous",
                      page_size=4, max_batch=4, prefix_cache=True,
                      prefill_chunk=8)
    assert eng.serve([r[:] for r in reqs], 6) == want
    assert eng.stats.cached_prefix_tokens == 2 * 15  # all but the last token


@pytest.mark.slow
def test_preempt_readmit_hits_cache(small_model):
    """A preemption victim's registered pages serve its own re-admission."""
    cfg, opts, params = small_model
    rng = np.random.default_rng(7)
    reqs = [rng.integers(1, cfg.vocab, size=8).tolist() for _ in range(2)]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(
        [r[:] for r in reqs], 12)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=4, max_batch=2, n_pages=8,
                      prefix_cache=True, prefill_chunk=8)
    assert eng.serve([r[:] for r in reqs], 12) == want
    assert eng.stats.preemptions >= 1
    assert eng.stats.cached_prefix_tokens > 0      # re-admit reused pages


@pytest.mark.slow
def test_chunked_prefill_compiles_once(small_model):
    """Acceptance: one jitted prefill for many distinct prompt lengths."""
    cfg, opts, params = small_model
    rng = np.random.default_rng(8)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (3, 5, 7, 9, 11, 13, 17, 21)]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(
        [r[:] for r in reqs], 4)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=8, max_batch=4, prefix_cache=False)
    assert eng.serve([r[:] for r in reqs], 4) == want
    assert eng.stats.prefill_compiles == 1


def test_chunked_prefill_interleaves_decode(small_model):
    """A long admission must not stall in-flight decodes: decode steps run
    between its chunks (the prefill budget bounds per-step prefill work)."""
    cfg, opts, params = small_model
    rng = np.random.default_rng(9)
    short = rng.integers(1, cfg.vocab, size=4).tolist()
    long = rng.integers(1, cfg.vocab, size=24).tolist()
    eng = ServeEngine(cfg, params, opts, max_len=40, scheduler="continuous",
                      page_size=4, max_batch=2, prefix_cache=False,
                      prefill_chunk=8, prefill_budget=8)
    want = ServeEngine(cfg, params, opts, max_len=40).serve(
        [short[:], long[:]], 8)
    assert eng.serve([short[:], long[:]], 8) == want
    # the 24-token prompt takes 3 chunks; the short request decodes during
    # them, so decode steps exceed what a post-prefill-only schedule needs
    assert eng.stats.decode_steps >= 8


def test_stats_percentiles(small_model):
    cfg, opts, params = small_model
    reqs = _shared_reqs(cfg, n=3)
    eng = ServeEngine(cfg, params, opts, max_len=40, scheduler="continuous",
                      page_size=8, max_batch=4)
    eng.serve([r[:] for r in reqs], 6)
    s = eng.stats
    assert len(s.ttft) == 3 and len(s.itl) == 3 * 5
    assert s.ttft_p95 >= s.ttft_p50 > 0
    assert s.itl_p95 >= s.itl_p50 > 0


# ---------------------- analytical sharing model ----------------------- #

def test_kv_dedup_factor():
    assert kv_dedup_factor(8, 1000, 0, shared_prefix_len=0) == 1.0
    assert kv_dedup_factor(8, 1000, 0, share_group=1,
                           shared_prefix_len=500) == 1.0
    f = kv_dedup_factor(8, 1000, 0, shared_prefix_len=1000, share_group=8)
    assert f == pytest.approx(1 / 8)
    # monotone in the share factor
    fs = [kv_dedup_factor(8, 1000, 200, shared_prefix_len=800, share_group=g)
          for g in (1, 2, 4, 8)]
    assert fs == sorted(fs, reverse=True) and fs[0] == 1.0


def test_sharing_raises_no_spill_concurrency():
    """Acceptance: predicted max concurrency before spill increases with
    the share factor."""
    from repro.core import hbs, lpddr6, npu_hierarchy, qkv_in_ddr
    cfg = get_config("llama3.2-1b")
    hier = npu_hierarchy(lpddr6(520.0, capacity_gb=2.0),
                         hbs(64.0, latency_us=20.0))
    place = qkv_in_ddr()
    lims = [max_concurrency_without_spill(
        cfg, hier, place, prefill_len=2048, decode_len=256,
        shared_prefix_len=1536, share_group=g) for g in (1, 2, 4, 8)]
    assert lims == sorted(lims)
    assert lims[-1] > lims[0]