"""CFG builder unit tests (repro.analysis.cfg, DESIGN.md SS18).

Structural checks — exception edges, with-blocks, early returns, loops,
try/finally threading — plus path-walk properties: every ``iter_paths``
walk terminates, uses each edge at most once per path, and the union of
walked edges covers every edge reachable from ENTRY. The property test
runs over a deterministic corpus always, and over hypothesis-generated
programs when hypothesis is installed.
"""
import ast
import textwrap

import pytest

from repro.analysis.cfg import EXC, FALSE, NORMAL, TRUE, build_cfg


def _cfg(src):
    tree = ast.parse(textwrap.dedent(src))
    fn = tree.body[0]
    assert isinstance(fn, ast.FunctionDef)
    return build_cfg(fn)


def _node_at(cfg, line):
    for n in cfg.stmt_nodes():
        if n.line == line:
            return n
    raise AssertionError(f"no CFG node at line {line}")


def _edge_kinds(cfg, src_idx):
    return sorted(k for _, k in cfg.succ[src_idx])


# ----------------------------- basics ---------------------------------- #

def test_straight_line_chains_to_exit():
    cfg = _cfg("""
        def f():
            a = 1
            b = a + 1
            return b
    """)
    a = _node_at(cfg, 3)
    b = _node_at(cfg, 4)
    r = _node_at(cfg, 5)
    assert (b.idx, NORMAL) in cfg.succ[a.idx]
    assert (r.idx, NORMAL) in cfg.succ[b.idx]
    assert (cfg.exit, NORMAL) in cfg.succ[r.idx]


def test_if_else_true_false_edges_and_join():
    cfg = _cfg("""
        def f(x):
            if x:
                a = 1
            else:
                a = 2
            return a
    """)
    head = _node_at(cfg, 3)
    assert _edge_kinds(cfg, head.idx) == sorted([TRUE, FALSE])
    then = _node_at(cfg, 4)
    other = _node_at(cfg, 6)
    ret = _node_at(cfg, 7)
    assert (ret.idx, NORMAL) in cfg.succ[then.idx]
    assert (ret.idx, NORMAL) in cfg.succ[other.idx]


def test_early_return_skips_tail():
    cfg = _cfg("""
        def f(x):
            if x:
                return 1
            y = 2
            return y
    """)
    early = _node_at(cfg, 4)
    tail = _node_at(cfg, 5)
    assert (cfg.exit, NORMAL) in cfg.succ[early.idx]
    # the early return has no edge into the tail
    assert all(v != tail.idx for v, _ in cfg.succ[early.idx])
    # but the false branch of the if reaches it
    head = _node_at(cfg, 3)
    assert (tail.idx, FALSE) in cfg.succ[head.idx]


# ------------------------------ loops ----------------------------------- #

def test_while_loop_back_edge_and_exit():
    cfg = _cfg("""
        def f(n):
            i = 0
            while i < n:
                i += 1
            return i
    """)
    head = _node_at(cfg, 4)
    body = _node_at(cfg, 5)
    ret = _node_at(cfg, 6)
    assert (body.idx, TRUE) in cfg.succ[head.idx]
    assert (ret.idx, FALSE) in cfg.succ[head.idx]
    # back edge body -> head
    assert any(v == head.idx for v, k in cfg.succ[body.idx])


def test_while_true_has_no_false_edge():
    cfg = _cfg("""
        def f():
            while True:
                if g():
                    break
            return 1
    """)
    head = _node_at(cfg, 3)
    assert FALSE not in _edge_kinds(cfg, head.idx)
    # break still reaches the statement after the loop
    brk = _node_at(cfg, 5)
    ret = _node_at(cfg, 6)
    assert (ret.idx, NORMAL) in cfg.succ[brk.idx]


def test_for_loop_break_continue():
    cfg = _cfg("""
        def f(xs):
            total = 0
            for x in xs:
                if x < 0:
                    continue
                if x > 9:
                    break
                total += x
            return total
    """)
    head = _node_at(cfg, 4)
    cont = _node_at(cfg, 6)
    brk = _node_at(cfg, 8)
    ret = _node_at(cfg, 10)
    assert any(v == head.idx for v, _ in cfg.succ[cont.idx])
    assert (ret.idx, NORMAL) in cfg.succ[brk.idx]
    assert (ret.idx, FALSE) in cfg.succ[head.idx]


# --------------------------- with-blocks -------------------------------- #

def test_with_block_threads_body():
    cfg = _cfg("""
        def f(p):
            with open(p) as fh:
                data = fh.read()
            return data
    """)
    w = _node_at(cfg, 3)
    body = _node_at(cfg, 4)
    ret = _node_at(cfg, 5)
    assert (body.idx, NORMAL) in cfg.succ[w.idx]
    assert (ret.idx, NORMAL) in cfg.succ[body.idx]


# ---------------------- exceptions and finally -------------------------- #

def test_try_body_has_exc_edges_to_handler():
    cfg = _cfg("""
        def f():
            try:
                risky()
            except ValueError:
                fallback()
            return 1
    """)
    risky = _node_at(cfg, 4)
    handler = _node_at(cfg, 5)       # the `except ValueError:` head
    fb = _node_at(cfg, 6)
    assert (handler.idx, EXC) in cfg.succ[risky.idx]
    assert (fb.idx, NORMAL) in cfg.succ[handler.idx]
    ret = _node_at(cfg, 7)
    assert (ret.idx, NORMAL) in cfg.succ[fb.idx]


def test_uncaught_raise_goes_to_raise_exit():
    cfg = _cfg("""
        def f(x):
            if x:
                raise ValueError(x)
            return 0
    """)
    rs = _node_at(cfg, 4)
    assert any(v == cfg.raise_exit for v, _ in cfg.succ[rs.idx])
    # a raise never falls through to the next statement
    ret = _node_at(cfg, 5)
    assert all(v != ret.idx for v, _ in cfg.succ[rs.idx])


def test_handler_chain_unmatched_goes_outward():
    cfg = _cfg("""
        def f():
            try:
                risky()
            except KeyError:
                a()
            except ValueError:
                b()
            return 1
    """)
    risky = _node_at(cfg, 4)
    h1 = _node_at(cfg, 5)
    h2 = _node_at(cfg, 7)
    # the try body may land in either handler (type match is dynamic)
    assert (h1.idx, EXC) in cfg.succ[risky.idx]
    assert (h2.idx, EXC) in cfg.succ[risky.idx]
    # and each handler head can escape the function when nothing matches
    assert (cfg.raise_exit, EXC) in cfg.succ[h1.idx]
    assert (cfg.raise_exit, EXC) in cfg.succ[h2.idx]


def test_finally_runs_on_normal_and_exception_paths():
    cfg = _cfg("""
        def f():
            try:
                risky()
            finally:
                cleanup()
            return 1
    """)
    risky = _node_at(cfg, 4)
    fin = _node_at(cfg, 6)
    ret = _node_at(cfg, 7)
    reach_normal = cfg.reachable([risky.idx])
    assert fin.idx in reach_normal and ret.idx in reach_normal
    # the finally tail over-approximates: both the continuation and the
    # propagating-exception exit are reachable from cleanup()
    reach_fin = cfg.reachable([fin.idx])
    assert ret.idx in reach_fin


def test_return_inside_try_still_passes_finally():
    cfg = _cfg("""
        def f():
            try:
                return g()
            finally:
                cleanup()
    """)
    ret = _node_at(cfg, 4)
    fin = _node_at(cfg, 6)
    assert fin.idx in cfg.reachable([ret.idx])


# ------------------------- reachable() semantics ------------------------ #

def test_reachable_blocked_cuts_paths():
    cfg = _cfg("""
        def f(x):
            acquire()
            if x:
                release()
            done()
    """)
    acq = _node_at(cfg, 3)
    rel = _node_at(cfg, 5)
    succs = [v for v, _ in cfg.succ[acq.idx]]
    # with the release node removed, EXIT is still reachable (the
    # false branch leaks) — exactly the all_paths violation shape
    assert cfg.exit in cfg.reachable(succs, blocked={rel.idx})


def test_reachable_blocked_full_coverage():
    cfg = _cfg("""
        def f(x):
            acquire()
            if x:
                release()
            else:
                release()
            done()
    """)
    acq = _node_at(cfg, 3)
    rels = {_node_at(cfg, 5).idx, _node_at(cfg, 7).idx}
    succs = [v for v, _ in cfg.succ[acq.idx]]
    assert cfg.exit not in cfg.reachable(succs, blocked=rels)


# -------------------------- path-walk property -------------------------- #

_CORPUS = [
    """
    def f(x):
        if x:
            return 1
        return 2
    """,
    """
    def f(xs):
        t = 0
        for x in xs:
            if x < 0:
                continue
            if x > 9:
                break
            t += x
        return t
    """,
    """
    def f():
        try:
            a()
        except ValueError:
            b()
        except KeyError:
            c()
        finally:
            d()
        return 1
    """,
    """
    def f(n):
        i = 0
        while True:
            with lock():
                i += 1
            if i >= n:
                break
        return i
    """,
    """
    def f(x):
        try:
            if x:
                raise ValueError(x)
            return g()
        finally:
            cleanup()
    """,
]


def _assert_path_properties(cfg):
    walked_edges = set()
    n_paths = 0
    for path in cfg.iter_paths(max_paths=5000):
        n_paths += 1
        assert path[0] == cfg.entry
        seen = set()
        for u, v in zip(path, path[1:]):
            assert (u, v) not in seen, "edge used twice on one path"
            seen.add((u, v))
        # a path ends at EXIT/RAISE_EXIT, or when every outgoing edge of
        # its last node was already used (e.g. after a while-True back
        # edge consumed the only way forward)
        last = path[-1]
        assert last in (cfg.exit, cfg.raise_exit) or all(
            (last, v) in seen for v, _ in cfg.succ[last])
        walked_edges |= seen
    assert n_paths >= 1
    # every edge reachable from ENTRY appears on some walked path
    reachable = cfg.reachable([cfg.entry])
    for u, ks in cfg.succ.items():
        if u not in reachable and u != cfg.entry:
            continue
        for v, _ in ks:
            assert (u, v) in walked_edges, f"edge {u}->{v} never walked"


@pytest.mark.parametrize("src", _CORPUS)
def test_iter_paths_terminates_and_covers_edges(src):
    _assert_path_properties(_cfg(src))


def test_iter_paths_property_random_programs():
    """Hypothesis sweep over generated nests of if/while/for/try —
    skipped when hypothesis isn't installed (the deterministic corpus
    above always runs)."""
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (requirements-dev)")
    from hypothesis import given, settings, strategies as st

    def gen_block(depth):
        simple = st.sampled_from(["x = g()", "h(x)", "return x", "raise E()"])
        if depth == 0:
            return st.lists(simple, min_size=1, max_size=3)

        sub = gen_block(depth - 1)

        def fmt(body, head, tail=None):
            lines = [head] + ["    " + ln for ln in body]
            if tail:
                lines += tail
            return lines

        compound = st.one_of(
            sub.map(lambda b: fmt(b, "if c():")),
            sub.map(lambda b: fmt(b, "while c():")),
            sub.map(lambda b: fmt(b, "for i in xs:")),
            st.tuples(sub, sub).map(lambda bb: fmt(
                bb[0], "try:",
                ["except E:"] + ["    " + ln for ln in bb[1]])),
        )
        return st.lists(st.one_of(simple.map(lambda s: [s]), compound),
                        min_size=1, max_size=3).map(
            lambda blocks: [ln for b in blocks for ln in b])

    @given(gen_block(2))
    @settings(max_examples=40, deadline=None)
    def run(body_lines):
        src = "def f(x, xs):\n" + "\n".join(
            "    " + ln for ln in body_lines)
        _assert_path_properties(build_cfg(ast.parse(src).body[0]))

    run()
