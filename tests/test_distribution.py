"""Sharding rules, HLO analyzer, and a small-mesh dry-run integration test."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import hlo_analysis as ha
from repro.models import RuntimeOptions, init_cache, init_params
from repro.sharding import cache_pspecs, opt_state_pspec, param_pspecs

OPTS = RuntimeOptions()


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by the rules."""
    def __init__(self, **axes):
        self.shape = axes


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def _pspecs(arch, mesh=MESH, mode="fsdp"):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), OPTS))
    return cfg, param_pspecs(cfg, shapes, mesh, mode=mode), shapes


def test_dense_weight_tp_and_fsdp():
    cfg, specs, shapes = _pspecs("yi-6b")
    wq = specs["stack"]["attn"]["wq"]["w"]
    assert wq == P(None, None, "model") or wq == P(None, ("data",), "model")
    # d_model=4096 divides dp=16 -> fsdp shards the replicated dim
    assert "data" in str(wq)
    wo = specs["stack"]["attn"]["wo"]["w"]
    assert str(wo).count("model") == 1


def test_moe_expert_parallelism():
    cfg, specs, shapes = _pspecs("deepseek-v2-236b")
    w_up = specs["stack"]["moe"]["w_up"]
    # (layers, E, d, ff): experts (160) sharded over model
    assert w_up[1] == "model"


def test_vocab_sharding_and_tied_embed():
    cfg, specs, _ = _pspecs("gemma3-1b")
    emb = specs["embed"]["emb"]
    assert emb[0] == "model"          # 262144 % 16 == 0


def test_tp_mode_has_no_data_sharding():
    cfg, specs, _ = _pspecs("yi-6b", mode="tp")
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all("data" not in str(s) for s in leaves)


def test_opt_state_zero1_shards_replicated_dim():
    out = opt_state_pspec(P(None, "model"), (4096, 11008), MESH)
    assert out == P(("data",), "model")
    # already-fsdp param spec is left alone
    out2 = opt_state_pspec(P(("data",), "model"), (4096, 11008), MESH)
    assert out2 == P(("data",), "model")


def test_cache_heads_vs_length_sharding():
    cfg = get_config("zamba2-2.7b")      # 32 kv heads: shardable
    shapes = jax.eval_shape(lambda: init_cache(cfg, 128, 1024, OPTS))
    specs = cache_pspecs(cfg, shapes, MESH, 128)
    assert specs["attn"]["k"][3] == "model"
    cfg2 = get_config("qwen2.5-3b")      # kv=2 -> sequence sharding
    shapes2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 32768, OPTS))
    specs2 = cache_pspecs(cfg2, shapes2, MESH, 128)
    assert specs2["stack"]["k"][2] == "model"
    assert specs2["stack"]["k"][3] is None


def test_batch1_never_shards_batch():
    cfg = get_config("zamba2-2.7b")
    shapes = jax.eval_shape(lambda: init_cache(cfg, 1, 4096, OPTS))
    specs = cache_pspecs(cfg, shapes, MESH, 1)
    assert specs["attn"]["k"][1] is None


# --------------------------- HLO analyzer ------------------------------ #

def test_hlo_analyzer_counts_scan_trips():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    res = ha.analyze(jax.jit(f).lower(x, ws).compile().as_text())
    want = 2 * 128 * 256 * 256 * 10
    assert want <= res.flops <= want * 1.1


def test_hlo_analyzer_tuple_comment_types():
    """Result types with /*index=N*/ comments must still parse (the bug
    that silently dropped every while body in train graphs)."""
    def f(x):
        def body(c, _):
            a, b = c
            return (a @ b, b + 1.0), None
        (a, b), _ = jax.lax.scan(body, (x, x), None, length=5)
        return a + b
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    res = ha.analyze(jax.jit(f).lower(x).compile().as_text())
    want = 2 * 64 * 64 * 64 * 5
    assert res.flops >= want * 0.9


def test_hlo_analyzer_collectives():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(0, keepdims=True), NamedSharding(mesh, P()))
    # single-device: no collectives expected; just exercise the path
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    res = ha.analyze(jax.jit(f).lower(x).compile().as_text())
    assert res.collective_bytes == 0.0


# ----------------------- small-mesh dry-run ----------------------------- #

@pytest.mark.slow
def test_dryrun_cell_compiles_on_8_devices(tmp_path):
    """End-to-end: lower+compile a full-config decode cell on a small host
    mesh in a subprocess (proves build_cell works outside the 512-dev run)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import Mesh
from repro.launch import dryrun
from repro.models import RuntimeOptions
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    fn, args = dryrun.build_cell("qwen2.5-3b", "decode_32k", mesh,
                                 variant="tp", opts=RuntimeOptions())
    compiled = fn.lower(*args).compile()
    print("PEAK", compiled.memory_analysis().temp_size_in_bytes)
"""
    out = subprocess.run([sys.executable, "-c", code], env={
        **os.environ, "PYTHONPATH": "src"}, capture_output=True, text=True,
        timeout=560, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PEAK" in out.stdout
