"""Paged KV cache: kernel equivalence, manager/scheduler invariants, and
continuous-vs-static engine equivalence (DESIGN.md SS10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.decode_attention as da
import repro.kernels.ref as ref
from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, init_params
from repro.serving import (ContinuousScheduler, PageAllocationError,
                           PagedKVManager, Request, ServeEngine, TierBudget)


# --------------------------- kernel equivalence ------------------------ #

def _mk_pages(key, P, ps, Hkv, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    kp = jax.random.normal(ks[0], (P, ps, Hkv, dh), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32).astype(dtype)
    return kp, vp


def _disjoint_tables(key, B, npp, P):
    """Each sequence owns distinct pages (ids >= 1, page 0 reserved)."""
    perm = np.asarray(jax.random.permutation(key, P - 1)) + 1
    return jnp.asarray(perm[:B * npp].reshape(B, npp), jnp.int32)


@pytest.mark.parametrize("B,H,Hkv,dh,ps,npp", [
    (3, 8, 2, 64, 16, 8),        # GQA 4:1
    (2, 4, 1, 128, 32, 4),       # MQA
    (4, 4, 4, 64, 8, 6),         # MHA, small pages
])
def test_paged_matches_dense_kernel_ragged(B, H, Hkv, dh, ps, npp):
    """Acceptance: paged == dense kernel to <=1e-5 (f32) on ragged batches."""
    P = B * npp + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp, vp = _mk_pages(ks[1], P, ps, Hkv, dh)
    pt = _disjoint_tables(ks[2], B, npp, P)
    L = npp * ps
    lens = jax.random.randint(ks[3], (B,), 1, L + 1)

    paged = da.paged_decode_attention(q, kp, vp, pt, lens, interpret=True)
    # dense kernel over the gathered cache must agree
    kd = ref.gather_pages(kp, pt)
    vd = ref.gather_pages(vp, pt)
    dense = da.decode_attention(q, kd, vd, lens, interpret=True,
                                block_kv=min(512, L))
    np.testing.assert_allclose(paged, dense, atol=1e-5, rtol=1e-5)
    want = ref.paged_decode_attention_ref(q, kp, vp, pt, lens,
                                          scale=dh ** -0.5)
    np.testing.assert_allclose(paged, want, atol=1e-5, rtol=1e-5)


def test_paged_kernel_int8():
    B, H, Hkv, dh, ps, npp = 2, 8, 2, 64, 16, 6
    P = B * npp + 1
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp, vp = _mk_pages(ks[1], P, ps, Hkv, dh)
    pt = _disjoint_tables(ks[2], B, npp, P)
    lens = jnp.array([5, 90], jnp.int32)
    ki, vi, ksc, vsc = da.quantize_kv(kp, vp)
    out = da.paged_decode_attention(q, ki, vi, pt, lens, k_scale=ksc,
                                    v_scale=vsc, interpret=True)
    want = ref.paged_decode_attention_ref(q, ki, vi, pt, lens,
                                          scale=dh ** -0.5,
                                          k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    # tracks the unquantized reference within quantization error
    fp = ref.paged_decode_attention_ref(q, kp, vp, pt, lens, scale=dh ** -0.5)
    assert float(jnp.max(jnp.abs(out - fp))) < 0.05


def test_paged_kernel_ignores_unowned_pages():
    """Pages outside the table — and table slots past seq_len — are inert."""
    B, H, dh, ps, npp = 1, 4, 64, 8, 4
    P = 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp, vp = _mk_pages(ks[1], P, ps, H, dh)
    pt = jnp.asarray([[3, 5, 0, 0]], jnp.int32)      # 2 real + null padding
    lens = jnp.array([13], jnp.int32)
    out1 = da.paged_decode_attention(q, kp, vp, pt, lens, interpret=True)
    owned = {3, 5}
    mask = np.ones((P,), bool)
    mask[list(owned)] = False
    kp2 = kp.at[mask].set(999.0)
    vp2 = vp.at[mask].set(-999.0)
    # also poison the owned-but-invalid tail of page 5 (rows 13..16)
    kp2 = kp2.at[5, 5:].set(777.0)
    out2 = da.paged_decode_attention(q, kp2, vp2, pt, lens, interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


# ----------------------------- manager --------------------------------- #

def test_manager_alloc_free_invariants():
    kv = PagedKVManager(n_pages=10, page_size=4)
    assert kv.n_free == 9 and kv.n_used == 0           # page 0 reserved
    pages = kv.allocate(0, 9, reserve_tokens=12)       # 3 pages
    assert len(pages) == 3 and 0 not in pages
    assert kv.n_free == 6 and kv.n_used == 3
    kv.allocate(1, 4)
    with pytest.raises(ValueError):
        kv.allocate(1, 4)                              # double alloc
    # growth: 9 -> 12 tokens fit the reserve; 13th crosses a boundary
    for _ in range(3):
        assert kv.append_token(0) is None
    assert kv.append_token(0) is not None
    assert kv.n_used == 5
    assert kv.free_seq(0) == 4
    assert kv.free_seq(1) == 1
    assert kv.n_free == 9 and kv.n_used == 0           # no leak


def test_manager_exhaustion_raises():
    kv = PagedKVManager(n_pages=4, page_size=4)
    kv.allocate(0, 12)                                 # all 3 usable pages
    with pytest.raises(PageAllocationError):
        kv.allocate(1, 1)
    with pytest.raises(PageAllocationError):
        kv.append_token(0)
    assert not kv.can_admit(1)
    assert kv.fits_at_all(12) and not kv.fits_at_all(13)


def test_manager_table_row_pads_with_null_page():
    kv = PagedKVManager(n_pages=8, page_size=4)
    kv.allocate(7, 8)
    row = kv.table_row(7, 5)
    assert row.shape == (5,) and (row[2:] == 0).all() and (row[:2] > 0).all()


def test_tier_budget_and_split():
    from repro.core import hbs, lpddr6, npu_hierarchy, sram_chiplet
    from repro.serving.kv_manager import page_bytes

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2)
    hier = npu_hierarchy(lpddr6(capacity_gb=1e-3),    # 1 MB "DDR"
                         hbs(64.0, latency_us=20.0, capacity_gb=1e-2),
                         chiplet=sram_chiplet(512.0, capacity_mb=0.1))
    pb = page_bytes(cfg, 16, 4)
    tb = TierBudget.from_hierarchy(hier, cfg, 16, 4)
    names = [n for n, _ in tb.tiers]
    assert names == ["chiplet", "ddr", "hbs"]          # fast tier first
    assert dict(tb.tiers)["chiplet"] == int(0.1e6 // pb)
    assert dict(tb.tiers)["ddr"] == int(1e6 // pb)

    # the chiplet is a promote-only level (SS17): fresh allocations land
    # in the base tiers and the chiplet fills by promotion, never by
    # first-touch assignment
    assert tb.n_promote == 1
    assert tb.promote_tiers == tb.tiers[:1]
    assert tb.offload_tier == "hbs"

    kv = PagedKVManager(n_pages=10_000, page_size=16, tier_budget=tb)
    assert kv.n_pages == tb.total_pages + 1            # budget caps the pool
    n_chip = dict(tb.tiers)["chiplet"]
    kv.allocate(0, (n_chip + 3) * 16)      # would have overflowed the chiplet
    split = kv.kv_tier_split()
    assert [s[0] for s in split] == ["ddr"]            # chiplet stays empty
    assert abs(sum(f for _, f in split) - 1.0) < 1e-9


# ---------------------------- scheduler -------------------------------- #

def _sched(n_pages=32, page_size=4, max_batch=4):
    kv = PagedKVManager(n_pages, page_size)
    return ContinuousScheduler(kv, max_batch), kv


def test_scheduler_admit_retire_no_leak():
    sched, kv = _sched()
    for i in range(6):
        sched.submit(Request(rid=i, prompt=[1] * 5, max_new_tokens=4))
    admitted = sched.admit()
    assert len(admitted) == 4                          # slot-bound
    assert kv.n_used == 4 * 2                          # 5 tokens -> 2 pages
    for slot, _ in admitted:
        sched.retire(slot)
    assert kv.n_used == 0 and len(sched.done) == 4
    assert len(sched.admit()) == 2                     # the queue drains


def test_scheduler_preempts_youngest_and_requeues_front():
    sched, kv = _sched(n_pages=7, page_size=4, max_batch=4)
    sched.submit(Request(rid=0, prompt=[1] * 8, max_new_tokens=8))
    sched.submit(Request(rid=1, prompt=[2] * 8, max_new_tokens=8))
    admitted = sched.admit()
    assert len(admitted) == 2                          # 2+2 pages of 6
    s0, r0 = admitted[0]
    s1, r1 = admitted[1]
    r0.out.append(9)
    # grow r0 past its pages: 8 -> 9 tokens needs a 3rd page; pool has 2
    # free, so no preemption yet; grow again after exhausting
    sched.grow_seq(s0)
    assert kv.n_used == 5
    kv.allocate(99, 4)                                 # eat the last free page
    r1.out.append(7)
    for _ in range(4):                                 # 9 -> 13 tokens
        sched.grow_seq(s0)
    # r1 (younger) must have been evicted to make room, r0 survives
    assert s1 not in sched.slots and s0 in sched.slots
    assert sched.waiting and sched.waiting[0] is r1
    assert r1.n_preemptions == 1
    assert r1.prefill_tokens == [2] * 8 + [7]          # recompute keeps out
    with pytest.raises(PageAllocationError):
        for _ in range(32):                            # nothing left to evict
            sched.grow_seq(s0)
    kv.free_seq(99)
    kv.free_seq(r0.rid)
    assert kv.n_used == 0


def test_scheduler_rejects_oversized_request():
    sched, _ = _sched(n_pages=4, page_size=4)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=4))


# ------------------------- engine equivalence --------------------------- #

@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def test_continuous_matches_static_equal_lengths(small_model):
    """Acceptance: token-identical greedy outputs for equal-length prompts."""
    cfg, opts, params = small_model
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 12),
                                            1, cfg.vocab))
    reqs = [p.tolist() for p in prompts]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(reqs, 8)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=8, max_batch=4)
    assert eng.serve(reqs, 8) == want


def test_continuous_matches_static_ragged(small_model):
    cfg, opts, params = small_model
    rng = np.random.default_rng(2)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (5, 12, 3, 9, 7)]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(reqs, 8)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=8, max_batch=3)      # forces queueing
    assert eng.serve(reqs, 8) == want
    assert eng.stats.requests == 5
    assert eng.kv_manager.n_used == 0                # no page leak


def test_continuous_preemption_token_identical(small_model):
    cfg, opts, params = small_model
    reqs = [list(range(1, 5)), list(range(5, 9))]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(reqs, 12)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=4, max_batch=2, n_pages=6)
    assert eng.serve(reqs, 12) == want
    assert eng.stats.preemptions >= 1                # the pool forced one


def test_continuous_eos_retires_early(small_model):
    cfg, opts, params = small_model
    reqs = [[3, 4, 5], [6, 7, 8, 9]]
    ref_eng = ServeEngine(cfg, params, opts, max_len=32)
    want = ref_eng.serve(reqs, 8)
    eos = want[0][2]                                 # force an early EOS
    a = ServeEngine(cfg, params, opts, max_len=32, eos_id=eos)
    b = ServeEngine(cfg, params, opts, max_len=32, eos_id=eos,
                    scheduler="continuous", page_size=8, max_batch=2)
    outs_a, outs_b = a.serve(reqs, 8), b.serve(reqs, 8)
    assert outs_b[0][-1] == eos and len(outs_b[0]) <= 8
    # the static wave pads finished rows until the wave exits; compare the
    # continuous output against the static prefix up to and incl. EOS
    for sa, sb in zip(outs_a, outs_b):
        assert sb == sa[:len(sb)]


def test_continuous_rejects_unsupported_config():
    cfg = reduced(get_config("mamba2-130m"), d_model=64)
    with pytest.raises(NotImplementedError):
        ServeEngine(cfg, opts=RuntimeOptions(dtype="float32"),
                    scheduler="continuous")


def test_serve_bucketed_returns_ordered_list(small_model):
    cfg, opts, params = small_model
    eng = ServeEngine(cfg, params, opts, max_len=32)
    reqs = [[1, 2, 3]] * 2 + [[5, 6, 7, 8, 9, 10]] * 3
    outs = eng.serve_bucketed(reqs, 4)
    assert isinstance(outs, list) and len(outs) == 5
    assert all(len(o) == 4 for o in outs)
    assert outs[0] == outs[1] and outs[2] == outs[3] == outs[4]


def test_generate_rejects_overlong_request(small_model):
    cfg, opts, params = small_model
    eng = ServeEngine(cfg, params, opts, max_len=16)
    with pytest.raises(AssertionError):
        eng.generate(np.ones((1, 12), np.int32), 8)   # 12 + 8 > 16
