"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional (requirements-dev.txt): only the property sweep
# needs it; the fixed-case kernel tests must run everywhere
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                         # pragma: no cover
    HAVE_HYPOTHESIS = False

import repro.kernels.decode_attention as da
import repro.kernels.flash_attention as fa
import repro.kernels.ref as ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------- flash attention ---------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,dh,bq,bkv", [
    (1, 256, 4, 4, 128, 128, 128),     # MHA
    (2, 512, 8, 2, 128, 256, 256),     # GQA 4:1
    (1, 384, 4, 1, 128, 128, 128),     # MQA, non-pow2 seq
    (1, 256, 2, 2, 256, 128, 128),     # wide head
])
def test_flash_attention_matches_ref(dtype, B, S, H, Hkv, dh, bq, bkv):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _mk(ks[0], (B, S, H, dh), dtype)
    k = _mk(ks[1], (B, S, Hkv, dh), dtype)
    v = _mk(ks[2], (B, S, Hkv, dh), dtype)
    out = fa.flash_attention(q, k, v, causal=True, interpret=True,
                             block_q=bq, block_kv=bkv)
    want = ref.flash_attention_ref(q, k, v, causal=True, scale=dh ** -0.5)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _mk(ks[0], (1, 256, 4, 128), jnp.float32)
    k = _mk(ks[1], (1, 256, 4, 128), jnp.float32)
    v = _mk(ks[2], (1, 256, 4, 128), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=False, interpret=True,
                             block_q=128, block_kv=128)
    want = ref.flash_attention_ref(q, k, v, causal=False, scale=128 ** -0.5)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


if HAVE_HYPOTHESIS:
    @given(
        S=st.sampled_from([128, 256, 384, 512]),
        Hkv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    @settings(max_examples=12, deadline=None)
    def test_flash_attention_property_sweep(S, Hkv, group, dtype):
        H = Hkv * group
        ks = jax.random.split(jax.random.PRNGKey(S * H), 3)
        q = _mk(ks[0], (1, S, H, 128), dtype)
        k = _mk(ks[1], (1, S, Hkv, 128), dtype)
        v = _mk(ks[2], (1, S, Hkv, 128), dtype)
        out = fa.flash_attention(q, k, v, causal=True, interpret=True,
                                 block_q=128, block_kv=128)
        want = ref.flash_attention_ref(q, k, v, causal=True,
                                       scale=128 ** -0.5)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   want.astype(jnp.float32),
                                   atol=TOL[dtype], rtol=TOL[dtype])
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                      "(pip install -r requirements-dev.txt)")
    def test_flash_attention_property_sweep():
        pass


# ------------------------- decode attention --------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,dh,L,bkv", [
    (2, 4, 2, 128, 1024, 256),
    (1, 8, 1, 128, 512, 128),          # MQA
    (4, 4, 4, 64, 256, 128),           # small head_dim
])
def test_decode_attention_matches_ref(dtype, B, H, Hkv, dh, L, bkv):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _mk(ks[0], (B, H, dh), dtype)
    kc = _mk(ks[1], (B, L, Hkv, dh), dtype)
    vc = _mk(ks[2], (B, L, Hkv, dh), dtype)
    valid = jax.random.randint(ks[3], (B,), 1, L + 1)
    out = da.decode_attention(q, kc, vc, valid, interpret=True, block_kv=bkv)
    want = ref.decode_attention_ref(q, kc, vc, valid, scale=dh ** -0.5)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_int8_kv():
    """int8 KV halves traffic; result must track the fp16 reference within
    quantization error (the paper's traffic-reduction knob, takeaway III)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, H, Hkv, dh, L = 2, 8, 2, 128, 1024
    q = _mk(ks[0], (B, H, dh), jnp.float32)
    kc = _mk(ks[1], (B, L, Hkv, dh), jnp.float32)
    vc = _mk(ks[2], (B, L, Hkv, dh), jnp.float32)
    valid = jnp.array([L, L // 2], jnp.int32)
    ki, vi, ksc, vsc = da.quantize_kv(kc, vc)
    out = da.decode_attention(q, ki, vi, valid, k_scale=ksc, v_scale=vsc,
                              interpret=True, block_kv=256)
    # exact vs int8 oracle
    want_i8 = ref.decode_attention_ref(q, ki, vi, valid, scale=dh ** -0.5,
                                       k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(out, want_i8, atol=2e-5, rtol=2e-5)
    # close to the unquantized reference
    want_fp = ref.decode_attention_ref(q, kc, vc, valid, scale=dh ** -0.5)
    assert float(jnp.max(jnp.abs(out - want_fp))) < 0.05


def test_decode_attention_valid_masking():
    """Tokens beyond kv_valid must not influence the result."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, H, dh, L = 1, 4, 128, 512
    q = _mk(ks[0], (B, H, dh), jnp.float32)
    kc = _mk(ks[1], (B, L, H, dh), jnp.float32)
    vc = _mk(ks[2], (B, L, H, dh), jnp.float32)
    valid = jnp.array([300], jnp.int32)
    out1 = da.decode_attention(q, kc, vc, valid, interpret=True, block_kv=128)
    kc2 = kc.at[:, 300:].set(999.0)
    vc2 = vc.at[:, 300:].set(-999.0)
    out2 = da.decode_attention(q, kc2, vc2, valid, interpret=True,
                               block_kv=128)
    np.testing.assert_allclose(out1, out2, atol=1e-6)
