"""On-device sampling (DESIGN.md SS14): greedy argmax parity, logit
filtering (temperature / top-k / top-p), keyed categorical sampling, and
the temperature -> 0 convergence guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import sampling


def test_greedy_matches_np_argmax_tie_breaking():
    """Acceptance: ``sample_greedy`` reproduces np.argmax exactly —
    including ties, which both break toward the LOWEST index — so the
    fused on-device path stays token-identical to the old host loop."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(16, 33)).astype(np.float32)
    # manufacture ties at assorted positions, incl. the first column
    logits[0, :] = 0.5
    logits[1, [3, 17]] = logits[1].max() + 1.0
    logits[2, [0, 32]] = logits[2].max() + 1.0
    got = np.asarray(sampling.sample_greedy(jnp.asarray(logits)))
    want = np.argmax(logits, axis=-1)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


def test_sample_temperature_zero_is_greedy():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 50)).astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    got = sampling.sample(logits, keys, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(sampling.sample_greedy(logits)))


def test_sample_converges_to_greedy_as_temperature_vanishes():
    """temp -> 0+ sharpens the categorical onto the argmax: at 1e-4 every
    draw must equal greedy (distinct maxima, so no tie ambiguity)."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(8, 40)).astype(np.float32))
    want = np.asarray(sampling.sample_greedy(logits))
    for seed in range(5):
        keys = jax.random.split(jax.random.PRNGKey(seed), 8)
        got = np.asarray(sampling.sample(logits, keys, temperature=1e-4))
        np.testing.assert_array_equal(got, want)


def test_filtered_logits_rejects_nonpositive_temperature():
    logits = jnp.zeros((1, 4))
    with pytest.raises(ValueError):
        sampling.filtered_logits(logits, temperature=0.0)
    with pytest.raises(ValueError):
        sampling.filtered_logits(logits, temperature=-1.0)


def test_filtered_logits_top_k_keeps_exactly_k():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    for k in (1, 3, 10):
        out = np.asarray(sampling.filtered_logits(logits, temperature=1.0,
                                                  top_k=k))
        kept = out > sampling.NEG_INF / 2
        assert (kept.sum(axis=-1) == k).all()
        # the kept set IS the top-k set
        for b in range(5):
            top = set(np.argsort(np.asarray(logits[b]))[-k:])
            assert set(np.flatnonzero(kept[b])) == top


def test_filtered_logits_top_p_nucleus_property():
    """The kept set is the minimal probability-sorted prefix whose mass
    reaches top_p: every kept token's 'mass before me' is < top_p, and
    the total kept mass is >= top_p."""
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(6, 32)).astype(np.float32) * 2)
    for p in (0.1, 0.5, 0.9):
        out = np.asarray(sampling.filtered_logits(logits, temperature=1.0,
                                                  top_p=p))
        kept = out > sampling.NEG_INF / 2
        probs = np.asarray(jax.nn.softmax(logits, axis=-1))
        for b in range(6):
            order = np.argsort(-probs[b])
            csum = np.cumsum(probs[b][order])
            mass_before = csum - probs[b][order]
            want = set(order[mass_before < p])
            assert set(np.flatnonzero(kept[b])) == want
            assert probs[b][kept[b]].sum() >= p - 1e-6
        # the argmax always survives the nucleus
        assert kept[np.arange(6), probs.argmax(-1)].all()


def test_filtered_logits_temperature_scales():
    logits = jnp.asarray([[2.0, 0.0, -2.0]])
    out = np.asarray(sampling.filtered_logits(logits, temperature=0.5))
    np.testing.assert_allclose(out, [[4.0, 0.0, -4.0]], atol=1e-6)


def test_sample_is_deterministic_per_key_and_unbiased():
    """Same key -> same token; across many keys the empirical histogram
    tracks softmax(logits / T) (loose TV bound)."""
    logits_row = np.asarray([1.5, 0.0, -0.5, 2.0, -3.0], np.float32)
    N = 4000
    logits = jnp.asarray(np.tile(logits_row, (N, 1)))
    keys = jax.random.split(jax.random.PRNGKey(7), N)
    got = np.asarray(sampling.sample(logits, keys, temperature=1.0))
    again = np.asarray(sampling.sample(logits, keys, temperature=1.0))
    np.testing.assert_array_equal(got, again)
    want = np.asarray(jax.nn.softmax(jnp.asarray(logits_row)))
    emp = np.bincount(got, minlength=5) / N
    assert 0.5 * np.abs(emp - want).sum() < 0.05


def test_split_keys_shapes_and_divergence():
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    sub = sampling.split_keys(keys, 2)
    assert sub.shape == (3, 2, 2)
    flat = np.asarray(sub).reshape(-1, 2)
    assert len({tuple(r) for r in flat}) == 6   # all children distinct


def test_sample_greedy_shim_rejects_nonzero_temperature():
    from repro.models.lm import sample_greedy
    logits = jnp.zeros((1, 4))
    with pytest.raises(ValueError):
        sample_greedy(logits, temperature=0.5)
    np.testing.assert_array_equal(np.asarray(sample_greedy(logits)), [0])
