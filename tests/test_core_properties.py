"""Property-based tests (hypothesis) on the analytical engine's invariants."""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (all_hbs, hbs, lpddr6, npu_hierarchy, qkv_in_ddr,
                        run_inference)
from repro.core.roofline import kernel_time, phase_time
from repro.core.tiling import gemm_tiling
from repro.core.workload import decode_phase

CFG = get_config("llama3.2-1b")          # small -> fast kernel graphs
DIMS = st.integers(min_value=1, max_value=4096)


# ------------------------------ tiling -------------------------------- #

@given(M=DIMS, N=DIMS, K=DIMS,
       cap=st.sampled_from([256e3, 2e6, 8e6, 64e6]))
@settings(max_examples=150, deadline=None)
def test_tiling_traffic_at_least_compulsory(M, N, K, cap):
    t = gemm_tiling(M, N, K, 2, cap)
    compulsory = (M * K + K * N + M * N) * 2
    assert t.total >= compulsory * 0.999
    # tile working set actually fits the buffer
    ws = (t.mt * t.kt + t.kt * t.nt + t.mt * t.nt) * 2
    assert ws <= cap or (t.mt == t.nt == t.kt == 1)


@given(M=DIMS, N=DIMS, K=DIMS)
@settings(max_examples=60, deadline=None)
def test_tiling_monotone_in_capacity(M, N, K):
    small = gemm_tiling(M, N, K, 2, 256e3)
    big = gemm_tiling(M, N, K, 2, 64e6)
    assert big.total <= small.total * 1.001


def test_tiling_gemv_is_compulsory():
    t = gemm_tiling(1, 8192, 4096, 2, 8e6)
    assert t.traffic["B"] == pytest.approx(8192 * 4096 * 2)


# --------------------------- roofline bounds -------------------------- #

def _hier(hbs_bw=512.0, lat=10.0, ddr_bw=173.0):
    return npu_hierarchy(lpddr6(ddr_bw), hbs(hbs_bw, latency_us=lat))


def test_kernel_time_never_below_compute_bound():
    ph = decode_phase(CFG, 512, 1, 2)
    hier = _hier()
    for k in ph.kernels:
        kt = kernel_time(k, hier, all_hbs())
        assert kt.time >= kt.compute_time - 1e-15
        assert kt.time >= k.total_flops() / hier.compute.flops - 1e-15


@given(bw1=st.floats(16.0, 256.0), scale=st.floats(1.1, 8.0))
@settings(max_examples=25, deadline=None)
def test_tps_monotone_in_hbs_bandwidth(bw1, scale):
    r1 = run_inference(CFG, _hier(hbs_bw=bw1), all_hbs(), 128, 64, n_samples=3)
    r2 = run_inference(CFG, _hier(hbs_bw=bw1 * scale), all_hbs(), 128, 64,
                       n_samples=3)
    assert r2.tps >= r1.tps * 0.999


@given(lat1=st.floats(1.0, 40.0), dlat=st.floats(1.0, 80.0))
@settings(max_examples=25, deadline=None)
def test_tps_antitone_in_hbs_latency(lat1, dlat):
    r1 = run_inference(CFG, _hier(lat=lat1), all_hbs(), 128, 64, n_samples=3)
    r2 = run_inference(CFG, _hier(lat=lat1 + dlat), all_hbs(), 128, 64,
                       n_samples=3)
    assert r2.tps <= r1.tps * 1.001


@given(ctx=st.integers(64, 4096))
@settings(max_examples=25, deadline=None)
def test_decode_step_time_monotone_in_context(ctx):
    hier = _hier()
    t1 = phase_time(decode_phase(CFG, ctx, 1, 2), hier, all_hbs()).total
    t2 = phase_time(decode_phase(CFG, ctx * 2, 1, 2), hier, all_hbs()).total
    assert t2 >= t1 * 0.999


def test_restricting_qkv_to_ddr_never_hurts():
    """The paper's experiment III placement dominates all-HBS."""
    for pf, dec in ((128, 64), (1024, 256)):
        r_hbs = run_inference(CFG, _hier(), all_hbs(), pf, dec, n_samples=3)
        r_ddr = run_inference(CFG, _hier(), qkv_in_ddr(), pf, dec, n_samples=3)
        assert r_ddr.tps >= r_hbs.tps * 0.999


# --------------------------- workload sanity -------------------------- #

@pytest.mark.parametrize("arch", ["llava15-13b", "llama3.2-1b", "yi-6b",
                                  "deepseek-v2-236b", "arctic-480b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "whisper-medium", "gemma3-1b",
                                  "qwen2.5-3b", "paligemma-3b",
                                  "command-r-plus-104b"])
def test_decode_flops_close_to_2x_active_params(arch):
    """Decode-step GEMM FLOPs ~ 2 * N_active (+ attention term)."""
    cfg = get_config(arch)
    ctx = 256
    ph = decode_phase(cfg, ctx, 1, 2)
    flops = sum(k.total_flops() for k in ph.kernels if k.kind == "gemm")
    n_act = cfg.n_active_params()
    attn_extra = 4.0 * cfg.kv_bytes_per_token(2) / 2 * ctx  # ~2*2*kv_elems
    lo, hi = 2.0 * n_act * 0.5, (2.0 * n_act + attn_extra) * 1.8
    assert lo <= flops <= hi, (flops / 1e9, n_act / 1e9)


def test_moe_decode_streams_only_topk_experts():
    cfg = get_config("deepseek-v2-236b")
    ph = decode_phase(cfg, 256, 1, 2)
    w_moe = sum(op.bytes * k.count for k in ph.kernels for op in k.operands
                if op.tclass == "w_moe" and op.role == "B")
    from repro.core.workload import resident_bytes
    fp = resident_bytes(cfg, 256, 1, 2)
    # streamed expert weights must be way below resident MoE weights
    assert w_moe < 0.10 * fp["w_moe"]


def test_sliding_window_caps_attention_traffic():
    """Local layers read at most window-sized KV -> far less attention BYTES.

    (Time shrinks less: per-matrix issue latency doesn't scale with the
    window — exactly the paper's latency-bound small-transfer regime.)"""
    cfg = get_config("gemma3-1b")
    full = cfg.replace(local_global_ratio=0, sliding_window=0)
    hier = _hier()

    def attn_hbs_traffic(c):
        rep = phase_time(decode_phase(c, 16384, 1, 2), hier, all_hbs())
        return sum(kt.level_traffic.get("hbs", 0.0) for kt in rep.kernel_times
                   if kt.kernel.group == "attn")

    assert attn_hbs_traffic(cfg) < 0.35 * attn_hbs_traffic(full)
