"""Analytical concurrency model: capacity_aware spill ordering under
multi-request KV footprints and the TPS-vs-concurrency curve."""
import pytest

from repro.configs import get_config
from repro.core import (TC, capacity_aware, chiplet_qkv, concurrency_sweep,
                        concurrent_inference, hbs, lpddr6,
                        max_concurrency_without_spill, npu_hierarchy,
                        placement_with_kv_split, qkv_in_ddr, resident_bytes,
                        sram_chiplet)

CFG = get_config("llama3.2-1b")
PF, DC = 2048, 256


def _hbs_hier():
    return npu_hierarchy(lpddr6(520.0), hbs(64.0, latency_us=20.0))


def _chiplet_hier():
    return npu_hierarchy(lpddr6(173.0), chiplet=sram_chiplet(512.0))


# ---------------------- capacity-aware multi-request ------------------- #

def test_capacity_aware_spills_multi_request_kv_to_next_tier():
    hier = _hbs_hier()
    place = qkv_in_ddr()                  # KV prefers the 16 GB DDR
    ctx = PF + DC
    per_req = CFG.kv_bytes_per_token(2) * ctx
    ddr_cap = hier.level("ddr").capacity
    n = int(ddr_cap // per_req) + 8       # guaranteed past DDR capacity
    fp = resident_bytes(CFG, ctx, n, 2)
    placed = capacity_aware(place, hier, fp)
    locs = dict(placed.locations(TC.KV))
    assert "ddr" in locs and "hbs" in locs      # spill goes outward to HBS
    assert locs["ddr"] + locs["hbs"] == pytest.approx(1.0)
    # the resident fraction is what physically fits after heavier classes
    assert 0.0 < locs["ddr"] < 1.0


def test_capacity_aware_spill_ordering_biggest_class_first():
    """Classes are placed in descending footprint: with enough requests the
    KV class dwarfs the weights and claims its tier's capacity first."""
    hier = _hbs_hier()
    place = qkv_in_ddr()
    ctx = PF + DC
    n_small, n_big = 1, 256
    fp_small = resident_bytes(CFG, ctx, n_small, 2)
    fp_big = resident_bytes(CFG, ctx, n_big, 2)
    assert fp_big[TC.KV] == pytest.approx(n_big * fp_small[TC.KV])
    placed_small = capacity_aware(place, hier, fp_small)
    placed_big = capacity_aware(place, hier, fp_big)
    assert placed_small.locations(TC.KV) == (("ddr", 1.0),)  # no pressure
    big_ddr = dict(placed_big.locations(TC.KV))["ddr"]
    assert big_ddr < 1.0                                     # spilled


# ------------------------- concurrency sweep --------------------------- #

@pytest.mark.parametrize("hier_fn,place_fn", [(_hbs_hier, qkv_in_ddr),
                                              (_chiplet_hier, chiplet_qkv)])
def test_sweep_per_request_tps_decreases(hier_fn, place_fn):
    pts = concurrency_sweep(CFG, hier_fn(), place_fn(),
                            concurrency=(1, 4, 16, 64),
                            prefill_len=PF, decode_len=DC)
    per_req = [p.per_request_tps for p in pts]
    assert all(t > 0 for t in per_req)
    assert all(a >= b * 0.999 for a, b in zip(per_req, per_req[1:]))
    # aggregate KV grows linearly with concurrency
    assert pts[-1].kv_bytes == pytest.approx(64 * pts[0].kv_bytes)
    # spill fraction is monotone non-decreasing
    spills = [p.kv_spill_frac for p in pts]
    assert all(a <= b + 1e-9 for a, b in zip(spills, spills[1:]))


def test_max_concurrency_without_spill_is_tight():
    hier, place = _hbs_hier(), qkv_in_ddr()
    n = max_concurrency_without_spill(CFG, hier, place,
                                      prefill_len=PF, decode_len=DC)
    assert n >= 1
    at = concurrent_inference(CFG, hier, place, n_concurrent=n,
                              prefill_len=PF, decode_len=DC)
    over = concurrent_inference(CFG, hier, place, n_concurrent=n + 1,
                                prefill_len=PF, decode_len=DC)
    assert at.kv_spill_frac == 0.0
    assert over.kv_spill_frac > 0.0


def test_spill_degrades_aggregate_tps_on_hbs():
    """Past the DDR capacity knee, marginal requests pay HBS-latency
    attention reads — the paper's capacity-pressure cliff."""
    hier, place = _hbs_hier(), qkv_in_ddr()
    n0 = max_concurrency_without_spill(CFG, hier, place,
                                       prefill_len=PF, decode_len=DC)
    at = concurrent_inference(CFG, hier, place, n_concurrent=n0,
                              prefill_len=PF, decode_len=DC)
    over = concurrent_inference(CFG, hier, place, n_concurrent=2 * n0,
                                prefill_len=PF, decode_len=DC)
    assert over.per_request_tps < at.per_request_tps * 0.5


# --------------------- runtime -> analytical bridge -------------------- #

def test_runtime_kv_split_feeds_placement():
    from repro.configs.reduce import reduced
    from repro.serving import PagedKVManager, TierBudget

    cfg = reduced(CFG, d_model=64, n_layers=2)
    hier = npu_hierarchy(lpddr6(capacity_gb=1e-3),
                         hbs(64.0, latency_us=20.0, capacity_gb=1e-2),
                         chiplet=sram_chiplet(512.0, capacity_mb=0.1))
    tb = TierBudget.from_hierarchy(hier, cfg, 16, 4)
    kv = PagedKVManager(10_000, 16, tier_budget=tb)
    n_chip = dict(tb.tiers)["chiplet"]
    kv.allocate(0, (n_chip + 5) * 16)
    split = kv.kv_tier_split()
    place = placement_with_kv_split(chiplet_qkv(), split)
    assert place.locations(TC.KV) == split
    # and it prices: a report computes with the runtime-observed split
    rep = concurrent_inference(cfg, hier, chiplet_qkv(), n_concurrent=2,
                               prefill_len=64, decode_len=16,
                               kv_split=split)
    assert rep.aggregate_tps > 0
