"""Faithful-reproduction gates: the analytical engine vs the paper's claims.

Tolerances: +-20 % on absolute TPS (the paper does not publish its chunk/
overlap constants — DESIGN.md SS4); bottleneck labels and qualitative trends
must match exactly.
"""
import pytest

from repro.configs import get_config
from repro.core import (all_hbs, chiplet_qkv, ddr_only, hbs, lpddr6,
                        npu_hierarchy, qkv_in_ddr, run_inference,
                        sram_chiplet)


def _llava():
    return get_config("llava15-13b")


def _run(ddr_bw, hbs_bw, place, lat=10.0, pf=200, dec=200):
    hier = npu_hierarchy(lpddr6(ddr_bw), hbs(hbs_bw, latency_us=lat))
    return run_inference(_llava(), hier, place, pf, dec, dtype_bytes=2)


# ----------------------------- Table I -------------------------------- #

TABLE1 = [
    (173.0, 173.0, all_hbs, 4.0, "hbs"),
    (173.0, 520.0, all_hbs, 5.5, "ddr"),
    (520.0, 512.0, all_hbs, 8.9, "hbs"),
    (520.0, 512.0, qkv_in_ddr, 12.5, "hbs"),
]


@pytest.mark.parametrize("ddr_bw,hbs_bw,place,paper_tps,paper_bott", TABLE1)
def test_table1_row(ddr_bw, hbs_bw, place, paper_tps, paper_bott):
    rep = _run(ddr_bw, hbs_bw, place())
    assert rep.tps == pytest.approx(paper_tps, rel=0.20)
    assert rep.bottleneck == paper_bott


def test_table1_gain_ordering():
    tps = [_run(*row[:2], row[2]()).tps for row in TABLE1]
    assert tps[0] < tps[1] < tps[2] < tps[3]
    # headline: Q/K/V-in-DDR configuration reaches the 10 TPS target
    assert tps[3] >= 10.0
    # and the all-HBS configurations do not (takeaway II)
    assert tps[2] < 10.0


# ----------------------------- Figure 1 ------------------------------- #

def test_fig1_tps_scales_with_hbs_bw_when_hbs_bound():
    t64 = _run(173.0, 64.0, all_hbs()).tps
    t128 = _run(173.0, 128.0, all_hbs()).tps
    assert t128 / t64 == pytest.approx(2.0, rel=0.20)  # ~linear region


def test_fig1_latency_monotonicity():
    tps = [_run(173.0, 173.0, all_hbs(), lat=l).tps for l in (2, 10, 50, 100)]
    assert tps == sorted(tps, reverse=True)


def test_fig1_bottleneck_shift_threshold():
    """Takeaway I: shift to DDR at HBS bw >= ~1.4x DDR bw (10 us HBS)."""
    ratios = [1.0, 1.2, 1.4, 1.6, 2.0, 2.5, 3.0]
    shift = None
    for r in ratios:
        rep = _run(173.0, 173.0 * r, all_hbs())
        if rep.bottleneck == "ddr":
            shift = r
            break
    assert shift is not None and 1.2 <= shift <= 1.8


def test_fig1b_only_2us_curve_meets_10tps():
    assert _run(520.0, 512.0, all_hbs(), lat=2.0).tps >= 10.0
    assert _run(520.0, 512.0, all_hbs(), lat=10.0).tps < 10.0


# ----------------------------- Figure 2 ------------------------------- #

def test_fig2_attention_share_of_gemm_time():
    """31-69 % of GEMM time for HBS latency 10-50 us (large model)."""
    lo_rep = _run(520.0, 512.0, all_hbs(), lat=10.0)
    hi_rep = _run(520.0, 512.0, all_hbs(), lat=50.0)
    _, share10 = lo_rep.decode_group_share("attn")
    _, share50 = hi_rep.decode_group_share("attn")
    assert 0.25 <= share10 <= 0.69
    assert share10 < share50 <= 0.75
    assert max(share10, share50) >= 0.31  # overlaps the paper band


def test_fig2_qkv_in_ddr_reaches_target_at_10us():
    assert _run(520.0, 512.0, qkv_in_ddr(), lat=10.0).tps >= 10.0


# ----------------------------- Figure 3 ------------------------------- #

def test_fig3_context_degradation_and_consistent_gains():
    gains = []
    for pf, dec in ((200, 200), (4096, 12288), (8192, 24576)):
        t1 = _run(173.0, 173.0, all_hbs(), pf=pf, dec=dec).tps
        t3 = _run(520.0, 512.0, qkv_in_ddr(), pf=pf, dec=dec).tps
        gains.append(t3 / t1)
    assert all(g > 1.5 for g in gains)
    assert max(gains) / min(gains) < 1.5  # "relative gains remain consistent"


def test_fig3_kv_cache_27gb_at_33k():
    cfg = _llava()
    kv = cfg.kv_bytes_per_token(2) * (8192 + 24576)
    assert kv == pytest.approx(27e9, rel=0.05)


# ----------------------------- Figure 4 ------------------------------- #

def test_fig4_small_model_attention_share():
    """4-9 % of GEMM time for DDR latency 0.1-1 us (small model)."""
    cfg = get_config("llama3.2-1b")
    shares = []
    for lat_ns in (100.0, 1000.0):
        h = npu_hierarchy(lpddr6(173.0, latency_ns=lat_ns))
        rep = run_inference(cfg, h, ddr_only(), 128, 384, dtype_bytes=2)
        shares.append(rep.decode_group_share("attn")[1])
    assert shares[0] < shares[1]
    assert 0.01 <= shares[0] <= 0.09
    assert 0.04 <= shares[1] <= 0.12


def test_fig4_kv_cache_68mb():
    cfg = get_config("llama3.2-1b")
    assert cfg.kv_bytes_per_token(2) * 512 == pytest.approx(68e6, rel=0.05)


def test_fig4_chiplet_gain_grows_with_ddr_latency():
    cfg = get_config("llama3.2-1b")
    gains = []
    for lat_ns in (100.0, 1000.0):
        base_h = npu_hierarchy(lpddr6(173.0, latency_ns=lat_ns))
        base = run_inference(cfg, base_h, ddr_only(), 128, 384, dtype_bytes=2)
        ch_h = npu_hierarchy(lpddr6(173.0, latency_ns=lat_ns),
                             chiplet=sram_chiplet(512.0))
        ch = run_inference(cfg, ch_h, chiplet_qkv(), 128, 384, dtype_bytes=2)
        gains.append(ch.tps / base.tps)
    assert gains[1] > gains[0] >= 1.0
    assert gains[1] < 1.3  # "not as high as the HBS studies"


def test_fig4_takeaway4_ideal_chiplet_prefers_weights():
    """With capacity to hold them, MLP/proj weights beat QKV in the chiplet."""
    from repro.core import chiplet_mlp_weights
    cfg = get_config("llama3.2-1b")
    h = npu_hierarchy(lpddr6(173.0, latency_ns=500.0),
                      chiplet=sram_chiplet(512.0, capacity_mb=4096.0))
    r_qkv = run_inference(cfg, h, chiplet_qkv(), 128, 384, dtype_bytes=2)
    r_w = run_inference(cfg, h, chiplet_mlp_weights(), 128, 384, dtype_bytes=2)
    assert r_w.tps > r_qkv.tps
