"""Numerics tests for the SSPerf optimization paths: they must be exact
drop-ins for the baselines (measured wins are only wins if correct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, lm
from repro.models.seq_shard_attn import decode_attn_seq_sharded

OPTS = RuntimeOptions(dtype="float32")


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_seq_shard_attention_matches_baseline_decode():
    mesh = _mesh11()
    cfg = reduced(get_config("gemma3-1b"))   # exercises sliding branch too
    o1 = dataclasses.replace(OPTS, seq_shard_attn=True, seq_shard_mesh=mesh)
    p = lm.init_params(cfg, jax.random.PRNGKey(0), OPTS)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    c0 = lm.init_cache(cfg, 2, 16, OPTS)
    l0, c0 = lm.prefill(cfg, p, toks, c0, OPTS)
    c1 = lm.init_cache(cfg, 2, 16, o1)
    l1, c1 = lm.prefill(cfg, p, toks, c1, o1)
    errs = [float(jnp.max(jnp.abs(l0 - l1)))]
    for t in range(8, 13):
        tok = jnp.argmax(l0, -1).astype(jnp.int32)
        l0, c0 = lm.decode_step(cfg, p, tok, jnp.int32(t), c0, OPTS)
        l1, c1 = lm.decode_step(cfg, p, tok, jnp.int32(t), c1, o1)
        errs.append(float(jnp.max(jnp.abs(l0 - l1))))
    assert max(errs) < 1e-4, errs


def test_seq_shard_attention_unit():
    """Direct unit check of the shard_map body vs dense attention."""
    mesh = _mesh11()
    B, H, Hkv, dh, L = 2, 4, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, 1, H, dh))
    k_new = jax.random.normal(ks[1], (B, 1, Hkv, dh))
    v_new = jax.random.normal(ks[2], (B, 1, Hkv, dh))
    ck = jax.random.normal(ks[3], (B, L, Hkv, dh))
    cv = jax.random.normal(ks[4], (B, L, Hkv, dh))
    pos = jnp.int32(7)
    out, nck, ncv = decode_attn_seq_sharded(q, k_new, v_new, ck, cv, pos,
                                            mesh)
    # reference: write then causal attention at q_offset=pos
    from repro.models import common as cm
    rk, rv = cm.update_cache(ck, cv, k_new, v_new, 7)
    want = cm.attention(q, rk, rv, mask_kind="causal", q_offset=7)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(nck, rk, atol=0)


def test_moe_shard_map_matches_capacity():
    mesh = _mesh11()
    cfg = reduced(get_config("deepseek-v2-236b"))
    o0 = dataclasses.replace(OPTS, capacity_factor=8.0)
    o1 = dataclasses.replace(o0, moe_impl="shard_map",
                             moe_shard_map_mesh=mesh)
    p = lm.init_params(cfg, jax.random.PRNGKey(0), o0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    l0, _ = lm.forward(cfg, p, toks, o0)
    l1, _ = lm.forward(cfg, p, toks, o1)
    np.testing.assert_allclose(l0, l1, atol=2e-4, rtol=2e-4)


def test_serving_with_prefix_model():
    """VLM serving: prefix embeddings flow through the engine."""
    from repro.serving import ServeEngine
    cfg = reduced(get_config("paligemma-3b"))
    eng = ServeEngine(cfg, opts=OPTS, max_len=64)
    B = 2
    prompts = jnp.ones((B, 6), jnp.int32)
    pe = jax.random.normal(jax.random.PRNGKey(0),
                           (B, cfg.prefix_len, cfg.d_model), jnp.float32)
    out = eng.generate(prompts, 4, prefix_emb=pe)
    assert len(out) == B and len(out[0]) == 4
    assert eng.stats.tps > 0


def test_decode_memory_floor_sanity():
    """The analytic compulsory floor is below any measured memory term."""
    from repro.core.tpu_roofline import decode_floor_seconds
    cfg = get_config("command-r-plus-104b")
    floor = decode_floor_seconds(cfg, 32768, 128, n_dev=256)
    assert 0.001 < floor < 1.0  # ~70 ms: weights+cache once over HBM
