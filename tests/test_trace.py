"""Structured tracing + latency attribution (DESIGN.md SS15).

Recorder units (tiling, clamping, recompute split, SLO blame, Chrome
structure), a hypothesis property that span accounting conserves time
under arbitrary engine-like event schedules (per-request phase sums ==
end-to-end latency; absorbed stalls == the stats counter), and golden
engine runs asserting event ordering, valid Chrome trace-event output
and strict trace/ServeStats reconciliation on the real serve loop."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.serving.trace import (DECODE, DRAFT, PHASES, PREFILL, STALL,
                                 TraceRecorder, validate_chrome_trace)


def _sum_phases(bd):
    return sum(bd[f"{p}_s"] for p in PHASES)


# --------------------------- recorder units ----------------------------- #

def test_span_tiling_fills_gaps_as_queue():
    tr = TraceRecorder()
    tr.submit(0, 10.0)
    tr.admit(0, 11.0)
    tr.span(0, PREFILL, 12.0, 13.0)      # 11 -> 12 gap becomes queue
    tr.retire(0, 13.5)                   # trailing gap too
    bd = tr.breakdown(0)
    assert bd["queue_s"] == pytest.approx(2.5)
    assert bd["prefill_s"] == pytest.approx(1.0)
    assert bd["e2e_s"] == pytest.approx(3.5)
    assert _sum_phases(bd) == pytest.approx(bd["e2e_s"])


def test_span_overlap_clamps_instead_of_double_counting():
    """A decode span launched at a block start whose stall span already
    tiled the barrier must only contribute its uncovered tail."""
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.span(0, STALL, 0.0, 1.0)
    tr.span(0, DECODE, 0.0, 3.0)         # overlaps [0, 1)
    bd = tr.breakdown(0)
    assert bd["stall_s"] == pytest.approx(1.0)
    assert bd["decode_s"] == pytest.approx(2.0)
    assert bd["e2e_s"] == pytest.approx(3.0)


def test_span_fully_covered_is_dropped():
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.span(0, DECODE, 0.0, 2.0)
    tr.span(0, STALL, 0.5, 1.5)          # entirely inside tiled time
    bd = tr.breakdown(0)
    assert bd["stall_s"] == 0.0
    assert bd["decode_s"] == pytest.approx(2.0)


def test_unknown_phase_rejected():
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    with pytest.raises(ValueError, match="unknown phase"):
        tr.span(0, "gpu", 0.0, 1.0)


def test_prefill_span_recompute_split():
    """Re-prefill below the computed-extent high-water mark is labelled
    recompute; fresh tokens stay prefill; mixed chunks split
    proportionally in time."""
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.prefill_span(0, 0.0, 1.0, 0, 32)      # first pass: all prefill
    tr.preempt(0, 1.0, n_valid=32)           # KV lost, extent remembered
    tr.prefill_span(0, 2.0, 3.0, 0, 32)      # full re-prefill: recompute
    tr.prefill_span(0, 3.0, 4.0, 32, 48)     # fresh extension: prefill
    bd = tr.breakdown(0)
    assert bd["recompute_s"] == pytest.approx(1.0)
    assert bd["prefill_s"] == pytest.approx(2.0)
    assert bd["queue_s"] == pytest.approx(1.0)       # preempted wait
    assert bd["n_preemptions"] == 1


def test_prefill_span_partial_recompute_proportional():
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.preempt(0, 0.0, n_valid=8)
    tr.prefill_span(0, 0.0, 1.0, 0, 16)      # half old, half new
    bd = tr.breakdown(0)
    assert bd["recompute_s"] == pytest.approx(0.5)
    assert bd["prefill_s"] == pytest.approx(0.5)


def test_ttft_itl_derived_from_token_instants():
    tr = TraceRecorder()
    tr.submit(3, 1.0)
    tr.token(3, 1.5, 42)
    tr.token(3, 1.7, 43)
    tr.token(3, 2.0, 44)
    tr.retire(3, 2.0)
    bd = tr.breakdown(3)
    assert bd["ttft_s"] == pytest.approx(0.5)
    assert bd["itl_s"] == pytest.approx([0.2, 0.3])
    assert bd["n_tokens"] == 3


def test_slo_report_blames_dominant_window_phase():
    """TTFT violators are blamed on the dominant phase of their
    [submit, first token] window — here a fetch stall."""
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.span(0, STALL, 0.0, 1.0)
    tr.span(0, DECODE, 1.0, 1.2)
    tr.token(0, 1.1, 5)
    tr.retire(0, 1.2)
    tr.submit(1, 0.0)                        # meets the target
    tr.span(1, DECODE, 0.0, 0.1)
    tr.token(1, 0.05, 5)
    tr.retire(1, 0.1)
    rep = tr.slo_report(ttft_target_s=0.5)
    assert rep["n_requests"] == 2 and rep["n_met_slo"] == 1
    assert rep["goodput_frac"] == 0.5
    (v,) = rep["violators"]
    assert v["rid"] == 0 and v["blame"] == "stall"
    assert v["blame_window_ms"]["stall"] == pytest.approx(1000.0)
    # no targets -> everything counts as goodput
    assert tr.slo_report()["goodput_frac"] == 1.0


def test_reconcile_strict_raises_on_drift():
    tr = TraceRecorder()
    tr.submit(0, 0.0)
    tr.span(0, DECODE, 0.0, 1.0)
    tr.token(0, 1.0, 9)
    tr.retire(0, 1.0)
    tr.finalize(1.0)
    ok = tr.reconcile(stall_s=0.0, ttft=[1.0], itl=[], new_tokens=1)
    assert ok["ok"] and not ok["failures"]
    with pytest.raises(AssertionError, match="drift"):
        tr.reconcile(stall_s=0.25, ttft=[1.0], itl=[], new_tokens=1)
    bad = tr.reconcile(stall_s=0.25, ttft=[0.9], itl=[0.1], new_tokens=2,
                       strict=False)
    assert not bad["ok"] and len(bad["failures"]) == 4


def test_chrome_export_structure_and_validation():
    tr = TraceRecorder()
    tr.submit(0, 5.0)
    tr.admit(0, 5.1)
    tr.span(0, DECODE, 5.1, 5.3)
    tr.token(0, 5.2, 7)
    tr.retire(0, 5.3)
    tr.engine_span("decode_block", 5.1, 5.3, {"n_steps": 2})
    tr.device_span("in", 5.0, 5.05, 4096)
    tr.absorbed_stall(5.05, 0.01)
    doc = tr.to_chrome()
    counts = validate_chrome_trace(doc)
    assert counts["X"] >= 4 and counts["i"] >= 3 and counts["M"] >= 6
    ev = doc["traceEvents"]
    # timestamps are rebased: everything non-negative, µs scale
    assert all(e["ts"] >= 0 for e in ev if e["ph"] != "M")
    names = {e["name"] for e in ev}
    assert {"admit", "first_token", "retire", "decode", "decode_block",
            "fetch", "stall", "process_name", "thread_name"} <= names
    assert doc["metadata"]["breakdowns"]["0"]["n_tokens"] == 1


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "events"})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="unsupported ph"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "B", "pid": 1, "tid": 0, "name": "x", "ts": 0}]})
    with pytest.raises(ValueError, match="bad dur"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "p"}},
            {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": 0,
             "dur": -1}]})
    with pytest.raises(ValueError, match="no track-naming"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "i", "pid": 1, "tid": 0, "name": "x", "ts": 0}]})


# ---------------------- conservation property test ---------------------- #

def _replay_random_schedule(rng):
    """Replay an arbitrary engine-like schedule — staggered submits,
    barrier stalls with per-request attribution, prefill/decode/draft
    blocks whose spans overlap the stall tiles the way real engine
    blocks do (launched at the block start), token emission — against a
    shadow ServeStats-style accumulator. Conservation must hold: every
    request's phase partition sums to its e2e latency, the trace's stall
    total equals the accumulated stat, and reconcile() passes strictly."""
    n = int(rng.integers(1, 5))
    tr = TraceRecorder()
    stats_stall = 0.0
    stall_by_rid = {}
    ttft, itl, last_tok = [], [], {}
    t = 100.0
    submit_t = {}
    for rid in range(n):
        t += float(rng.uniform(0.0, 0.01))
        submit_t[rid] = t
        tr.submit(rid, t)
    for _ in range(int(rng.integers(1, 11))):
        k = int(rng.integers(1, n + 1))
        rids = rng.choice(n, size=k, replace=False).tolist()
        t0 = t
        # fetch-wait barrier: the batch absorbs the max of per-request
        # waits, each request is blamed for its own
        per = {rid: (float(rng.uniform(0.0, 0.02))
                     if rng.random() < 0.5 else 0.0) for rid in rids}
        s = max(per.values())
        if s > 0:
            stats_stall += s
            tr.absorbed_stall(t0, s)
        for rid, v in per.items():
            if v > 0:
                stall_by_rid[rid] = stall_by_rid.get(rid, 0.0) + v
                tr.span(rid, STALL, t0, t0 + v)
        t = t0 + s + float(rng.uniform(0.001, 0.02))
        phase = (PREFILL, DECODE, DRAFT)[int(rng.integers(3))]
        for rid in rids:
            tr.span(rid, phase, t0, t)
            if phase == DECODE:
                if rid in last_tok:
                    itl.append(t - last_tok[rid])
                else:
                    ttft.append(t - submit_t[rid])
                last_tok[rid] = t
                tr.token(rid, t, 7)
    for rid in range(n):
        tr.retire(rid, t)
    tr.finalize(t)
    rep = tr.reconcile(stall_s=stats_stall, ttft=ttft, itl=itl,
                       new_tokens=len(ttft) + len(itl),
                       stall_by_rid=stall_by_rid)
    assert rep["ok"]
    for rid in range(n):
        bd = tr.breakdown(rid)
        assert abs(_sum_phases(bd) - bd["e2e_s"]) < 1e-9
        assert bd["e2e_s"] == pytest.approx(t - submit_t[rid])
    assert validate_chrome_trace(tr.to_chrome())["M"] >= 5 + n


def test_span_accounting_conserves_time_seeded():
    """Deterministic fallback sweep of the conservation property (always
    runs, even without hypothesis)."""
    for seed in range(32):
        _replay_random_schedule(np.random.default_rng(seed))


def test_hypothesis_span_accounting_conserves_time():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=100, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def run(seed):
        _replay_random_schedule(np.random.default_rng(seed))

    run()


# ------------------------- golden engine traces ------------------------- #

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.models import RuntimeOptions, init_params

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def _offload_hierarchy(cfg, fast_pages, page_size=8):
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    pb = page_bytes(cfg, page_size, 4)
    return npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                         hbs(8.0, latency_us=20.0, capacity_gb=1.0))


def test_golden_trace_offload_run(small_model):
    """Deterministic small serve with a stingy offload tier: the trace
    must reconcile strictly, export valid Chrome JSON, keep per-request
    events ordered (admit <= first_token <= retire), tile each request
    track without overlap, and conserve time in every breakdown."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(3)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (20, 9, 14)]
    hier = _offload_hierarchy(cfg, fast_pages=4)
    eng = ServeEngine(cfg, params, opts, max_len=40,
                      scheduler="continuous", page_size=8, max_batch=3,
                      prefill_budget=96, hierarchy=hier, hbs_gbps=1e-3,
                      hbs_latency_us=500.0)
    eng.serve([r[:] for r in reqs], 8)

    tr = eng.trace
    assert eng.trace_report["ok"], eng.trace_report["failures"]
    doc = tr.to_chrome()
    counts = validate_chrome_trace(doc)
    assert counts["X"] > 0 and counts["i"] > 0
    ev = doc["traceEvents"]
    names = {e["name"] for e in ev}
    assert {"admit", "first_token", "retire", "prefill_chunk",
            "decode_block", "fetch", "stall"} <= names

    for rid in range(len(reqs)):
        inst = {e["name"]: e["ts"] for e in ev
                if e["ph"] == "i" and e["pid"] == 1 and e["tid"] == rid}
        assert inst["admit"] <= inst["first_token"] <= inst["retire"]
        spans = sorted((e["ts"], e["ts"] + e["dur"]) for e in ev
                       if e["ph"] == "X" and e["pid"] == 1
                       and e["tid"] == rid)
        for (_, e0), (s1, _) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-3          # contiguous tiling (µs tol)

    for rid, bd in tr.breakdowns().items():
        assert abs(_sum_phases(bd) - bd["e2e_s"]) <= 1e-6
        assert bd["n_tokens"] == 8
    # the stingy tier stalls for real, and the trace attributes it
    agg = tr.aggregate_breakdown_ms()
    assert agg["stall_ms"] > 0
    assert eng.stats.stall_s * 1e3 == pytest.approx(
        tr.stall_total * 1e3)

    # goodput report: impossible targets blame every request, absent
    # targets pass every request
    rep = tr.slo_report(1e-9, 1e-9)
    assert rep["goodput_frac"] == 0.0
    assert len(rep["violators"]) == len(reqs)
    assert all(v["blame"] in PHASES for v in rep["violators"])
    assert tr.slo_report()["goodput_frac"] == 1.0


def test_trace_spec_decode_draft_phase(small_model):
    """Speculative serve: draft proposal overhead lands in the DRAFT
    phase and the spec_propose/spec_commit instants appear."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(0)
    doc = rng.integers(1, cfg.vocab, size=32).tolist()
    reqs = [doc + rng.integers(1, cfg.vocab, size=4).tolist()
            for _ in range(2)]
    eng = ServeEngine(cfg, params, opts, max_len=72,
                      scheduler="continuous", page_size=8, max_batch=2,
                      spec_mode="ngram", spec_k=4)
    eng.serve([r[:] for r in reqs], 16)
    assert eng.trace_report["ok"], eng.trace_report["failures"]
    names = {e["name"] for e in eng.trace.to_chrome()["traceEvents"]}
    assert {"spec_propose", "spec_verify", "spec_commit"} <= names
    agg = eng.trace.aggregate_breakdown_ms()
    assert agg["draft_ms"] > 0
    assert agg["decode_ms"] > 0


def test_trace_preemption_recompute_attribution(small_model):
    """A pool too small for everyone's lookahead windows preempts LIFO;
    without the prefix cache the re-prefill is honest recompute and the
    trace labels it so."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    reqs = [list(range(1, 5)), list(range(5, 9))]
    eng = ServeEngine(cfg, params, opts, max_len=32,
                      scheduler="continuous", page_size=4, max_batch=2,
                      n_pages=6, decode_lookahead=4, prefix_cache=False)
    eng.serve([r[:] for r in reqs], 12)
    assert eng.stats.preemptions >= 1
    assert eng.trace_report["ok"], eng.trace_report["failures"]
    names = {e["name"] for e in eng.trace.to_chrome()["traceEvents"]}
    assert "preempt" in names
    bds = eng.trace.breakdowns()
    assert sum(bd["n_preemptions"] for bd in bds.values()) \
        == eng.stats.preemptions
    assert any(bd["recompute_s"] > 0 for bd in bds.values())


def test_second_serve_on_same_engine_reconciles(small_model):
    """ServeStats accumulates across serve() calls; the per-serve trace
    must reconcile against the deltas, not the lifetime totals."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(7)
    reqs = [rng.integers(1, cfg.vocab, size=12).tolist() for _ in range(2)]
    eng = ServeEngine(cfg, params, opts, max_len=32,
                      scheduler="continuous", page_size=8, max_batch=2)
    eng.serve([r[:] for r in reqs], 6)
    first = eng.trace
    eng.serve([r[:] for r in reqs], 6)
    assert eng.trace is not first                  # fresh recorder
    assert eng.trace_report["ok"], eng.trace_report["failures"]
    assert len(eng.stats.ttft) == 4                # totals kept growing
