"""Substrate tests: data determinism, checkpoint atomicity/resume,
fault-tolerant train loop, serving engine + tiered KV policy."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.data.pipeline import SyntheticTextDataset
from repro.models import RuntimeOptions, init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.serving import ServeEngine
from repro.train import TrainConfig, train

OPTS = RuntimeOptions(dtype="float32")


# ------------------------------ data ----------------------------------- #

def test_data_pure_function_of_step():
    ds = SyntheticTextDataset(vocab=64, seq_len=16, global_batch=4, seed=3)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    c = ds.batch_at(8)
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert not jnp.array_equal(a["tokens"], c["tokens"])
    assert int(a["tokens"].max()) < 64 and int(a["tokens"].min()) >= 0


# ---------------------------- optimizer -------------------------------- #

def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}     # d/dw of w^2
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, 0)) == 0.0
    assert float(cosine_schedule(cfg, 10)) == pytest.approx(1e-3)
    assert float(cosine_schedule(cfg, 100)) == pytest.approx(1e-4, rel=0.01)


# ---------------------------- checkpoint ------------------------------- #

def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((2,), 7.0)]}
    for s in (5, 10, 15, 20):
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 20
    # GC kept only the last 2
    steps = sorted(int(p.name.split("_")[1])
                   for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [15, 20]
    got, step = restore_checkpoint(tmp_path, tree)
    assert step == 20
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_partial_write_is_invisible(tmp_path):
    tree = {"a": jnp.ones((2,))}
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crashed writer: stale tmp dir must be ignored
    (pathlib.Path(tmp_path) / "step_9.tmp").mkdir()
    assert latest_step(tmp_path) == 1


# ---------------------------- train loop ------------------------------- #

def _tiny_cfg():
    return reduced(get_config("yi-6b"), d_model=32, n_layers=2, vocab=64)


def test_train_loss_decreases_and_resumes(tmp_path):
    cfg = _tiny_cfg()
    tcfg = TrainConfig(steps=12, seq_len=32, global_batch=4, ckpt_every=6,
                       ckpt_dir=str(tmp_path), log_every=100,
                       optimizer=AdamWConfig(lr=3e-3, warmup_steps=2,
                                             total_steps=12))
    out = train(cfg, tcfg, OPTS, log_fn=None)
    assert out["last_step"] == 12
    assert out["losses"][-1] < out["losses"][0]
    # resume: continue to 16 steps from the step-12 checkpoint
    tcfg2 = TrainConfig(**{**tcfg.__dict__, "steps": 16})
    out2 = train(cfg, tcfg2, OPTS, log_fn=None)
    assert out2["last_step"] == 16
    assert len(out2["losses"]) == 4      # only steps 12..15 re-run
    # metrics log exists and is parseable
    lines = (tmp_path / "metrics.jsonl").read_text().strip().splitlines()
    assert len(lines) >= 16
    json.loads(lines[-1])


def test_train_grad_accum_matches_single_batch():
    """n_micro=2 must equal n_micro=1 up to float tolerance."""
    cfg = _tiny_cfg()

    def run(n_micro):
        tcfg = TrainConfig(steps=3, seq_len=16, global_batch=4,
                           n_micro=n_micro, ckpt_every=1000,
                           ckpt_dir=f"/tmp/repro_na_{n_micro}",
                           optimizer=AdamWConfig(lr=1e-3, warmup_steps=0,
                                                 total_steps=3))
        return train(cfg, tcfg, OPTS, log_fn=None)["losses"]
    l1, l2 = run(1), run(2)
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


# ------------------------------ serving -------------------------------- #

def test_serve_engine_greedy_deterministic():
    cfg = _tiny_cfg()
    eng = ServeEngine(cfg, opts=OPTS, max_len=128, seed=0)
    prompts = jnp.ones((2, 8), jnp.int32)
    out1 = eng.generate(prompts, 6)
    out2 = eng.generate(prompts, 6)
    assert out1 == out2
    assert len(out1) == 2 and len(out1[0]) == 6
    assert eng.stats.tps > 0


def test_serve_bucketed_ragged_requests():
    cfg = _tiny_cfg()
    eng = ServeEngine(cfg, opts=OPTS, max_len=128)
    reqs = [[1, 2, 3], [4, 5, 6, 7, 8], [9, 8, 7]]
    outs = eng.serve_bucketed(reqs, 4)
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)


def test_tiered_kv_int8_close_to_native():
    """The int8 tiered-KV policy must track native-cache outputs."""
    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), OPTS)
    e_native = ServeEngine(cfg, params, OPTS, kv_policy="native", max_len=128)
    e_int8 = ServeEngine(cfg, params, OPTS, kv_policy="int8", max_len=128)
    prompts = jnp.asarray(
        np.random.RandomState(0).randint(1, cfg.vocab, (2, 16)), jnp.int32)
    o_native = e_native.generate(prompts, 8)
    o_int8 = e_int8.generate(prompts, 8)
    agree = np.mean([a == b for ra, rb in zip(o_native, o_int8)
                     for a, b in zip(ra, rb)])
    assert agree >= 0.75, f"int8 KV diverged: agreement {agree}"
