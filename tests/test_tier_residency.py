"""Real per-page tier residency, async spill/prefetch, and stall
accounting (DESIGN.md SS13): manager invariants, migration timing,
the satellite bugfixes (dtype width, reserved-page traffic mass,
unknown-capacity budgets), and engine-level token identity of the
offload path."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.serving import (PageAllocationError, PagedKVManager,
                           SimulatedTierDevice, TierBudget)

PB = 1000.0                       # page payload bytes used by unit tests


def _kv(fast=4, offload=16, *, bw=1e6, lat=1e-3, page_size=4, n_pages=64,
        device=True, **kw):
    tb = TierBudget((("ddr", fast), ("hbs", offload)))
    dev = SimulatedTierDevice(bandwidth=bw, latency=lat) if device else None
    return PagedKVManager(n_pages, page_size, tier_budget=tb,
                          page_nbytes=PB, tier_device=dev, **kw)


def _check_residency(kv):
    """The SS13 invariants, checkable after ANY operation."""
    assigned = set(kv._tier)
    # every referenced or cached-evictable page is in exactly one tier;
    # free pages are untracked
    in_use = set(kv._ref) | set(kv._evictable)
    assert assigned == in_use
    assert 0 not in assigned                      # null page never assigned
    for p in kv._free:
        assert p not in assigned
    # per-tier counters match the residency map
    counts = {}
    for t in kv._tier.values():
        counts[t] = counts.get(t, 0) + 1
    for name, _ in kv.tier_budget.tiers:
        assert kv.tier_occupancy_pages()[name] == counts.get(name, 0)
    # no tier over budget — in particular fast occupancy after any spill
    for name, cap in kv.tier_budget.tiers:
        assert kv.tier_occupancy_pages()[name] <= cap
    assert kv.fast_pages_used <= kv.tier_budget.fast_pages
    # the split is a distribution over budget tiers
    split = kv.kv_tier_split()
    if split:
        assert abs(sum(f for _, f in split) - 1.0) < 1e-9
        assert all(t in dict(kv.tier_budget.tiers) for t, _ in split)


# ------------------------- residency invariants ------------------------ #

def test_pages_live_in_exactly_one_tier():
    kv = _kv(fast=4, offload=16)
    kv.allocate(0, 6 * 4)                  # 4 fast + 2 offload overflow
    _check_residency(kv)
    assert kv.fast_pages_used == 4
    tiers = [kv.page_tier(p) for p in kv.seq_pages(0)]
    assert tiers == ["ddr"] * 4 + ["hbs"] * 2
    kv.allocate(1, 3 * 4)                  # all offload (fast is full)
    _check_residency(kv)
    assert [kv.page_tier(p) for p in kv.seq_pages(1)] == ["hbs"] * 3
    kv.free_seq(0)
    _check_residency(kv)
    assert kv.fast_pages_used == 0         # freed pages leave their tier
    kv.free_seq(1)
    _check_residency(kv)
    assert sum(kv.tier_occupancy_pages().values()) == 0


def test_fast_budget_respected_after_spill_and_fetch():
    kv = _kv(fast=3, offload=16)
    kv.allocate(0, 2 * 4)                  # 2 fast
    kv.allocate(1, 4 * 4)                  # 1 fast + 3 offload
    # preparing seq 1 spills seq 0's cold pages but never overfills fast
    kv.residency_stall([1], 0.0)
    _check_residency(kv)
    assert kv.fast_pages_used == 3
    assert [kv.page_tier(p) for p in kv.seq_pages(1)].count("ddr") == 3
    # seq 0 is now (partially) offload-resident; fetching it back spills 1
    kv.residency_stall([0], 10.0)
    _check_residency(kv)
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0))


def test_lru_cold_pages_spill_first():
    kv = _kv(fast=4, offload=16)
    kv.allocate(0, 2 * 4)
    kv.allocate(1, 2 * 4)                  # fast now full: 2 + 2
    kv.residency_stall([1], 1.0)           # touch seq 1 (hotter)
    kv.allocate(2, 2 * 4)                  # lands offload
    kv.residency_stall([2], 2.0)           # needs 2 fast slots
    _check_residency(kv)
    # seq 0 (cold) was demoted; seq 1 (hot) kept its fast residency
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(0))
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(1))
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(2))
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB


# ------------------------ migration timing model ----------------------- #

def test_demand_fetch_charges_latency_plus_bytes_over_bandwidth():
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)                  # 2 fast + 2 offload
    stall = kv.residency_stall([0], 0.0)
    assert stall == pytest.approx(1e-3 + 2 * PB / 1e5)
    assert kv.fetch_bytes == 2 * PB and kv.prefetch_misses == 2
    assert kv.prefetch_hits == 0


def test_prefetch_ahead_hides_migration_time():
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)
    ready = kv.prefetch_seqs([0], 0.0)     # issue ahead of the block
    assert ready > 0.0
    # the kernel launches after the migration landed: zero stall, hits
    assert kv.residency_stall([0], ready + 0.5) == 0.0
    assert kv.prefetch_hits == 2 and kv.prefetch_misses == 0
    # a block that outruns the prefetch absorbs exactly the residual
    kv2 = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv2.allocate(0, 4 * 4)
    ready2 = kv2.prefetch_seqs([0], 0.0)
    late = kv2.residency_stall([0], ready2 - 0.004)
    assert late == pytest.approx(0.004)
    assert kv2.prefetch_misses == 2


def test_lookahead_prefetch_hides_prefill_migration():
    """ROADMAP item 5: with the fetch channel idle and the primary set
    already resident, ``prefetch_seqs`` spends the idle window promoting a
    scheduled prefill's pages so its next chunk starts without a demand
    stall."""
    def run(lookahead):
        kv = _kv(fast=4, offload=16, bw=1e5, lat=1e-3)
        kv.allocate(0, 2 * 4)              # running seq: fast-resident
        kv.allocate(1, 4 * 4)              # prefilling seq: 2 fast + 2 hbs
        kv.prefetch_seqs([0], 0.0,
                         lookahead_seqs=[1] if lookahead else ())
        return kv, kv.residency_stall([1], 1.0)

    kv_no, stall_no = run(False)
    kv_yes, stall_yes = run(True)
    assert stall_no > 0.0                  # demand fetch pays the migration
    assert stall_yes == 0.0                # lookahead hid it entirely
    assert stall_yes < stall_no            # the stall-reduction gate
    assert kv_yes.prefetch_hits == 2 and kv_no.prefetch_hits == 0
    _check_residency(kv_yes)


def test_lookahead_defers_to_primary_fetch_traffic():
    """Lookahead is strictly idle-channel work: when the primary set
    itself misses, the prefilling sequence's pages stay put."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)                  # primary misses: 2 hbs pages
    kv.allocate(1, 2 * 4)                  # prefilling seq: offload
    kv.prefetch_seqs([0], 0.0, lookahead_seqs=[1])
    assert kv.fetch_bytes == 2 * PB        # primary traffic only
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(1))
    _check_residency(kv)


def test_lookahead_targets_deepest_prefill():
    """Among the scheduled prefills, the one with the most landed KV is
    promoted first (FCFS order: it decodes soonest)."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4)                      # running seq (fast-resident)
    kv.allocate(1, 2 * 4)                  # shallow prefill: 2 hbs
    kv.allocate(2, 3 * 4)                  # deep prefill: 3 hbs
    kv.prefetch_seqs([0], 0.0, lookahead_seqs=[1, 2])
    assert kv.fetch_bytes == 3 * PB        # seq 2's pages, not seq 1's
    _check_residency(kv)


def test_streamed_pages_charge_per_block_but_never_double():
    """A working set larger than the fast tiers streams from HBS: charged
    once per block, not once per prefetch+wait pair."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=0.0)
    kv.allocate(0, 6 * 4)                  # 4 pages can never fit fast
    t = 0.0
    kv.prefetch_seqs([0], t)
    before = kv.fetch_bytes
    kv.residency_stall([0], t + 1.0)       # same block: no re-charge
    assert kv.fetch_bytes == before == 4 * PB
    # the pages stayed offload-resident -> next block pays again
    kv.residency_stall([0], t + 2.0)
    assert kv.fetch_bytes == 8 * PB
    _check_residency(kv)


def test_reserved_unwritten_pages_carry_no_migration_traffic():
    """Traffic follows content: lookahead pages hold no KV, so preparing
    a block neither fetches them nor books misses — until a commit lands
    real writes in them."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4)                      # 1 landed page (fast)
    kv.reserve_ahead(0, 8)                 # 2 empty pages -> offload
    assert kv.residency_stall([0], 0.0) == 0.0
    assert kv.fetch_bytes == 0 and kv.prefetch_misses == 0
    kv.commit_tokens(0, 8)                 # the block wrote them: landed
    stall = kv.residency_stall([0], 1.0)
    assert stall > 0.0 and kv.fetch_bytes == 2 * PB
    _check_residency(kv)


def test_empty_write_targets_promote_free_spilling_cold_content():
    """Offload-resident write targets swap into fast for free when cold
    unpinned pages can make room; only the content-bearing victims are
    charged as spill traffic."""
    kv = _kv(fast=3, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(1, 2 * 4)                  # 2 cold landed pages (fast)
    kv.allocate(0, 4)                      # 1 landed page (fast: full)
    kv.reserve_ahead(0, 8)                 # 2 empty pages -> offload
    assert kv.residency_stall([0], 0.0) == 0.0      # no fetch: all empty
    assert kv.fetch_bytes == 0
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB  # cold content out
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0))
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(1))
    _check_residency(kv)


def test_unprefilled_prompt_pages_carry_no_migration_traffic():
    """mark_written: prompt pages the chunked prefill has not reached yet
    are capacity, not traffic — no fetch bytes, no stall, no split mass."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(1, 6 * 4)                  # long prompt: 2 fast + 4 hbs
    kv.mark_written(1, 0)                  # admission: nothing landed yet
    assert kv.residency_stall([1], 0.0) == 0.0
    assert kv.fetch_bytes == 0 and kv.prefetch_misses == 0
    assert kv.kv_tier_split() == ()        # no landed mass either
    kv.mark_written(1, 3 * 4)              # first chunks landed 3 pages
    stall = kv.residency_stall([1], 1.0)
    assert stall > 0.0                     # the landed hbs page fetches
    assert kv.fetch_bytes == 1 * PB        # ...and only it
    _check_residency(kv)


def test_freed_cached_page_cancels_inflight_fetch():
    """A page freed into the evictable cache mid-fetch must drop its
    pending state: it stays spillable and a revival pays a real fetch
    instead of consuming a phantom hit."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3,
             enable_prefix_cache=True)
    toks = list(range(1, 9))               # 2 full pages of 4
    kv.allocate(0, len(toks))
    kv.register_prefix(0, toks, n_valid=8)
    # second page is offload-resident (fast=1); start migrating it
    kv.prefetch_seqs([0], 0.0)
    assert kv._fetch_pending
    kv.free_seq(0)                         # owner gone mid-flight
    assert not kv._fetch_pending and not kv._ready_at
    _check_residency(kv)
    # revival via the prefix cache pays a real (charged) fetch
    before = kv.fetch_bytes
    alloc = kv.allocate_shared(1, toks + [9])
    assert alloc.n_cached == 8
    stall = kv.residency_stall([1], 100.0)
    assert kv.fetch_bytes > before and stall > 0.0
    _check_residency(kv)


def test_fetch_channel_serializes_batches():
    dev = SimulatedTierDevice(bandwidth=1e3, latency=0.5)
    a = dev.transfer("in", 1e3, now=0.0)   # 0.5 + 1.0
    assert a == pytest.approx(1.5)
    b = dev.transfer("in", 1e3, now=0.0)   # queues behind a
    assert b == pytest.approx(3.0)
    # the spill channel is independent (full duplex)
    c = dev.transfer("out", 1e3, now=0.0)
    assert c == pytest.approx(1.5)


def test_without_device_migrations_are_free_but_tracked():
    kv = _kv(fast=2, offload=16, device=False)
    kv.allocate(0, 4 * 4)
    assert kv.residency_stall([0], 5.0) == 0.0
    _check_residency(kv)
    assert kv.n_fetches == 2               # residency still migrated
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0)[:2])


# --------------------------- satellite bugfixes ------------------------ #

def test_tier_budget_unknown_capacity_raises():
    """S3: a capacity-less tier must not silently become 2^30 pages."""
    from repro.core import lpddr6, npu_hierarchy
    from repro.core.memspec import MemoryLevel

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2)
    hier = npu_hierarchy(lpddr6(capacity_gb=1e-3),
                         MemoryLevel("hbs", capacity=None, bandwidth=8e9,
                                     latency=20e-6))
    with pytest.raises(ValueError, match="uncapped_pages"):
        TierBudget.from_hierarchy(hier, cfg, 16, 4)
    tb = TierBudget.from_hierarchy(hier, cfg, 16, 4, uncapped_pages=128)
    assert dict(tb.tiers)["hbs"] == 128
    assert tb.total_pages < 1 << 20        # admission checks stay meaningful


def test_kv_tier_split_excludes_reserved_unwritten_pages():
    """S2: reserve_ahead pages are capacity, not attention traffic."""
    kv = _kv(fast=8, offload=16)
    kv.allocate(0, 2 * 4)                  # 2 landed pages
    kv.reserve_ahead(0, 8)                 # +2 reserved, unwritten
    assert len(kv.seq_pages(0)) == 4
    split = dict(kv.kv_tier_split())
    occ = kv.tier_occupancy_bytes()
    assert sum(occ.values()) == pytest.approx(2 * PB)   # mass: landed only
    assert split["ddr"] == 1.0
    # capacity accounting still covers the reserved pages
    assert sum(kv.tier_occupancy_pages().values()) == 4
    kv.commit_tokens(0, 8)                 # the block landed its writes
    assert sum(kv.tier_occupancy_bytes().values()) == pytest.approx(4 * PB)


def test_tier_occupancy_priced_at_active_dtype_width():
    """S1: an int8 pool must not be priced at bf16 widths."""
    from repro.serving.kv_manager import page_bytes

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2)
    tb = TierBudget((("ddr", 8), ("hbs", 8)))
    kv8 = PagedKVManager(32, 16, tier_budget=tb, dtype_bytes=1)
    kv16 = PagedKVManager(32, 16, tier_budget=tb, dtype_bytes=2)
    kv8.allocate(0, 32)
    kv16.allocate(0, 32)
    b8 = sum(kv8.tier_occupancy_bytes(cfg).values())
    b16 = sum(kv16.tier_occupancy_bytes(cfg).values())
    assert b8 * 2 == b16                   # half the footprint, not double
    assert b8 == 2 * page_bytes(cfg, 16, 1)


def test_engine_threads_kv_dtype_width():
    from repro.models import RuntimeOptions
    from repro.serving import ServeEngine

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    eng = ServeEngine(cfg, opts=RuntimeOptions(dtype="float32"),
                      kv_policy="int8", max_len=32, scheduler="continuous")
    assert eng.kv_dtype_bytes == 1
    native = ServeEngine(cfg, opts=RuntimeOptions(dtype="float32"),
                         max_len=32, scheduler="continuous")
    assert native.kv_dtype_bytes == 4
    assert eng.page_nbytes * 4 == native.page_nbytes


# ------------------------ hypothesis trace property --------------------- #

def test_hypothesis_residency_invariants_over_random_traces():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.integers(0, 5),      # op kind
                             st.integers(0, 5),      # seq id
                             st.integers(1, 40)),    # size / k
                   min_size=1, max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(ops=ops)
    def run(ops):
        kv = _kv(fast=3, offload=10, n_pages=32, bw=1e4, lat=1e-3)
        t = 0.0
        for kind, sid, n in ops:
            t += 0.01
            try:
                if kind == 0 and sid not in kv._seqs:
                    kv.allocate(sid, n)
                elif kind == 1 and sid in kv._seqs:
                    kv.free_seq(sid)
                elif kind == 2 and sid in kv._seqs:
                    kv.reserve_ahead(sid, n % 8 + 1)
                elif kind == 3 and sid in kv._seqs:
                    kv.release_reserved(sid)
                elif kind == 4 and sid in kv._seqs:
                    kv.prefetch_seqs([sid], t)
                elif kind == 5 and sid in kv._seqs:
                    stall = kv.residency_stall([sid], t)
                    assert stall >= 0.0
                    t += stall
            except PageAllocationError:
                pass                                  # admission pressure
            _check_residency(kv)
        # drain: every page returns to the free list tier-less
        for sid in list(kv._seqs):
            kv.free_seq(sid)
        _check_residency(kv)
        assert sum(kv.tier_occupancy_pages().values()) == 0
        assert kv.n_free == kv.n_pages - 1

    run()


# ------------------------- engine-level behaviour ----------------------- #

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.models import RuntimeOptions, init_params

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def _offload_hierarchy(cfg, fast_pages, page_size=8):
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    pb = page_bytes(cfg, page_size, 4)
    return npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                         hbs(8.0, latency_us=20.0, capacity_gb=1.0))


def test_offload_token_identical_and_stall_envelope(small_model):
    """Acceptance: generous HBS bandwidth -> token-identical to the
    no-offload engine with (sub-µs) zero recorded stall; stingy
    bandwidth -> same tokens, positive stall."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(3)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (20, 9, 14)]
    # a prefill budget covering every prompt makes the requests decode
    # concurrently: their joint working set exceeds the fast tier, so the
    # offload path genuinely streams landed KV instead of only writing
    kw = dict(max_len=40, scheduler="continuous", page_size=8, max_batch=3,
              prefill_budget=96)
    base = ServeEngine(cfg, params, opts, **kw)
    want = base.serve([r[:] for r in reqs], 8)
    hier = _offload_hierarchy(cfg, fast_pages=4)

    generous = ServeEngine(cfg, params, opts, **kw, hierarchy=hier,
                           hbs_gbps=1e6, hbs_latency_us=0.0)
    assert generous.serve([r[:] for r in reqs], 8) == want
    assert generous.stats.stall_s < 1e-6
    assert generous.stats.pages_fetched > 0        # the offload path ran

    stingy = ServeEngine(cfg, params, opts, **kw, hierarchy=hier,
                         hbs_gbps=1e-3, hbs_latency_us=500.0)
    assert stingy.serve([r[:] for r in reqs], 8) == want
    # wall-clock ITL is jit-noise-dominated on cold engines; the virtual
    # stall is deterministic and is what the latency metrics absorb
    assert stingy.stats.stall_s > 1e-3 > 1e-6 > generous.stats.stall_s


def test_long_context_request_runs_spilled_not_preempted(small_model):
    """A request whose KV exceeds the fast tier admits against TOTAL
    capacity and runs with cold pages spilled — no preemption."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(4)
    req = [rng.integers(1, cfg.vocab, size=40).tolist()]
    hier = _offload_hierarchy(cfg, fast_pages=2)   # 2 pages << 6 needed
    eng = ServeEngine(cfg, params, opts, max_len=48,
                      scheduler="continuous", page_size=8, max_batch=2,
                      hierarchy=hier, hbs_gbps=0.01, hbs_latency_us=20.0)
    base = ServeEngine(cfg, params, opts, max_len=48,
                       scheduler="continuous", page_size=8, max_batch=2)
    want = base.serve([r[:] for r in req], 8)
    got = eng.serve([r[:] for r in req], 8)
    assert got == want
    assert eng.stats.preemptions == 0
    assert eng.stats.peak_fast_pages <= 2          # budget held
    assert eng.stats.fetch_bytes > 0               # it streamed instead
    assert eng.stats.stall_s > 0.0
    assert dict(eng.stats.kv_split_at_peak).get("hbs", 0) > 0


def test_offload_stats_reach_serve_stats(small_model):
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab, size=16).tolist() for _ in range(3)]
    hier = _offload_hierarchy(cfg, fast_pages=3)
    eng = ServeEngine(cfg, params, opts, max_len=32,
                      scheduler="continuous", page_size=8, max_batch=3,
                      prefill_budget=96,      # concurrent decode: streams
                      hierarchy=hier, hbs_gbps=0.01, hbs_latency_us=20.0)
    eng.serve([r[:] for r in reqs], 8)
    s = eng.stats
    assert s.pages_fetched > 0 and s.fetch_bytes > 0
    assert s.prefetch_hits + s.prefetch_misses >= s.pages_fetched > 0
    assert 0.0 <= s.prefetch_hit_rate <= 1.0
    # stall feeds the latency metrics: decode+prefill wall time covers it
    assert s.prefill_s + s.decode_s >= s.stall_s
