"""Real per-page tier residency, async spill/prefetch, and stall
accounting (DESIGN.md SS13): manager invariants, migration timing,
the satellite bugfixes (dtype width, reserved-page traffic mass,
unknown-capacity budgets), and engine-level token identity of the
offload path."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.serving import (PageAllocationError, PagedKVManager,
                           SimulatedTierDevice, TierBudget)

PB = 1000.0                       # page payload bytes used by unit tests


def _kv(fast=4, offload=16, *, bw=1e6, lat=1e-3, page_size=4, n_pages=64,
        device=True, **kw):
    tb = TierBudget((("ddr", fast), ("hbs", offload)))
    dev = SimulatedTierDevice(bandwidth=bw, latency=lat) if device else None
    return PagedKVManager(n_pages, page_size, tier_budget=tb,
                          page_nbytes=PB, tier_device=dev, **kw)


def _check_residency(kv):
    """The SS13 invariants, checkable after ANY operation."""
    assigned = set(kv._tier)
    # every referenced or cached-evictable page is in exactly one tier;
    # free pages are untracked
    in_use = set(kv._ref) | set(kv._evictable)
    assert assigned == in_use
    assert 0 not in assigned                      # null page never assigned
    for p in kv._free:
        assert p not in assigned
    # per-tier counters match the residency map
    counts = {}
    for t in kv._tier.values():
        counts[t] = counts.get(t, 0) + 1
    for name, _ in kv.tier_budget.tiers:
        assert kv.tier_occupancy_pages()[name] == counts.get(name, 0)
    # no tier over budget — in particular fast occupancy after any spill
    for name, cap in kv.tier_budget.tiers:
        assert kv.tier_occupancy_pages()[name] <= cap
    assert kv.fast_pages_used <= kv.tier_budget.fast_pages
    # the split is a distribution over budget tiers
    split = kv.kv_tier_split()
    if split:
        assert abs(sum(f for _, f in split) - 1.0) < 1e-9
        assert all(t in dict(kv.tier_budget.tiers) for t, _ in split)


# ------------------------- residency invariants ------------------------ #

def test_pages_live_in_exactly_one_tier():
    kv = _kv(fast=4, offload=16)
    kv.allocate(0, 6 * 4)                  # 4 fast + 2 offload overflow
    _check_residency(kv)
    assert kv.fast_pages_used == 4
    tiers = [kv.page_tier(p) for p in kv.seq_pages(0)]
    assert tiers == ["ddr"] * 4 + ["hbs"] * 2
    kv.allocate(1, 3 * 4)                  # all offload (fast is full)
    _check_residency(kv)
    assert [kv.page_tier(p) for p in kv.seq_pages(1)] == ["hbs"] * 3
    kv.free_seq(0)
    _check_residency(kv)
    assert kv.fast_pages_used == 0         # freed pages leave their tier
    kv.free_seq(1)
    _check_residency(kv)
    assert sum(kv.tier_occupancy_pages().values()) == 0


def test_fast_budget_respected_after_spill_and_fetch():
    kv = _kv(fast=3, offload=16)
    kv.allocate(0, 2 * 4)                  # 2 fast
    kv.allocate(1, 4 * 4)                  # 1 fast + 3 offload
    # preparing seq 1 spills seq 0's cold pages but never overfills fast
    kv.residency_stall([1], 0.0)
    _check_residency(kv)
    assert kv.fast_pages_used == 3
    assert [kv.page_tier(p) for p in kv.seq_pages(1)].count("ddr") == 3
    # seq 0 is now (partially) offload-resident; fetching it back spills 1
    kv.residency_stall([0], 10.0)
    _check_residency(kv)
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0))


def test_lru_cold_pages_spill_first():
    kv = _kv(fast=4, offload=16)
    kv.allocate(0, 2 * 4)
    kv.allocate(1, 2 * 4)                  # fast now full: 2 + 2
    kv.residency_stall([1], 1.0)           # touch seq 1 (hotter)
    kv.allocate(2, 2 * 4)                  # lands offload
    kv.residency_stall([2], 2.0)           # needs 2 fast slots
    _check_residency(kv)
    # seq 0 (cold) was demoted; seq 1 (hot) kept its fast residency
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(0))
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(1))
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(2))
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB


# ------------------------ migration timing model ----------------------- #

def test_demand_fetch_charges_latency_plus_bytes_over_bandwidth():
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)                  # 2 fast + 2 offload
    stall = kv.residency_stall([0], 0.0)
    assert stall == pytest.approx(1e-3 + 2 * PB / 1e5)
    assert kv.fetch_bytes == 2 * PB and kv.prefetch_misses == 2
    assert kv.prefetch_hits == 0


def test_prefetch_ahead_hides_migration_time():
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)
    ready = kv.prefetch_seqs([0], 0.0)     # issue ahead of the block
    assert ready > 0.0
    # the kernel launches after the migration landed: zero stall, hits
    assert kv.residency_stall([0], ready + 0.5) == 0.0
    assert kv.prefetch_hits == 2 and kv.prefetch_misses == 0
    # a block that outruns the prefetch absorbs exactly the residual
    kv2 = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv2.allocate(0, 4 * 4)
    ready2 = kv2.prefetch_seqs([0], 0.0)
    late = kv2.residency_stall([0], ready2 - 0.004)
    assert late == pytest.approx(0.004)
    assert kv2.prefetch_misses == 2


def test_lookahead_prefetch_hides_prefill_migration():
    """ROADMAP item 5: with the fetch channel idle and the primary set
    already resident, ``prefetch_seqs`` spends the idle window promoting a
    scheduled prefill's pages so its next chunk starts without a demand
    stall."""
    def run(lookahead):
        kv = _kv(fast=4, offload=16, bw=1e5, lat=1e-3)
        kv.allocate(0, 2 * 4)              # running seq: fast-resident
        kv.allocate(1, 4 * 4)              # prefilling seq: 2 fast + 2 hbs
        kv.prefetch_seqs([0], 0.0,
                         lookahead_seqs=[1] if lookahead else ())
        return kv, kv.residency_stall([1], 1.0)

    kv_no, stall_no = run(False)
    kv_yes, stall_yes = run(True)
    assert stall_no > 0.0                  # demand fetch pays the migration
    assert stall_yes == 0.0                # lookahead hid it entirely
    assert stall_yes < stall_no            # the stall-reduction gate
    assert kv_yes.prefetch_hits == 2 and kv_no.prefetch_hits == 0
    _check_residency(kv_yes)


def test_lookahead_defers_to_primary_fetch_traffic():
    """Lookahead is strictly idle-channel work: when the primary set
    itself misses, the prefilling sequence's pages stay put."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)                  # primary misses: 2 hbs pages
    kv.allocate(1, 2 * 4)                  # prefilling seq: offload
    kv.prefetch_seqs([0], 0.0, lookahead_seqs=[1])
    assert kv.fetch_bytes == 2 * PB        # primary traffic only
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(1))
    _check_residency(kv)


def test_lookahead_targets_deepest_prefill():
    """Among the scheduled prefills, the one with the most landed KV is
    promoted first (FCFS order: it decodes soonest)."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4)                      # running seq (fast-resident)
    kv.allocate(1, 2 * 4)                  # shallow prefill: 2 hbs
    kv.allocate(2, 3 * 4)                  # deep prefill: 3 hbs
    kv.prefetch_seqs([0], 0.0, lookahead_seqs=[1, 2])
    assert kv.fetch_bytes == 3 * PB        # seq 2's pages, not seq 1's
    _check_residency(kv)


def test_streamed_pages_charge_per_block_but_never_double():
    """A working set larger than the fast tiers streams from HBS: charged
    once per block, not once per prefetch+wait pair."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=0.0)
    kv.allocate(0, 6 * 4)                  # 4 pages can never fit fast
    t = 0.0
    kv.prefetch_seqs([0], t)
    before = kv.fetch_bytes
    kv.residency_stall([0], t + 1.0)       # same block: no re-charge
    assert kv.fetch_bytes == before == 4 * PB
    # the pages stayed offload-resident -> next block pays again
    kv.residency_stall([0], t + 2.0)
    assert kv.fetch_bytes == 8 * PB
    _check_residency(kv)


def test_reserved_unwritten_pages_carry_no_migration_traffic():
    """Traffic follows content: lookahead pages hold no KV, so preparing
    a block neither fetches them nor books misses — until a commit lands
    real writes in them."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4)                      # 1 landed page (fast)
    kv.reserve_ahead(0, 8)                 # 2 empty pages -> offload
    assert kv.residency_stall([0], 0.0) == 0.0
    assert kv.fetch_bytes == 0 and kv.prefetch_misses == 0
    kv.commit_tokens(0, 8)                 # the block wrote them: landed
    stall = kv.residency_stall([0], 1.0)
    assert stall > 0.0 and kv.fetch_bytes == 2 * PB
    _check_residency(kv)


def test_empty_write_targets_promote_free_spilling_cold_content():
    """Offload-resident write targets swap into fast for free when cold
    unpinned pages can make room; only the content-bearing victims are
    charged as spill traffic."""
    kv = _kv(fast=3, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(1, 2 * 4)                  # 2 cold landed pages (fast)
    kv.allocate(0, 4)                      # 1 landed page (fast: full)
    kv.reserve_ahead(0, 8)                 # 2 empty pages -> offload
    assert kv.residency_stall([0], 0.0) == 0.0      # no fetch: all empty
    assert kv.fetch_bytes == 0
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB  # cold content out
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0))
    assert all(kv.page_tier(p) == "hbs" for p in kv.seq_pages(1))
    _check_residency(kv)


def test_unprefilled_prompt_pages_carry_no_migration_traffic():
    """mark_written: prompt pages the chunked prefill has not reached yet
    are capacity, not traffic — no fetch bytes, no stall, no split mass."""
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(1, 6 * 4)                  # long prompt: 2 fast + 4 hbs
    kv.mark_written(1, 0)                  # admission: nothing landed yet
    assert kv.residency_stall([1], 0.0) == 0.0
    assert kv.fetch_bytes == 0 and kv.prefetch_misses == 0
    assert kv.kv_tier_split() == ()        # no landed mass either
    kv.mark_written(1, 3 * 4)              # first chunks landed 3 pages
    stall = kv.residency_stall([1], 1.0)
    assert stall > 0.0                     # the landed hbs page fetches
    assert kv.fetch_bytes == 1 * PB        # ...and only it
    _check_residency(kv)


def test_freed_cached_page_cancels_inflight_fetch():
    """A page freed into the evictable cache mid-fetch must drop its
    pending state: it stays spillable and a revival pays a real fetch
    instead of consuming a phantom hit."""
    kv = _kv(fast=1, offload=16, bw=1e5, lat=1e-3,
             enable_prefix_cache=True)
    toks = list(range(1, 9))               # 2 full pages of 4
    kv.allocate(0, len(toks))
    kv.register_prefix(0, toks, n_valid=8)
    # second page is offload-resident (fast=1); start migrating it
    kv.prefetch_seqs([0], 0.0)
    assert kv._fetch_pending
    kv.free_seq(0)                         # owner gone mid-flight
    assert not kv._fetch_pending and not kv._ready_at
    _check_residency(kv)
    # revival via the prefix cache pays a real (charged) fetch
    before = kv.fetch_bytes
    alloc = kv.allocate_shared(1, toks + [9])
    assert alloc.n_cached == 8
    stall = kv.residency_stall([1], 100.0)
    assert kv.fetch_bytes > before and stall > 0.0
    _check_residency(kv)


def test_fetch_channel_serializes_batches():
    dev = SimulatedTierDevice(bandwidth=1e3, latency=0.5)
    a = dev.transfer("in", 1e3, now=0.0)   # 0.5 + 1.0
    assert a == pytest.approx(1.5)
    b = dev.transfer("in", 1e3, now=0.0)   # queues behind a
    assert b == pytest.approx(3.0)
    # the spill channel is independent (full duplex)
    c = dev.transfer("out", 1e3, now=0.0)
    assert c == pytest.approx(1.5)


def test_without_device_migrations_are_free_but_tracked():
    kv = _kv(fast=2, offload=16, device=False)
    kv.allocate(0, 4 * 4)
    assert kv.residency_stall([0], 5.0) == 0.0
    _check_residency(kv)
    assert kv.n_fetches == 2               # residency still migrated
    assert all(kv.page_tier(p) == "ddr" for p in kv.seq_pages(0)[:2])


# --------------------------- satellite bugfixes ------------------------ #

def test_tier_budget_unknown_capacity_raises():
    """S3: a capacity-less tier must not silently become 2^30 pages."""
    from repro.core import lpddr6, npu_hierarchy
    from repro.core.memspec import MemoryLevel

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2)
    hier = npu_hierarchy(lpddr6(capacity_gb=1e-3),
                         MemoryLevel("hbs", capacity=None, bandwidth=8e9,
                                     latency=20e-6))
    with pytest.raises(ValueError, match="uncapped_pages"):
        TierBudget.from_hierarchy(hier, cfg, 16, 4)
    tb = TierBudget.from_hierarchy(hier, cfg, 16, 4, uncapped_pages=128)
    assert dict(tb.tiers)["hbs"] == 128
    assert tb.total_pages < 1 << 20        # admission checks stay meaningful


def test_kv_tier_split_excludes_reserved_unwritten_pages():
    """S2: reserve_ahead pages are capacity, not attention traffic."""
    kv = _kv(fast=8, offload=16)
    kv.allocate(0, 2 * 4)                  # 2 landed pages
    kv.reserve_ahead(0, 8)                 # +2 reserved, unwritten
    assert len(kv.seq_pages(0)) == 4
    split = dict(kv.kv_tier_split())
    occ = kv.tier_occupancy_bytes()
    assert sum(occ.values()) == pytest.approx(2 * PB)   # mass: landed only
    assert split["ddr"] == 1.0
    # capacity accounting still covers the reserved pages
    assert sum(kv.tier_occupancy_pages().values()) == 4
    kv.commit_tokens(0, 8)                 # the block landed its writes
    assert sum(kv.tier_occupancy_bytes().values()) == pytest.approx(4 * PB)


def test_tier_occupancy_priced_at_active_dtype_width():
    """S1: an int8 pool must not be priced at bf16 widths."""
    from repro.serving.kv_manager import page_bytes

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2)
    tb = TierBudget((("ddr", 8), ("hbs", 8)))
    kv8 = PagedKVManager(32, 16, tier_budget=tb, dtype_bytes=1)
    kv16 = PagedKVManager(32, 16, tier_budget=tb, dtype_bytes=2)
    kv8.allocate(0, 32)
    kv16.allocate(0, 32)
    b8 = sum(kv8.tier_occupancy_bytes(cfg).values())
    b16 = sum(kv16.tier_occupancy_bytes(cfg).values())
    assert b8 * 2 == b16                   # half the footprint, not double
    assert b8 == 2 * page_bytes(cfg, 16, 1)


def test_engine_threads_kv_dtype_width():
    from repro.models import RuntimeOptions
    from repro.serving import ServeEngine

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    eng = ServeEngine(cfg, opts=RuntimeOptions(dtype="float32"),
                      kv_policy="int8", max_len=32, scheduler="continuous")
    assert eng.kv_dtype_bytes == 1
    native = ServeEngine(cfg, opts=RuntimeOptions(dtype="float32"),
                         max_len=32, scheduler="continuous")
    assert native.kv_dtype_bytes == 4
    assert eng.page_nbytes * 4 == native.page_nbytes


# ------------------------ hypothesis trace property --------------------- #

def test_hypothesis_residency_invariants_over_random_traces():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.integers(0, 5),      # op kind
                             st.integers(0, 5),      # seq id
                             st.integers(1, 40)),    # size / k
                   min_size=1, max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(ops=ops)
    def run(ops):
        kv = _kv(fast=3, offload=10, n_pages=32, bw=1e4, lat=1e-3)
        t = 0.0
        for kind, sid, n in ops:
            t += 0.01
            try:
                if kind == 0 and sid not in kv._seqs:
                    kv.allocate(sid, n)
                elif kind == 1 and sid in kv._seqs:
                    kv.free_seq(sid)
                elif kind == 2 and sid in kv._seqs:
                    kv.reserve_ahead(sid, n % 8 + 1)
                elif kind == 3 and sid in kv._seqs:
                    kv.release_reserved(sid)
                elif kind == 4 and sid in kv._seqs:
                    kv.prefetch_seqs([sid], t)
                elif kind == 5 and sid in kv._seqs:
                    stall = kv.residency_stall([sid], t)
                    assert stall >= 0.0
                    t += stall
            except PageAllocationError:
                pass                                  # admission pressure
            _check_residency(kv)
        # drain: every page returns to the free list tier-less
        for sid in list(kv._seqs):
            kv.free_seq(sid)
        _check_residency(kv)
        assert sum(kv.tier_occupancy_pages().values()) == 0
        assert kv.n_free == kv.n_pages - 1

    run()


# ------------------------- engine-level behaviour ----------------------- #

@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.models import RuntimeOptions, init_params

    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def _offload_hierarchy(cfg, fast_pages, page_size=8):
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    pb = page_bytes(cfg, page_size, 4)
    return npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                         hbs(8.0, latency_us=20.0, capacity_gb=1.0))


def test_offload_token_identical_and_stall_envelope(small_model):
    """Acceptance: generous HBS bandwidth -> token-identical to the
    no-offload engine with (sub-µs) zero recorded stall; stingy
    bandwidth -> same tokens, positive stall."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(3)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (20, 9, 14)]
    # a prefill budget covering every prompt makes the requests decode
    # concurrently: their joint working set exceeds the fast tier, so the
    # offload path genuinely streams landed KV instead of only writing
    kw = dict(max_len=40, scheduler="continuous", page_size=8, max_batch=3,
              prefill_budget=96)
    base = ServeEngine(cfg, params, opts, **kw)
    want = base.serve([r[:] for r in reqs], 8)
    hier = _offload_hierarchy(cfg, fast_pages=4)

    generous = ServeEngine(cfg, params, opts, **kw, hierarchy=hier,
                           hbs_gbps=1e6, hbs_latency_us=0.0)
    assert generous.serve([r[:] for r in reqs], 8) == want
    assert generous.stats.stall_s < 1e-6
    assert generous.stats.pages_fetched > 0        # the offload path ran

    stingy = ServeEngine(cfg, params, opts, **kw, hierarchy=hier,
                         hbs_gbps=1e-3, hbs_latency_us=500.0)
    assert stingy.serve([r[:] for r in reqs], 8) == want
    # wall-clock ITL is jit-noise-dominated on cold engines; the virtual
    # stall is deterministic and is what the latency metrics absorb
    assert stingy.stats.stall_s > 1e-3 > 1e-6 > generous.stats.stall_s


def test_long_context_request_runs_spilled_not_preempted(small_model):
    """A request whose KV exceeds the fast tier admits against TOTAL
    capacity and runs with cold pages spilled — no preemption."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(4)
    req = [rng.integers(1, cfg.vocab, size=40).tolist()]
    hier = _offload_hierarchy(cfg, fast_pages=2)   # 2 pages << 6 needed
    eng = ServeEngine(cfg, params, opts, max_len=48,
                      scheduler="continuous", page_size=8, max_batch=2,
                      hierarchy=hier, hbs_gbps=0.01, hbs_latency_us=20.0)
    base = ServeEngine(cfg, params, opts, max_len=48,
                       scheduler="continuous", page_size=8, max_batch=2)
    want = base.serve([r[:] for r in req], 8)
    got = eng.serve([r[:] for r in req], 8)
    assert got == want
    assert eng.stats.preemptions == 0
    assert eng.stats.peak_fast_pages <= 2          # budget held
    assert eng.stats.fetch_bytes > 0               # it streamed instead
    assert eng.stats.stall_s > 0.0
    assert dict(eng.stats.kv_split_at_peak).get("hbs", 0) > 0


def test_offload_stats_reach_serve_stats(small_model):
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab, size=16).tolist() for _ in range(3)]
    hier = _offload_hierarchy(cfg, fast_pages=3)
    eng = ServeEngine(cfg, params, opts, max_len=32,
                      scheduler="continuous", page_size=8, max_batch=3,
                      prefill_budget=96,      # concurrent decode: streams
                      hierarchy=hier, hbs_gbps=0.01, hbs_latency_us=20.0)
    eng.serve([r[:] for r in reqs], 8)
    s = eng.stats
    assert s.pages_fetched > 0 and s.fetch_bytes > 0
    assert s.prefetch_hits + s.prefetch_misses >= s.pages_fetched > 0
    assert 0.0 <= s.prefetch_hit_rate <= 1.0
    # stall feeds the latency metrics: decode+prefill wall time covers it
    assert s.prefill_s + s.decode_s >= s.stall_s


# -------------- three-level chiplet residency (DESIGN.md SS17) ---------- #

def _kv3(chip=2, fast=4, offload=16, *, bw=1e6, lat=1e-3, chip_bw=1e9,
         chip_lat=0.0, page_size=4, n_pages=64, **kw):
    """chiplet (promotion-only) / ddr / hbs manager for the SS17 tests."""
    tb = TierBudget((("chiplet", chip), ("ddr", fast), ("hbs", offload)),
                    n_promote=1)
    dev = SimulatedTierDevice(bandwidth=bw, latency=lat)
    cdev = SimulatedTierDevice(bandwidth=chip_bw, latency=chip_lat,
                               link="chiplet")
    return PagedKVManager(n_pages, page_size, tier_budget=tb,
                          page_nbytes=PB, tier_device=dev,
                          chiplet_device=cdev, **kw)


def test_three_level_budget_split_and_fresh_pages_skip_chiplet():
    """Satellite regression: a 3-level budget keeps the chiplet
    promotion-only — fresh pages land in ddr, overflow to hbs, and
    ``kv_tier_split`` stays a distribution over the tiers actually
    holding landed KV."""
    kv = _kv3(chip=2, fast=2, offload=16)
    assert kv.tier_budget.n_promote == 1
    assert kv.tier_budget.promote_tiers == (("chiplet", 2),)
    assert kv.tier_budget.offload_tier == "hbs"
    assert kv.tier_budget.fast_pages == 4          # chiplet + ddr
    kv.allocate(0, 4 * 4)                          # 2 ddr + 2 hbs overflow
    assert kv.tier_occupancy_pages()["chiplet"] == 0
    assert [kv.page_tier(p) for p in kv.seq_pages(0)] == (
        ["ddr"] * 2 + ["hbs"] * 2)
    split = dict(kv.kv_tier_split())
    assert "chiplet" not in split
    assert split["ddr"] == pytest.approx(0.5)
    assert split["hbs"] == pytest.approx(0.5)
    _check_residency(kv)
    # two consecutive hot rounds earn chiplet residency, and the split
    # then reports the promoted fraction
    kv.residency_stall([0], 0.0)
    kv.residency_stall([0], 1.0)
    assert kv.tier_occupancy_pages()["chiplet"] == 2
    assert dict(kv.kv_tier_split()).get("chiplet", 0.0) > 0.0
    _check_residency(kv)


def test_chiplet_promotion_needs_consecutive_touches():
    kv = _kv3(chip=2, fast=4, offload=16)
    kv.allocate(0, 2 * 4)                          # 2 landed ddr pages
    assert kv.residency_stall([0], 0.0) == 0.0     # round 1: EMA 1.0
    assert kv.chiplet_promotions == 0
    kv.residency_stall([0], 1.0)                   # round 2: EMA 1.5
    assert kv.chiplet_promotions == 2
    assert all(kv.page_tier(p) == "chiplet" for p in kv.seq_pages(0))
    assert kv.channel_bytes["ddr->chiplet"] == 2 * PB
    assert "chiplet->ddr" not in kv.channel_bytes  # room: no demotion
    _check_residency(kv)


def test_chiplet_lru_demotion_swaps_cold_resident():
    kv = _kv3(chip=1, fast=4, offload=16)
    kv.allocate(0, 4)
    kv.allocate(1, 4)
    kv.residency_stall([0], 0.0)
    kv.residency_stall([0], 1.0)                   # seq 0 promoted
    p0, p1 = kv.seq_pages(0)[0], kv.seq_pages(1)[0]
    assert kv.page_tier(p0) == "chiplet"
    kv.residency_stall([1], 2.0)
    kv.residency_stall([1], 3.0)                   # seq 1 hot, chiplet full
    assert kv.page_tier(p1) == "chiplet"           # swapped in
    assert kv.page_tier(p0) == "ddr"               # cold resident demoted
    assert kv.chiplet_promotions == 2 and kv.chiplet_demotions == 1
    assert kv.channel_bytes["chiplet->ddr"] == PB
    _check_residency(kv)


def test_dirty_writeback_vs_free_clean_demotion():
    """A spill victim is charged only when its content diverged from the
    offload copy; re-demoting an unmodified page is a free residency
    flip, and writing into it re-arms the write-back."""
    kv = _kv(fast=1, offload=16)
    kv.allocate(0, 3)                              # page A in ddr, dirty
    kv.allocate(1, 3)                              # page B in hbs
    kv.residency_stall([1], 0.0)                   # B in, A out: write-back
    assert kv.n_spills == 1 and kv.spill_bytes == PB
    kv.residency_stall([0], 1.0)                   # A in, B out: B dirty too
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB
    assert kv.clean_demotions == 0
    kv.residency_stall([1], 2.0)                   # A out again: now clean
    assert kv.n_spills == 2 and kv.spill_bytes == 2 * PB
    assert kv.clean_demotions == 1
    kv.residency_stall([0], 3.0)                   # B out again: also clean
    assert kv.clean_demotions == 2
    assert kv.channel_bytes["ddr->hbs"] == 2 * PB  # only charged spills
    kv.append_token(0)                             # A's content diverges
    kv.residency_stall([1], 4.0)                   # A out: charged again
    assert kv.n_spills == 3 and kv.spill_bytes == 3 * PB
    _check_residency(kv)


# ------------------- layer-sliced migration (SS17) --------------------- #

def test_transfer_sliced_chain_matches_bulk_transfer():
    dev = SimulatedTierDevice(bandwidth=1e5, latency=1e-3)
    dones = dev.transfer_sliced("in", 4 * PB, 0.0, 4)
    per = PB / 1e5
    # issue latency charged once; slice l lands at latency + (l+1)*per
    assert dones == pytest.approx([1e-3 + (i + 1) * per for i in range(4)])
    bulk = SimulatedTierDevice(bandwidth=1e5, latency=1e-3).transfer(
        "in", 4 * PB, 0.0)
    assert dones[-1] == pytest.approx(bulk)        # last slice == bulk done
    # the chain is ONE queued command: the channel frees at the last slice
    assert not dev.idle("in", dones[-1] - 1e-9)
    assert dev.idle("in", dones[-1])
    # n_slices=1 degenerates to the bulk transfer
    one = SimulatedTierDevice(bandwidth=1e5, latency=1e-3)
    assert one.transfer_sliced("in", 4 * PB, 0.0, 1) == [
        pytest.approx(bulk)]


def test_shared_writeback_link_serializes_directions():
    full = SimulatedTierDevice(bandwidth=1e5, latency=0.0)
    assert full.transfer("out", PB, 0.0) == pytest.approx(PB / 1e5)
    assert full.transfer("in", PB, 0.0) == pytest.approx(PB / 1e5)
    shared = SimulatedTierDevice(bandwidth=1e5, latency=0.0, duplex=False)
    s_out = shared.transfer("out", PB, 0.0)
    assert s_out == pytest.approx(PB / 1e5)
    assert not shared.idle("in", s_out - 1e-9)     # one queue for both
    assert shared.transfer("in", PB, 0.0) == pytest.approx(2 * PB / 1e5)


def test_plan_charge_pipeline_stall_bounded_by_barrier():
    """The split fetch-wait barrier: with layer slices pipelined against
    the layer loop the stall is only the un-hidden remainder, strictly
    below the whole-block counterfactual; n_slices=1 reproduces the
    barrier (and ``residency_stall``) exactly."""
    C = 0.02                                       # measured block compute
    kv = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv.allocate(0, 4 * 4)                          # 2-page demand fetch
    plan = kv.plan_residency([0], 0.0)
    assert len(plan.need) == 2
    per_seq = {}
    stall, barrier = kv.charge_residency(plan, 0.0, n_slices=4,
                                         compute_s=C, per_seq=per_seq)
    assert barrier == pytest.approx(1e-3 + 2 * PB / 1e5)
    # slices land at 6/11/16/21ms, layers take 5ms each -> ends at 26ms
    assert stall == pytest.approx(0.006)
    assert stall < barrier
    # per-request attribution still sums to the block's recorded stall
    assert sum(per_seq.values()) == pytest.approx(stall)

    kv1 = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv1.allocate(0, 4 * 4)
    s1, b1 = kv1.charge_residency(kv1.plan_residency([0], 0.0), 0.0,
                                  n_slices=1, compute_s=C)
    assert s1 == b1 == pytest.approx(barrier)
    kv2 = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv2.allocate(0, 4 * 4)
    assert kv2.residency_stall([0], 0.0) == pytest.approx(s1)
    # zero measured compute cannot hide anything: slicing is skipped
    kv3 = _kv(fast=2, offload=16, bw=1e5, lat=1e-3)
    kv3.allocate(0, 4 * 4)
    s3, b3 = kv3.charge_residency(kv3.plan_residency([0], 0.0), 0.0,
                                  n_slices=4, compute_s=0.0)
    assert s3 == b3


# ------------- per-channel byte accounting (SS17 satellite) ------------- #

def test_channel_bytes_reconcile_against_trace_dma_spans():
    from repro.serving import TraceRecorder

    tr = TraceRecorder()
    kv = _kv3(chip=2, fast=2, offload=16, bw=1e5, lat=1e-3, tracer=tr)
    kv.tier_device.tracer = tr
    kv.chiplet_device.tracer = tr
    kv.allocate(0, 4 * 4)                   # 2 ddr + 2 hbs
    kv.residency_stall([0], 0.0)            # streams 2 pages in
    kv.allocate(1, 4)                       # lands hbs (ddr pinned-full)
    kv.residency_stall([1], 1.0)            # spill + fetch
    kv.residency_stall([1], 2.0)            # promote seq 1's page
    assert kv.chiplet_promotions > 0
    got = dict(kv.channel_bytes)
    assert set(got) >= {"hbs->ddr", "ddr->chiplet"}
    assert tr.dma_bytes == got              # trace spans carry the labels
    report = tr.reconcile(stall_s=tr.stall_total, ttft=[], itl=[],
                          new_tokens=0, channel_bytes=got)
    assert report["ok"]
    bad = dict(got)
    bad["hbs->ddr"] = bad["hbs->ddr"] + 5 * PB
    with pytest.raises(AssertionError):
        tr.reconcile(stall_s=tr.stall_total, ttft=[], itl=[],
                     new_tokens=0, channel_bytes=bad)


def test_hypothesis_three_level_invariants_over_random_traces():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.integers(0, 6),      # op kind
                             st.integers(0, 5),      # seq id
                             st.integers(1, 40)),    # size / k
                   min_size=1, max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(ops=ops)
    def run(ops):
        kv = _kv3(chip=2, fast=3, offload=10, n_pages=32, bw=1e4, lat=1e-3)
        t = 0.0
        for kind, sid, n in ops:
            t += 0.01
            try:
                if kind == 0 and sid not in kv._seqs:
                    kv.allocate(sid, n)
                elif kind == 1 and sid in kv._seqs:
                    kv.free_seq(sid)
                elif kind == 2 and sid in kv._seqs:
                    kv.reserve_ahead(sid, n % 8 + 1)
                elif kind == 3 and sid in kv._seqs:
                    kv.release_reserved(sid)
                elif kind == 4 and sid in kv._seqs:
                    kv.prefetch_seqs([sid], t)
                elif kind == 5 and sid in kv._seqs:
                    stall = kv.residency_stall([sid], t)
                    assert stall >= 0.0
                    t += stall
                elif kind == 6 and sid in kv._seqs:
                    compute = 0.01 * (n % 3)
                    plan = kv.plan_residency([sid], t)
                    stall, barrier = kv.charge_residency(
                        plan, t, n_slices=4, compute_s=compute)
                    # overlap is never worse than the barrier it replaces
                    assert 0.0 <= stall <= barrier + 1e-12
                    t += stall + compute
            except PageAllocationError:
                pass                                  # admission pressure
            _check_residency(kv)
        for sid in list(kv._seqs):
            kv.free_seq(sid)
        _check_residency(kv)
        assert sum(kv.tier_occupancy_pages().values()) == 0
        # chiplet traffic is conserved: bytes == page moves on that link
        assert kv.channel_bytes.get("ddr->chiplet", 0.0) == (
            kv.chiplet_promotions * PB)
        assert kv.channel_bytes.get("chiplet->ddr", 0.0) == (
            kv.chiplet_demotions * PB)

    run()


# ------------------- engine-level SS17 behaviour ------------------------ #

def _chiplet_hierarchy(cfg, fast_pages, chiplet_pages, page_size=8):
    from repro.core import hbs, lpddr6, npu_hierarchy, sram_chiplet
    from repro.serving.kv_manager import page_bytes

    pb = page_bytes(cfg, page_size, 4)
    return npu_hierarchy(lpddr6(capacity_gb=fast_pages * pb / 1e9),
                         hbs(8.0, latency_us=20.0, capacity_gb=1.0),
                         chiplet=sram_chiplet(
                             512.0, capacity_mb=chiplet_pages * pb / 1e6))


@pytest.mark.slow
def test_engine_layer_overlap_token_identical_and_never_worse(small_model):
    """Tentpole acceptance at engine level: layer-sliced migration is
    token-identical to both the no-offload and the whole-block-barrier
    runs, never stalls more than its own barrier counterfactual, and the
    ``--no-layer-overlap`` baseline reports zero savings."""
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(6)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (20, 9, 14)]
    kw = dict(max_len=40, scheduler="continuous", page_size=8, max_batch=3,
              prefill_budget=96)
    base = ServeEngine(cfg, params, opts, **kw)
    want = base.serve([r[:] for r in reqs], 8)
    hier = _offload_hierarchy(cfg, fast_pages=4)
    okw = dict(hierarchy=hier, hbs_gbps=1e-3, hbs_latency_us=500.0)

    overlap = ServeEngine(cfg, params, opts, **kw, **okw)
    assert overlap.n_layer_slices == cfg.n_layers == 2
    assert overlap.serve([r[:] for r in reqs], 8) == want
    barrier = ServeEngine(cfg, params, opts, **kw, **okw,
                          layer_overlap=False)
    assert barrier.n_layer_slices == 1
    assert barrier.serve([r[:] for r in reqs], 8) == want
    assert barrier.stats.stall_saved_s == 0.0
    # within-run counterfactual: stall + saved is what the barrier would
    # have recorded, so overlap can only help
    assert overlap.stats.stall_saved_s > 0.0
    assert overlap.stats.stall_s <= (
        overlap.stats.stall_s + overlap.stats.stall_saved_s)


def test_engine_chiplet_promotions_and_channel_stats(small_model):
    from repro.serving import ServeEngine

    cfg, opts, params = small_model
    rng = np.random.default_rng(7)
    reqs = [rng.integers(1, cfg.vocab, size=16).tolist() for _ in range(3)]
    kw = dict(max_len=32, scheduler="continuous", page_size=8, max_batch=3,
              prefill_budget=96)
    base = ServeEngine(cfg, params, opts, **kw)
    want = base.serve([r[:] for r in reqs], 8)
    hier = _chiplet_hierarchy(cfg, fast_pages=3, chiplet_pages=2)
    eng = ServeEngine(cfg, params, opts, **kw, hierarchy=hier,
                      hbs_gbps=0.01, hbs_latency_us=20.0,
                      chiplet_gbps=512.0, chiplet_latency_us=0.05)
    assert eng.serve([r[:] for r in reqs], 8) == want
    s = eng.stats
    assert s.chiplet_promotions > 0
    assert 0.0 < s.chiplet_hit_rate <= 1.0
    assert s.tier_touches.get("chiplet", 0) > 0
    assert s.channel_bytes.get("ddr->chiplet", 0.0) == pytest.approx(
        s.chiplet_promotions * eng.page_nbytes)
