"""Speculative decoding on the fused paged path (DESIGN.md SS14).

Covers the multi-query verify kernel vs its jnp oracle (f32 + int8,
page-boundary causal masking), leftover/rejection sampling correctness
(greedy identity + distribution sanity), the manager's
``commit_speculative`` partial-rollback protocol (unit + hypothesis
trace), the draft proposers, and engine-level token identity: spec-on at
temperature 0 equals spec-off for both draft modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.decode_attention as da
import repro.kernels.ref as ref
from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import RuntimeOptions, init_params, sampling
from repro.serving import (AdaptiveSpecK, ModelDraft, NGramDraft,
                           PagedKVManager, Request, ServeEngine)


# --------------------------- verify kernel ------------------------------ #

@pytest.mark.parametrize("B,H,Hkv,dh,ps,C,lens,fed", [
    (2, 8, 2, 64, 16, 8, (40, 17), (8, 5)),   # GQA, ragged starts
    (1, 4, 1, 128, 16, 4, (30,), (3,)),       # MQA, window crosses a page
    (2, 4, 4, 64, 8, 8, (8, 15), (1, 8)),     # MHA, fed=1 == plain decode
])
def test_spec_verify_kernel_matches_oracle(B, H, Hkv, dh, ps, C, lens, fed):
    """Acceptance: the Pallas verify pass matches the jnp oracle in
    interpret mode, per-row causal masking included — row j of slot b
    attends exactly ``lens[b] + min(j, fed[b] - 1) + 1`` positions."""
    L = max(l + C for l in lens)
    npp = -(-L // ps) + 1
    P = B * npp + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, dh), jnp.float32)
    perm = np.asarray(jax.random.permutation(ks[0], P - 1)) + 1
    pt = jnp.asarray(perm[:B * npp].reshape(B, npp), jnp.int32)
    sl = jnp.asarray(lens, jnp.int32)
    nf = jnp.asarray(fed, jnp.int32)
    out = da.spec_verify_attention(q, kp, vp, pt, sl, nf, interpret=True)
    want = ref.spec_verify_attention_ref(q, kp, vp, pt, sl, nf,
                                         scale=dh ** -0.5)
    for b in range(B):
        np.testing.assert_allclose(out[b, :fed[b]], want[b, :fed[b]],
                                   atol=1e-5, rtol=1e-5)


def test_spec_verify_kernel_int8():
    B, C, H, Hkv, dh, ps, npp = 1, 8, 8, 2, 64, 32, 3
    P = npp + 2
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, dh), jnp.float32)
    pt = jnp.asarray([[2, 3, 1]], jnp.int32)
    sl, nf = jnp.asarray([40], jnp.int32), jnp.asarray([8], jnp.int32)
    ki, vi, ksc, vsc = da.quantize_kv(kp, vp)
    out = da.spec_verify_attention(q, ki, vi, pt, sl, nf, k_scale=ksc,
                                   v_scale=vsc, interpret=True)
    want = ref.spec_verify_attention_ref(q, ki, vi, pt, sl, nf,
                                         scale=dh ** -0.5,
                                         k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    fp = ref.spec_verify_attention_ref(q, kp, vp, pt, sl, nf,
                                       scale=dh ** -0.5)
    assert float(jnp.max(jnp.abs(out - fp))) < 0.05


def test_spec_verify_rows_ignore_later_draft_kv():
    """Causal independence across the verify window: corrupting the KV of
    fed position j must leave rows 0..j-1 untouched (page-boundary case:
    the window spans two pages)."""
    B, C, H, Hkv, dh, ps = 1, 4, 4, 2, 64, 4
    lens, fed = 6, 4                       # window occupies slots 6..9:
    npp = 4                                # crosses the page-1 boundary
    P = npp + 1
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, Hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, Hkv, dh), jnp.float32)
    pt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    sl = jnp.asarray([lens], jnp.int32)
    nf = jnp.asarray([fed], jnp.int32)
    base = da.spec_verify_attention(q, kp, vp, pt, sl, nf, interpret=True)
    # corrupt the LAST fed position's KV (token index lens+fed-1 = 9,
    # page 2 slot 1) — only the final row may see it
    kp2 = kp.at[3, 1].set(100.0)
    vp2 = vp.at[3, 1].set(-100.0)
    out = da.spec_verify_attention(q, kp2, vp2, pt, sl, nf, interpret=True)
    np.testing.assert_allclose(out[:, :fed - 1], base[:, :fed - 1],
                               atol=1e-5, rtol=1e-5)
    assert float(jnp.max(jnp.abs(out[:, fed - 1] - base[:, fed - 1]))) > 1.0


# ------------------------ accept / reject rules ------------------------- #

def test_spec_accept_greedy_prefix_and_correction():
    """Greedy accept = longest argmax-matching prefix; the emitted block is
    [accepted drafts, correction from the first rejected row, pads]."""
    V = 8
    tgt_rows = np.asarray([[1, 2, 3, 4], [5, 0, 0, 0]])       # argmax chain
    logits = np.full((2, 4, V), -5.0, np.float32)
    for b in range(2):
        for j in range(4):
            logits[b, j, tgt_rows[b, j]] = 5.0
    draft = jnp.asarray([[1, 2, 9], [6, 0, 0]], jnp.int32)    # b0: 2 match
    dl = jnp.asarray([3, 3], jnp.int32)                       # b1: 0 match
    keys = jnp.zeros((2, 2), jnp.uint32)
    out, n_acc, _ = sampling.spec_accept(jnp.asarray(logits), draft, dl,
                                         keys, temperature=0.0, pad_id=0)
    np.testing.assert_array_equal(np.asarray(n_acc), [2, 0])
    np.testing.assert_array_equal(np.asarray(out),
                                  [[1, 2, 3, 0], [5, 0, 0, 0]])


def test_spec_accept_full_acceptance_emits_bonus():
    V = 8
    logits = np.full((1, 3, V), -5.0, np.float32)
    for j, t in enumerate([4, 5, 6]):
        logits[0, j, t] = 5.0
    out, n_acc, _ = sampling.spec_accept(
        jnp.asarray(logits), jnp.asarray([[4, 5]], jnp.int32),
        jnp.asarray([2], jnp.int32), jnp.zeros((1, 2), jnp.uint32),
        temperature=0.0)
    assert int(n_acc[0]) == 2
    np.testing.assert_array_equal(np.asarray(out), [[4, 5, 6]])


def test_rejection_sampling_matches_target_distribution():
    """Distribution sanity (chi-square-loose / total-variation): for a
    one-hot draft the accept-or-leftover construction is exactly unbiased
    — P(emit x) = p(x) for EVERY fixed draft d — so the empirical first
    token over many keys must track softmax(logits/T)."""
    V, N = 6, 6000
    row = np.asarray([1.2, 0.3, -0.4, 2.0, 0.0, -1.0], np.float32)
    logits = jnp.asarray(np.tile(row, (N, 2, 1)))      # C=2: 1 draft+bonus
    want = np.asarray(jax.nn.softmax(jnp.asarray(row) / 0.9))
    for d in (3, 1):                                   # likely + unlikely
        draft = jnp.full((N, 1), d, jnp.int32)
        keys = jax.random.split(jax.random.PRNGKey(d), N)
        out, n_acc, _ = sampling.spec_accept(
            logits, draft, jnp.ones((N,), jnp.int32), keys, temperature=0.9)
        first = np.asarray(out[:, 0])
        emp = np.bincount(first, minlength=V) / N
        assert 0.5 * np.abs(emp - want).sum() < 0.05
        # acceptance rate itself must track p(d)
        assert abs(np.asarray(n_acc).mean() - want[d]) < 0.05


# -------------------- manager: partial rollback ------------------------- #

def test_commit_speculative_partial_rollback_unit():
    kv = PagedKVManager(n_pages=9, page_size=4)
    kv.allocate(0, 6)                                  # 2 pages, slot 6 next
    used0 = kv.n_used
    claimed = kv.reserve_ahead(0, 5)                   # covers tokens 6..10
    assert len(claimed) == 1                           # page for 8..11
    rolled = kv.commit_speculative(0, 1)               # accept 1 of 5
    assert kv.seq_len(0) == 7
    assert rolled == 1                                 # surplus page freed
    assert kv.n_used == used0
    # re-reserve after rollback: the protocol is reentrant
    kv.reserve_ahead(0, 5)                             # 7 + 5 -> 12: 1 new
    rolled = kv.commit_speculative(0, 5)               # full acceptance
    assert kv.seq_len(0) == 12 and rolled == 0
    assert kv.n_used == used0 + 1


def test_commit_speculative_hypothesis_trace():
    """Random reserve/verify/rollback traces preserve the invariants:
    pages exactly cover the landed extent after every commit_speculative,
    the landed length equals the sum of accepted counts, and no page
    leaks (total used == pages_needed of every live sequence)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2),      # seq id
                              st.integers(1, 6),      # draft_len + 1
                              st.floats(0.0, 1.0)),   # acceptance fraction
                    min_size=1, max_size=25))
    def trace(ops):
        ps = 4
        kv = PagedKVManager(n_pages=64, page_size=ps)
        landed = {}
        for sid, window, frac in ops:
            if sid not in landed:
                kv.allocate(sid, 2)
                landed[sid] = 2
            kv.reserve_ahead(sid, window)
            acc = int(round(frac * window))
            kv.commit_speculative(sid, acc)
            landed[sid] += acc
            assert kv.seq_len(sid) == landed[sid]
            pages = kv._seqs[sid].pages
            assert len(pages) == kv.pages_needed(landed[sid])
        total = sum(kv.pages_needed(n) for n in landed.values())
        assert kv.n_used == total

    trace()


# ----------------------------- drafters --------------------------------- #

def test_ngram_draft_unrolls_loops_to_full_k():
    """A period-2 decode loop must draft the full window, not truncate at
    the latest occurrence (the iterated-rollout property)."""
    d = NGramDraft(max_ngram=3, min_ngram=1)
    req = Request(rid=0, prompt=[9, 1, 2, 1, 2, 1, 2], max_new_tokens=8)
    got = d.propose(req, 6)
    assert got == [1, 2, 1, 2, 1, 2]
    assert d.propose(Request(rid=1, prompt=[3, 4, 5], max_new_tokens=8),
                     4) == []                          # no repeat: no draft
    d.drop(0)
    assert 0 not in d._idx and 0 not in d._seen


def test_ngram_draft_prefers_longest_match():
    d = NGramDraft(max_ngram=3, min_ngram=1)
    # trailing [7,8] occurs earlier followed by 5; trailing [8] also occurs
    # followed by 6 — the longer match must win
    req = Request(rid=0, prompt=[7, 8, 5, 0, 8, 6, 0, 7, 8],
                  max_new_tokens=4)
    assert d.propose(req, 1) == [5]


def test_adaptive_spec_k_tracks_acceptance():
    a = AdaptiveSpecK(8, k_min=1, beta=0.5)
    r = Request(rid=0, prompt=[1], max_new_tokens=4)
    assert a.k_for(r) == 8                             # optimistic start
    for _ in range(6):
        a.update(r, 8, 0)                              # everything rejected
    assert a.k_for(r) == 1
    for _ in range(6):
        a.update(r, 8, 8)
    assert a.k_for(r) == 8
    a.update(r, 0, 0)                                  # no-op: nothing asked
    assert a.k_for(r) == 8
    with pytest.raises(ValueError):
        AdaptiveSpecK(0)


def test_model_draft_sync_catchup_propose():
    """Protocol unit: admit syncs to the target's landed extent, catch-up
    absorbs committed tokens, propose returns k tokens and rolls its
    reservation back (landed draft extent unchanged)."""
    cfg = reduced(get_config("llama3.2-1b"), d_model=32, n_layers=1,
                  vocab=64)
    d = ModelDraft(cfg, page_size=4, max_batch=2, max_len=32)
    req = Request(rid=7, prompt=[3, 1, 4, 1, 5], max_new_tokens=8)
    out = d.propose_all([(req, 3)])
    assert set(out) == {7} and len(out[7]) == 3
    assert all(0 <= t < cfg.vocab for t in out[7])
    assert d.kv.seq_len(7) == len(req.prefill_tokens) - 1   # rolled back
    req.out.extend([9, 2])                     # target committed 2 tokens
    out2 = d.propose_all([(req, 3)])
    assert d.kv.seq_len(7) == len(req.prefill_tokens) - 1   # caught up
    assert len(out2[7]) == 3
    # determinism given the same request state (one-hot draft assumption)
    assert d.propose_all([(req, 3)])[7] == out2[7]
    d.drop(7)
    assert d.kv.n_used == 0


# --------------------------- engine identity ---------------------------- #

@pytest.fixture(scope="module")
def spec_model():
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    rng = np.random.default_rng(0)
    doc = rng.integers(1, 120, size=40).tolist()
    reqs = [doc + rng.integers(1, 120, size=5).tolist() for _ in range(3)]
    return cfg, opts, params, reqs


def _serve(cfg, params, opts, reqs, *, prefix=True, **kw):
    eng = ServeEngine(cfg, params, opts, max_len=96, max_batch=2,
                      scheduler="continuous", page_size=8, prefill_chunk=16,
                      prefix_cache=prefix, **kw)
    return eng.serve([r[:] for r in reqs], max_new_tokens=10), eng.stats


def test_engine_ngram_spec_token_identity(spec_model):
    """Acceptance (fast lane): spec-on at temperature 0 is token-identical
    to spec-off, and drafts actually land."""
    cfg, opts, params, reqs = spec_model
    want, _ = _serve(cfg, params, opts, reqs)
    got, s = _serve(cfg, params, opts, reqs, spec_mode="ngram", spec_k=4)
    assert got == want
    assert s.spec_blocks > 0 and s.draft_accepted > 0
    assert 0.0 < s.acceptance_rate <= 1.0


@pytest.mark.slow
@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("prefix", [True, False])
def test_engine_ngram_spec_identity_matrix(spec_model, k, prefix):
    cfg, opts, params, reqs = spec_model
    want, _ = _serve(cfg, params, opts, reqs, prefix=prefix)
    got, _ = _serve(cfg, params, opts, reqs, prefix=prefix,
                    spec_mode="ngram", spec_k=k)
    assert got == want


@pytest.mark.slow
@pytest.mark.parametrize("k", [1, 4])
def test_engine_model_draft_identity(spec_model, k):
    cfg, opts, params, reqs = spec_model
    dcfg = reduced(get_config("llama3.2-1b"), d_model=32, n_layers=1,
                   vocab=128)
    want, _ = _serve(cfg, params, opts, reqs)
    got, s = _serve(cfg, params, opts, reqs, spec_mode="model", spec_k=k,
                    draft_cfg=dcfg)
    assert got == want
    assert s.spec_blocks > 0


def test_engine_spec_flag_validation(spec_model):
    cfg, opts, params, _ = spec_model
    mk = lambda **kw: ServeEngine(cfg, params, opts, max_len=64,
                                  scheduler="continuous", **kw)
    with pytest.raises(ValueError, match="spec_mode"):
        mk(spec_mode="banana")
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(cfg, params, opts, max_len=64, scheduler="static",
                    spec_mode="ngram")
    with pytest.raises(ValueError, match="draft_cfg"):
        mk(spec_mode="model")                  # model mode needs a config
    with pytest.raises(ValueError, match="draft_cfg"):
        mk(draft_cfg=cfg)                      # config needs model mode
    with pytest.raises(ValueError, match="temperature"):
        mk(top_k=5)                            # filters need temperature
    with pytest.raises(ValueError, match="spec_k"):
        mk(spec_mode="ngram", spec_k=0)


def test_engine_stall_attribution_per_request(spec_model):
    """Satellite: ServeStats.stall_by_rid partitions the recorded stall."""
    from repro.core import hbs, lpddr6, npu_hierarchy
    cfg, opts, params, reqs = spec_model
    hier = npu_hierarchy(lpddr6(capacity_gb=2e-5),
                         hbs(0.001, latency_us=50.0, capacity_gb=1.0))
    eng = ServeEngine(cfg, params, opts, max_len=96, max_batch=2,
                      scheduler="continuous", page_size=8, prefill_chunk=16,
                      hierarchy=hier, hbs_gbps=0.001, hbs_latency_us=50.0)
    eng.serve([r[:] for r in reqs], max_new_tokens=10)
    s = eng.stats
    assert s.stall_s > 0
    assert s.stall_by_rid
    assert all(v > 0 for v in s.stall_by_rid.values())
    # each barrier absorbs the batch MAX while charging every request its
    # own pages' wait, so no single request can out-accrue the total
    assert max(s.stall_by_rid.values()) <= s.stall_s + 1e-9
