"""Shared latency-statistics helpers and the sectioned BENCH_serve.json
writer (DESIGN.md SS15 satellites): one percentile implementation for
engine + benchmarks, and a merge that can never clobber another
benchmark's section."""
import json
import os
import sys

import numpy as np
import pytest

from repro.serving import metrics
from repro.serving.engine import ServeStats

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))
from benchmarks.common import (BENCH_SECTIONS, goodput_summary,  # noqa: E402
                               merge_bench_json)


# ------------------------- percentile helpers -------------------------- #

def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.uniform(0, 1, size=37).tolist()
    for q in (0, 25, 50, 95, 99.9, 100):
        assert metrics.percentile(xs, q) == pytest.approx(
            float(np.percentile(np.asarray(xs), q)))


def test_percentile_empty_is_zero():
    assert metrics.percentile([], 50) == 0.0
    assert metrics.percentile((), 95) == 0.0


def test_percentile_rejects_bad_q():
    with pytest.raises(ValueError):
        metrics.percentile([1.0], -1)
    with pytest.raises(ValueError):
        metrics.percentile([1.0], 100.5)


def test_pct_ms_converts_and_rounds():
    # 12.3456 ms with the default 3-digit rounding
    assert metrics.pct_ms([0.0123456], 50) == 12.346
    assert metrics.pct_ms([0.0123456], 50, ndigits=1) == 12.3
    assert metrics.pct_ms([], 95) == 0.0


def test_latency_summary_ms_fields():
    out = metrics.latency_summary_ms([0.010, 0.020, 0.030])
    assert out["n"] == 3
    assert out["p50_ms"] == pytest.approx(20.0)
    assert out["mean_ms"] == pytest.approx(20.0)
    assert out["max_ms"] == pytest.approx(30.0)
    empty = metrics.latency_summary_ms([])
    assert empty == {"p50_ms": 0.0, "p95_ms": 0.0, "mean_ms": 0.0,
                     "max_ms": 0.0, "n": 0}


def test_serve_stats_uses_shared_percentile():
    """ServeStats percentile properties must be bit-identical to the
    shared helper (the pre-SS15 duplication is gone)."""
    s = ServeStats()
    assert s.ttft_p50 == 0.0 and s.itl_p95 == 0.0     # empty convention
    s.ttft = [0.01, 0.02, 0.05, 0.3]
    s.itl = [0.001, 0.002, 0.009]
    assert s.ttft_p95 == metrics.percentile(s.ttft, 95)
    assert s.itl_p50 == metrics.percentile(s.itl, 50)


# --------------------- BENCH_serve.json merge writer -------------------- #

def _payload(section):
    return {k: {} for k in BENCH_SECTIONS[section]}


def test_merge_preserves_other_sections(tmp_path):
    path = str(tmp_path / "BENCH_serve.json")
    merge_bench_json(path, "serve_bench", _payload("serve_bench"))
    merge_bench_json(path, "hbs_sweep", _payload("hbs_sweep"))
    merge_bench_json(path, "spec_sweep", _payload("spec_sweep"))
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"serve_bench", "hbs_sweep", "spec_sweep"}
    # re-running one benchmark replaces only its own section
    pl = _payload("serve_bench")
    pl["derived"] = {"marker": 1}
    merge_bench_json(path, "serve_bench", pl)
    with open(path) as f:
        doc = json.load(f)
    assert doc["serve_bench"]["derived"] == {"marker": 1}
    assert set(doc) == {"serve_bench", "hbs_sweep", "spec_sweep"}


def test_merge_rejects_unknown_section(tmp_path):
    with pytest.raises(ValueError, match="unknown"):
        merge_bench_json(str(tmp_path / "b.json"), "mystery", {})


def test_merge_validates_required_keys(tmp_path):
    path = str(tmp_path / "b.json")
    bad = _payload("spec_sweep")
    del bad["ngram"]
    with pytest.raises(ValueError, match="missing required keys"):
        merge_bench_json(path, "spec_sweep", bad)
    assert not os.path.exists(path)          # nothing written on failure


def test_merge_rejects_legacy_top_level_layout(tmp_path):
    path = str(tmp_path / "b.json")
    with open(path, "w") as f:
        json.dump({"workload": {}, "derived": {}}, f)   # pre-SS15 layout
    with pytest.raises(ValueError, match="non-section top-level"):
        merge_bench_json(path, "serve_bench", _payload("serve_bench"))


def test_merge_rejects_corrupt_file(tmp_path):
    path = str(tmp_path / "b.json")
    with open(path, "w") as f:
        f.write("{ not json")
    with pytest.raises(ValueError, match="not valid JSON"):
        merge_bench_json(path, "serve_bench", _payload("serve_bench"))


def test_goodput_summary_counts_blame():
    rep = {"goodput_frac": 0.5, "n_met_slo": 2, "n_requests": 4,
           "violators": [{"blame": "stall"}, {"blame": "stall"},
                         {"blame": "queue"}]}
    out = goodput_summary(rep)
    assert out["violator_blame"] == {"stall": 2, "queue": 1}
    assert out["goodput_frac"] == 0.5
