"""STCO driver tests: requirement solvers invert the paper's Fig. 1."""

from repro.configs import get_config
from repro.core import all_hbs, qkv_in_ddr
from repro.core.stco import (max_tolerable_latency, required_bandwidth,
                             sweep)
from repro.core.memspec import hbs, lpddr6, npu_hierarchy


def test_required_bandwidth_monotone_in_target():
    cfg = get_config("llava15-13b")
    bw5 = required_bandwidth(cfg, qkv_in_ddr(), target_tps=5.0,
                             prefill=200, decode=200)
    bw10 = required_bandwidth(cfg, qkv_in_ddr(), target_tps=10.0,
                              prefill=200, decode=200)
    assert bw5 is not None and bw10 is not None
    assert bw10 > bw5
    # paper: ~10 TPS needs hundreds of GB/s of HBS with Q/K/V in DDR
    assert 100 <= bw10 <= 1024


def test_latency_requirement_matches_fig1b():
    """Paper Fig. 1(b): at 512 GB/s all-in-HBS only ~2 us meets 10 TPS."""
    cfg = get_config("llava15-13b")
    lat = max_tolerable_latency(cfg, all_hbs(), target_tps=10.0,
                                bw_gbps=512.0, prefill=200, decode=200)
    assert lat is not None and 1.0 <= lat <= 8.0


def test_sweep_shapes():
    cfg = get_config("llama3.2-1b")
    hiers = {"lpddr6": npu_hierarchy(lpddr6(173.0)),
             "lpddr6+hbs": npu_hierarchy(lpddr6(173.0), hbs(256.0, 10.0))}
    pts = sweep([cfg], hiers, [all_hbs(), qkv_in_ddr()],
                [(128, 128), (1024, 512)])
    assert len(pts) == 2 * 2 * 2
    assert all(p.tps > 0 for p in pts if p.hierarchy == "lpddr6+hbs")
