"""Fused multi-step decode (DESIGN.md SS12): on-device sampling, EOS/quota
latching, lookahead page reservation, and the K=1 equivalence guarantee.

Covers the model-level fused scan vs the per-step loop (f32 + int8), the
manager's all-or-nothing ``reserve_ahead`` / ``commit_tokens`` /
``release_reserved`` protocol, preemption during a reserved lookahead
window, and engine-level token-identity plus the host-sync bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import (RuntimeOptions, decode_step_paged,
                          decode_steps_paged, init_paged_cache, init_params,
                          prefill_paged)
from repro.serving import (ContinuousScheduler, PageAllocationError,
                           PagedKVManager, Request, ServeEngine)
from repro.serving.engine import _pad_pow2


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


# ----------------------- model-level equivalence ------------------------ #

def _paged_setup(cfg, params, opts, *, K=6, ps=4, seed=3):
    """Prefill two ragged prompts into a paged pool with room for K steps.

    Returns (cache, tok0, seq_lens, full page_table)."""
    B, S = 2, 8
    rng = np.random.default_rng(seed)
    true_len = np.asarray([8, 6], np.int32)
    toks = np.zeros((B, S), np.int32)
    for b in range(B):
        toks[b, :true_len[b]] = rng.integers(1, cfg.vocab, size=true_len[b])
    npp = (S + K + ps - 1) // ps
    n_pages = B * npp + 1
    pt_full = np.arange(1, B * npp + 1, dtype=np.int32).reshape(B, npp)
    cache = init_paged_cache(cfg, n_pages, ps, opts)
    logits, cache = prefill_paged(cfg, params, jnp.asarray(toks), cache,
                                  jnp.asarray(pt_full[:, :S // ps]),
                                  jnp.asarray(true_len), opts,
                                  calibrate=opts.cache_dtype == "int8")
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return cache, tok0, jnp.asarray(true_len), jnp.asarray(pt_full)


def _per_step_loop(cfg, params, opts, cache, tok, lens, pt, K):
    """The pre-SS12 host loop: decode_step_paged + host argmax per token."""
    cols = []
    for _ in range(K):
        logits, cache = decode_step_paged(cfg, params, tok, lens, pt, cache,
                                          opts)
        tok = jnp.asarray(np.argmax(np.asarray(logits), axis=-1), jnp.int32)
        cols.append(np.asarray(tok))
        lens = lens + 1
    return np.stack(cols, axis=1)


@pytest.mark.slow
@pytest.mark.parametrize("cache_dtype", ["", "int8"])
def test_fused_scan_matches_per_step_loop(small_model, cache_dtype):
    """Acceptance: decode_steps_paged(K) == K iterations of
    decode_step_paged + host argmax, bit-identical tokens (f32 and int8)."""
    cfg, opts, params = small_model
    import dataclasses
    opts = dataclasses.replace(opts, cache_dtype=cache_dtype)
    K = 6
    cache, tok0, lens, pt = _paged_setup(cfg, params, opts, K=K)
    want = _per_step_loop(cfg, params, opts, cache, tok0, lens, pt, K)
    blk, _ = decode_steps_paged(cfg, params, tok0, lens, pt, cache, K, opts)
    assert np.array_equal(np.asarray(blk), want)


@pytest.mark.slow
def test_fused_scan_eos_latch_emits_pads(small_model):
    """EOS mid-block: tokens after a slot's EOS are pad_id and its length
    freezes (writes go to the null page)."""
    cfg, opts, params = small_model
    K = 6
    cache, tok0, lens, pt = _paged_setup(cfg, params, opts, K=K)
    free = _per_step_loop(cfg, params, opts, cache, tok0, lens, pt, K)
    eos = int(free[0, 2])
    t = int(np.flatnonzero(free[0] == eos)[0])   # first emission of eos
    blk, _ = decode_steps_paged(cfg, params, tok0, lens, pt, cache, K, opts,
                                eos_id=eos, pad_id=0)
    blk = np.asarray(blk)
    assert np.array_equal(blk[0, :t + 1], free[0, :t + 1])  # incl. the EOS
    assert (blk[0, t + 1:] == 0).all()                      # then pads
    # the other slot is unaffected unless it happens to emit eos too
    stop1 = np.flatnonzero(free[1] == eos)
    limit = int(stop1[0]) + 1 if stop1.size else K
    assert np.array_equal(blk[1, :limit], free[1, :limit])


@pytest.mark.slow
def test_fused_scan_quota_latch(small_model):
    """A slot's device-side quota mirrors its remaining budget: emissions
    past it are pads, and earlier tokens are unchanged."""
    cfg, opts, params = small_model
    K = 6
    cache, tok0, lens, pt = _paged_setup(cfg, params, opts, K=K)
    free = _per_step_loop(cfg, params, opts, cache, tok0, lens, pt, K)
    blk, _ = decode_steps_paged(cfg, params, tok0, lens, pt, cache, K, opts,
                                quota=jnp.asarray([2, K], jnp.int32))
    blk = np.asarray(blk)
    assert np.array_equal(blk[0, :2], free[0, :2])
    assert (blk[0, 2:] == 0).all()
    assert np.array_equal(blk[1], free[1])


@pytest.mark.slow
def test_fused_scan_done_slots_inert(small_model):
    """Slots that start done (inactive batch lanes) emit pads only and do
    not disturb live slots."""
    cfg, opts, params = small_model
    K = 4
    cache, tok0, lens, pt = _paged_setup(cfg, params, opts, K=K)
    free = _per_step_loop(cfg, params, opts, cache, tok0, lens, pt, K)
    blk, _ = decode_steps_paged(cfg, params, tok0, lens, pt, cache, K, opts,
                                done=jnp.asarray([False, True]))
    blk = np.asarray(blk)
    assert np.array_equal(blk[0], free[0])
    assert (blk[1] == 0).all()


# ------------------- manager: lookahead reservation --------------------- #

def _pool_ok(kv):
    assert kv.n_free + kv.n_evictable + kv.n_used == kv.n_pages - 1


def test_reserve_ahead_commit_release():
    kv = PagedKVManager(n_pages=8, page_size=4)
    kv.allocate(0, 6)                        # 2 pages, partial second page
    assert kv.reserve_ahead(0, 2) == []      # 8 tokens still fit 2 pages
    claimed = kv.reserve_ahead(0, 6)         # 12 tokens -> 1 fresh page
    assert len(claimed) == 1 and kv.n_used == 3
    assert kv.seq_len(0) == 6                # reservation lands no tokens
    _pool_ok(kv)
    kv.commit_tokens(0, 6)
    assert kv.seq_len(0) == 12
    with pytest.raises(ValueError):
        kv.commit_tokens(0, 1)               # beyond the reserved extent
    # release: drop a reserved window the block never used
    kv.reserve_ahead(0, 4)
    assert kv.n_used == 4
    assert kv.release_reserved(0) == 1
    assert kv.n_used == 3 and kv.seq_len(0) == 12
    _pool_ok(kv)


def test_reserve_ahead_all_or_nothing_rollback():
    kv = PagedKVManager(n_pages=8, page_size=4)
    kv.allocate(0, 6)
    kv.allocate(1, 20)                       # 5 pages; pool now full
    state = (kv.n_free, kv.n_used, tuple(kv.seq_pages(0)))
    with pytest.raises(PageAllocationError):
        kv.reserve_ahead(0, 8)               # needs 2 pages, 0 allocatable
    assert (kv.n_free, kv.n_used, tuple(kv.seq_pages(0))) == state
    assert kv.drain_copies() == []           # nothing half-claimed
    kv.free_seq(0)
    kv.free_seq(1)
    assert kv.n_used == 0
    _pool_ok(kv)


def test_reserve_ahead_cows_shared_window_page():
    """A shared page inside the lookahead write window is copied-on-write
    during the reservation, so the fused scan never writes into it."""
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    kv.allocate(0, 6)
    kv.register_prefix(0, list(range(6)), n_valid=4)
    kv.allocate_shared(1, list(range(6)))    # shares seq 0's first page
    kv._seqs[1].n_tokens = 3                 # next write hits the shared page
    shared = kv.seq_pages(0)[0]
    assert kv.page_ref(shared) == 2
    claimed = kv.reserve_ahead(1, 2)
    assert kv.seq_pages(1)[0] != shared and kv.page_ref(shared) == 1
    assert kv.drain_copies() == [(shared, kv.seq_pages(1)[0])]
    assert kv.seq_pages(1)[0] in claimed
    _pool_ok(kv)


def test_free_seq_purges_stale_pending_copies():
    """A COW copy queued by reserve_ahead must not survive its sequence's
    preemption: the freed dst page can be re-claimed and re-targeted before
    the engine drains, and duplicate-dst scatters apply in undefined
    order."""
    kv = PagedKVManager(n_pages=12, page_size=4, enable_prefix_cache=True)
    kv.allocate(0, 6)
    kv.register_prefix(0, list(range(6)), n_valid=4)
    kv.allocate_shared(1, list(range(6)))    # shares seq 0's first page
    kv._seqs[1].n_tokens = 3                 # next write hits the shared page
    kv.reserve_ahead(1, 2)                   # queues (shared, dst)
    assert kv._pending_copies
    kv.free_seq(1)                           # preempted before the drain
    assert kv.drain_copies() == []
    _pool_ok(kv)


def test_preempt_during_reserved_window_releases_all_pages():
    """Satellite acceptance: LIFO preemption hitting a slot that holds a
    reserved lookahead window returns every page — reserved included."""
    kv = PagedKVManager(n_pages=5, page_size=4)
    sched = ContinuousScheduler(kv, 2, prefill_chunk=4)
    a = Request(rid=0, prompt=[1] * 4, max_new_tokens=12)
    b = Request(rid=1, prompt=[2] * 4, max_new_tokens=12)
    sched.submit(a)
    sched.submit(b)
    (sa, _), (sb, _) = sched.admit()
    for slot, req in ((sa, a), (sb, b)):
        req.n_prefilled = 4
        sched.finish_prefill(slot)
        req.out.append(5)
    kv.reserve_ahead(b.rid, 4)               # b holds a reserved window
    assert kv.n_used == 3
    # a's big reservation cannot fit beside b -> b (younger) is preempted,
    # and ALL of b's pages (1 allocated + 1 reserved) come back
    sched.reserve_lookahead(sa, 12)
    assert sb not in sched.slots and sched.waiting[0] is b
    assert b.n_preemptions == 1
    assert kv.n_used == 4                    # a alone: 1 page + 3 reserved
    _pool_ok(kv)
    # b's re-admission starts from a clean allocation
    assert b.rid not in kv._seqs


# -------------------------- engine equivalence -------------------------- #

def _reqs(cfg, n=4, seed=11, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=rng.integers(lo, hi)).tolist()
            for _ in range(n)]


@pytest.mark.slow
@pytest.mark.parametrize("kv_policy,prefix_cache", [
    ("native", True), ("native", False), ("int8", True), ("int8", False),
])
def test_lookahead_token_identical(small_model, kv_policy, prefix_cache):
    """Acceptance: K=8 fused decode is token-identical to the K=1 per-token
    path (f32 + int8, prefix cache on/off), and to the static engine under
    the native policy."""
    cfg, opts, params = small_model
    reqs = _reqs(cfg)
    outs = {}
    for k in (1, 8):
        eng = ServeEngine(cfg, params, opts, max_len=40,
                          scheduler="continuous", page_size=4, max_batch=4,
                          kv_policy=kv_policy, prefix_cache=prefix_cache,
                          prefill_chunk=8, decode_lookahead=k)
        outs[k] = eng.serve([r[:] for r in reqs], 6)
        assert eng.kv_manager.n_used == 0
    assert outs[1] == outs[8]
    if kv_policy == "native":
        want = ServeEngine(cfg, params, opts, max_len=40).serve(
            [r[:] for r in reqs], 6)
        assert outs[8] == want


@pytest.mark.slow
def test_lookahead_eos_mid_block_token_identical(small_model):
    """EOS firing inside a fused block retires the request at the block
    boundary with the same output as the per-token path."""
    cfg, opts, params = small_model
    reqs = _reqs(cfg, n=3, seed=12)
    base = ServeEngine(cfg, params, opts, max_len=48,
                       scheduler="continuous", page_size=4, max_batch=4,
                       decode_lookahead=1).serve([r[:] for r in reqs], 10)
    eos = base[0][4]                          # fires mid-block for K=8
    outs = {}
    for k in (1, 8):
        eng = ServeEngine(cfg, params, opts, max_len=48, eos_id=eos,
                          scheduler="continuous", page_size=4, max_batch=4,
                          decode_lookahead=k)
        outs[k] = eng.serve([r[:] for r in reqs], 10)
        assert eng.kv_manager.n_used == 0
    assert outs[1] == outs[8]
    assert outs[8][0][-1] == eos and len(outs[8][0]) <= 10


def test_lookahead_preemption_token_identical(small_model):
    """A pool too small for everyone's lookahead windows preempts LIFO and
    still reproduces the static engine's tokens."""
    cfg, opts, params = small_model
    reqs = [list(range(1, 5)), list(range(5, 9))]
    want = ServeEngine(cfg, params, opts, max_len=32).serve(
        [r[:] for r in reqs], 12)
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=4, max_batch=2, n_pages=6,
                      decode_lookahead=4)
    assert eng.serve([r[:] for r in reqs], 12) == want
    assert eng.stats.preemptions >= 1
    assert eng.kv_manager.n_used == 0


@pytest.mark.slow
def test_static_generate_lookahead_identical(small_model):
    """The static engine's fused blocks emit the same columns for every K,
    including the EOS early-exit step."""
    cfg, opts, params = small_model
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (3, 6),
                                            1, cfg.vocab))
    outs = {k: ServeEngine(cfg, params, opts, max_len=64,
                           decode_lookahead=k).generate(prompts, 12)
            for k in (1, 4, 8)}
    assert outs[1] == outs[4] == outs[8]
    eos = outs[1][0][3]
    eouts = {k: ServeEngine(cfg, params, opts, max_len=64, eos_id=eos,
                            decode_lookahead=k).generate(prompts, 12)
             for k in (1, 4, 8)}
    assert eouts[1] == eouts[4] == eouts[8]
    assert len(eouts[1][0]) <= 12


# ---------------------- sync / compile accounting ----------------------- #

def test_host_sync_bound_and_decode_compiles(small_model):
    """Satellite acceptance: a T-token decode takes <= ceil(T/K) + O(1)
    host syncs, and the fixed block shape compiles once (the counter
    mirrors prefill_compiles)."""
    cfg, opts, params = small_model
    T, K = 16, 8
    rng = np.random.default_rng(13)
    req = [rng.integers(1, cfg.vocab, size=5).tolist()]
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=4, max_batch=2, prefill_chunk=8,
                      decode_lookahead=K)
    eng.serve([req[0][:]], T)
    s = eng.stats
    assert s.host_syncs <= -(-T // K) + 2    # 1 prefill chunk + 2 blocks
    assert s.decode_compiles == 1
    # the same workload at K=1 syncs ~T times; K=8 must be strictly fewer
    eng1 = ServeEngine(cfg, params, opts, max_len=32,
                       scheduler="continuous", page_size=4, max_batch=2,
                       prefill_chunk=8, decode_lookahead=1)
    eng1.serve([req[0][:]], T)
    assert s.host_syncs < eng1.stats.host_syncs
    assert eng1.stats.host_syncs >= T        # per-token round-trips


def test_decode_steps_counts_block_micro_steps(small_model):
    """decode_steps counts launched micro-steps, so K=1 matches the legacy
    per-token accounting."""
    cfg, opts, params = small_model
    req = [[7, 8, 9]]
    eng = ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                      page_size=4, max_batch=1, decode_lookahead=1)
    eng.serve([req[0][:]], 5)
    assert eng.stats.decode_steps == 4       # token 0 came from prefill


# ------------------------------ helpers --------------------------------- #

def test_pad_pow2():
    assert _pad_pow2([], (0, 0)) == [(0, 0)]
    assert _pad_pow2([(1, 2)], (0, 0)) == [(1, 2)]
    assert _pad_pow2([(1, 2)] * 3, (0, 0)) == [(1, 2)] * 3 + [(0, 0)]
    for n in (2, 5, 9):
        out = _pad_pow2(list(range(n)), -1)
        assert len(out) & (len(out) - 1) == 0   # power of two
        assert out[:n] == list(range(n))
