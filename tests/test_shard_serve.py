"""Multi-device head-sharded serving (DESIGN.md SS16): shard-vs-single
kernel oracles (f32 + int8), engine token identity across mesh sizes,
overlapped-stream invariants, and the per-device tier budget.

The multi-device tests skip unless the host exposes enough devices; the
CI shard lane runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``. Tests never set
that flag themselves — it must land before jax initializes.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.reduce import reduced
from repro.models import (RuntimeOptions, decode_step_paged,
                          decode_steps_paged, init_paged_cache, init_params,
                          prefill_paged)
from repro.models.lm import prefill_paged_chunk
from repro.serving import ServeEngine, VirtualStream

pytestmark = pytest.mark.shard

N_DEV = len(jax.devices())


def _needs(n):
    return pytest.mark.skipif(
        N_DEV < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


def _cfg(n_kv_heads):
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    return dataclasses.replace(cfg, n_kv_heads=n_kv_heads)


# --------------------------- kernel oracles ---------------------------- #

@_needs(2)
@pytest.mark.parametrize("cache_dtype", ["", "int8"])
def test_sharded_kernels_bitwise_match_single_device(cache_dtype):
    """Head-sharding is a layout change, not a numerics change: the
    decode step, the chunked prefill, and the fused decode scan must be
    BITWISE identical to the unsharded kernels — sharded operands see the
    same per-head slices, and the all-gather only reorders."""
    cfg = _cfg(n_kv_heads=2)
    mesh = jax.make_mesh((2,), ("model",), devices=jax.devices()[:2])
    opts0 = RuntimeOptions(dtype="float32", cache_dtype=cache_dtype)
    opts1 = dataclasses.replace(opts0, kv_shard_mesh=mesh)
    params = init_params(cfg, jax.random.PRNGKey(0), opts0)
    B, S, K, ps = 2, 8, 4, 4
    rng = np.random.default_rng(3)
    true_len = np.asarray([8, 6], np.int32)
    toks = np.zeros((B, S), np.int32)
    for b in range(B):
        toks[b, :true_len[b]] = rng.integers(1, cfg.vocab, size=true_len[b])
    npp = (S + K + ps - 1) // ps
    n_pages = B * npp + 1
    pt = np.arange(1, B * npp + 1, dtype=np.int32).reshape(B, npp)
    cal = cache_dtype == "int8"

    def dec_loop(opts):
        cache = init_paged_cache(cfg, n_pages, ps, opts)
        logits, cache = prefill_paged(cfg, params, jnp.asarray(toks), cache,
                                      jnp.asarray(pt[:, :S // ps]),
                                      jnp.asarray(true_len), opts,
                                      calibrate=cal)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        lens = jnp.asarray(true_len)
        cols = [np.asarray(tok)]
        for _ in range(K):
            logits, cache = decode_step_paged(cfg, params, tok, lens,
                                              jnp.asarray(pt), cache, opts)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cols.append(np.asarray(tok))
            lens = lens + 1
        return np.stack(cols, 1), np.asarray(logits)

    def chunk(opts):
        cache = init_paged_cache(cfg, n_pages, ps, opts)
        lg, _ = prefill_paged_chunk(cfg, params, jnp.asarray(toks), cache,
                                    jnp.asarray(pt), jnp.int32(0),
                                    jnp.asarray(true_len), opts,
                                    calibrate=cal)
        return np.asarray(lg)

    def fused(opts):
        cache = init_paged_cache(cfg, n_pages, ps, opts)
        logits, cache = prefill_paged(cfg, params, jnp.asarray(toks), cache,
                                      jnp.asarray(pt[:, :S // ps]),
                                      jnp.asarray(true_len), opts,
                                      calibrate=cal)
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        blk, _ = decode_steps_paged(cfg, params, tok0, jnp.asarray(true_len),
                                    jnp.asarray(pt), cache, K, opts)
        return np.asarray(blk)

    for fn in (dec_loop, chunk, fused):
        base, shard = fn(opts0), fn(opts1)
        if not isinstance(base, tuple):
            base, shard = (base,), (shard,)
        for a, b in zip(base, shard):
            assert np.array_equal(a, b), fn.__name__


# ----------------------- engine token identity ------------------------- #

@pytest.fixture(scope="module")
def shard_model():
    cfg = _cfg(n_kv_heads=4)               # divisible by meshes {1, 2, 4}
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


@_needs(2)
def test_engine_token_identity_across_mesh_sizes(shard_model):
    """Acceptance: serve output is token-identical to the single-device
    engine at every mesh size, overlapped or serialized."""
    cfg, opts, params = shard_model
    rng = np.random.default_rng(0)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist()
            for n in (20, 9, 14, 6)]
    kw = dict(max_len=40, scheduler="continuous", page_size=8, max_batch=3)
    want = ServeEngine(cfg, params, opts, **kw).serve(
        [r[:] for r in reqs], 8)
    for shards in (1, 2, 4):
        if shards > N_DEV:
            continue
        for overlap in (True, False):
            eng = ServeEngine(cfg, params, opts, **kw, shards=shards,
                              overlap=overlap)
            got = eng.serve([r[:] for r in reqs], 8)
            assert got == want, (shards, overlap)


@_needs(2)
def test_shard_constructor_validation(shard_model):
    cfg, opts, params = shard_model
    with pytest.raises(ValueError, match="shards"):
        ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                    shards=0)
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeEngine(cfg, params, opts, max_len=32, scheduler="continuous",
                    shards=3)
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(cfg, params, opts, max_len=32, scheduler="static",
                    shards=2)


# ------------------------ per-device tier budget ----------------------- #

@_needs(4)
def test_per_device_budget_admits_what_one_device_cannot(shard_model):
    """The paper's memory constraint is per chip: each of N shards holds
    1/N of every page, so the same DDR+HBS hierarchy admits N× the pages.
    A request the single-device pool must reject outright runs (token-
    identically) on the 4-way mesh."""
    from repro.core import hbs, lpddr6, npu_hierarchy
    from repro.serving.kv_manager import page_bytes

    cfg, opts, params = shard_model
    pb = page_bytes(cfg, 8, 4)             # native f32 pool width
    hier = npu_hierarchy(
        lpddr6(capacity_gb=1.5 * pb / 1e9),       # 1 page/dev fast
        hbs(1e3, latency_us=0.0, capacity_gb=2.5 * pb / 1e9))
    rng = np.random.default_rng(7)
    req = rng.integers(1, cfg.vocab, size=20).tolist()   # 4 pages total
    kw = dict(max_len=32, scheduler="continuous", page_size=8, max_batch=2)

    with pytest.raises(ValueError, match="across all"):
        ServeEngine(cfg, params, opts, **kw,
                    hierarchy=hier).serve([req[:]], 8)

    want = ServeEngine(cfg, params, opts, **kw).serve([req[:]], 8)
    eng4 = ServeEngine(cfg, params, opts, **kw, hierarchy=hier, shards=4)
    assert eng4.serve([req[:]], 8) == want
    assert eng4.stats.peak_fast_pages <= 6        # per-device fast budget


# -------------------------- stream invariants -------------------------- #

def test_virtual_stream_semantics():
    s = VirtualStream("p")
    t0 = s.start(0.0)
    assert t0 == 0.0
    assert s.commit(t0, 2.0) == 2.0 and s.free == 2.0
    assert s.start(1.0) == 2.0             # stream busy until free
    assert s.start(3.0) == 3.0             # input readiness dominates
    assert s.commit(3.0, -1.0) == 3.0      # durations clamp at zero
    assert s.busy_s == pytest.approx(2.0)


def test_overlap_makespan_within_serialized_envelope(shard_model):
    """The two-stream makespan never exceeds the summed phase time (any
    gap on one stream is covered by the other), and the serialized engine
    degenerates to exactly that sum."""
    cfg, opts, params = shard_model
    rng = np.random.default_rng(1)
    reqs = [rng.integers(1, cfg.vocab, size=n).tolist() for n in (18, 7, 12)]
    kw = dict(max_len=32, scheduler="continuous", page_size=8, max_batch=2)

    over = ServeEngine(cfg, params, opts, **kw)
    over.serve([r[:] for r in reqs], 8)
    s = over.stats
    assert 0.0 < s.serve_s <= s.prefill_s + s.decode_s + 1e-9

    ser = ServeEngine(cfg, params, opts, **kw, overlap=False)
    ser.serve([r[:] for r in reqs], 8)
    t = ser.stats
    assert t.serve_s == pytest.approx(t.prefill_s + t.decode_s)
